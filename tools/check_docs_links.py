"""Dead-link checker for the repo's markdown docs.

Walks every ``*.md`` under the repo root, extracts relative links
(``[text](path)`` and ``[text](path#anchor)``), and verifies each
target exists on disk relative to the file that links it.  External
schemes (http/https/mailto) and pure in-page anchors are skipped —
this guards the *repo-internal* doc graph (README → docs/*, docs
cross-references), which is the part that silently rots when files
move.

    python tools/check_docs_links.py          # exit 1 + listing on rot
    python tools/check_docs_links.py --root X
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

__all__ = ["broken_links", "iter_md_files", "links_in"]

#: ``[label](target)`` with an optional ``#anchor`` split off; the
#: target group deliberately excludes ``)``, ``#`` and whitespace so
#: titles (``[x](y "title")``) and anchors don't pollute the path
_LINK_RE = re.compile(r"\[[^\]]*\]\(\s*([^)#\s]+)(#[^)]*)?\s*\)")

#: inline code spans are stripped first so ``[i](j)`` indexing examples
#: inside backticks never count as links
_CODE_SPAN_RE = re.compile(r"`[^`]*`")
_FENCE_RE = re.compile(r"^(```|~~~)")

_SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache",
              "node_modules", ".venv", "venv"}
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def iter_md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not any(part in _SKIP_DIRS for part in path.parts):
            yield path


def links_in(text: str):
    """Yield relative-link targets, skipping fenced code blocks,
    inline code spans, external schemes and pure anchors."""
    in_fence = False
    for line in text.splitlines():
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(_CODE_SPAN_RE.sub("", line)):
            target = m.group(1)
            if target.startswith(_SKIP_SCHEMES):
                continue
            yield target


def broken_links(root: Path):
    """``[(md_file, target)]`` for every relative link whose target
    does not exist on disk."""
    broken = []
    for md in iter_md_files(root):
        for target in links_in(md.read_text(encoding="utf-8")):
            base = root if target.startswith("/") else md.parent
            if not (base / target.lstrip("/")).exists():
                broken.append((md.relative_to(root), target))
    return broken


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=Path(__file__).resolve().parent.parent,
                    type=Path, help="repo root to scan (default: repo)")
    args = ap.parse_args(argv)
    bad = broken_links(args.root.resolve())
    for md, target in bad:
        print(f"docs-links: {md}: dead relative link -> {target}")
    if bad:
        print(f"docs-links: {len(bad)} dead link(s)")
        return 1
    n = sum(1 for _ in iter_md_files(args.root.resolve()))
    print(f"docs-links: ok ({n} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
