"""Hypothesis when importable, a deterministic fallback otherwise.

Test modules import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` so the tier-1 suite collects and runs in a bare
environment.  The fallback is *not* a property-testing engine — it simply
replays ``max_examples`` seeded draws from each strategy (seeded by the
test's qualified name, so failures reproduce), with no shrinking and no
example database.  Install ``hypothesis`` (see requirements-dev.txt) to
get the real thing; nothing else changes.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import zlib

    import numpy as _np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _strategies:
        """The (small) subset of hypothesis.strategies this repo uses."""

        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kwargs):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    st = _strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kwargs):
        """Accepts (and mostly ignores) hypothesis.settings kwargs."""
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # No functools.wraps: copying __wrapped__ would let pytest
            # see the strategy parameters and demand fixtures for them.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*args, *(s.example(rng) for s in strategies),
                       **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper._shim_max_examples = getattr(
                fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            return wrapper
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
