"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (the FULL configs are exercised
via the dry-run with ShapeDtypeStructs only)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_arch
from repro.data.synthetic import dlrm_batch, gnn_batch, lm_batch
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

LM_ARCHS = ["command-r-plus-104b", "command-r-35b", "starcoder2-7b"]
MOE_ARCHS = ["qwen3-moe-235b-a22b", "grok-1-314b"]
GNN_ARCHS = ["meshgraphnet", "schnet", "pna", "equiformer-v2"]


def _finite(x):
    return bool(np.isfinite(np.asarray(x, np.float32)).all())


@pytest.mark.parametrize("name", LM_ARCHS)
class TestDenseLM:
    def test_train_step(self, name):
        from repro.models.transformer import init_lm, train_forward
        cfg = get_arch(name).reduced_cfg
        params = init_lm(jax.random.key(0), cfg)
        batch = jax.tree.map(jnp.asarray, lm_batch(0, 2, 32, cfg.vocab))
        loss = jax.jit(lambda p, b: train_forward(cfg, p, b))(params, batch)
        assert _finite(loss) and float(loss) > 0

    def test_prefill_then_decode(self, name):
        from repro.models.transformer import decode_step, init_lm, prefill
        cfg = get_arch(name).reduced_cfg
        params = init_lm(jax.random.key(0), cfg)
        tokens = jnp.ones((2, 16), jnp.int32)
        logits, cache = jax.jit(lambda p, t: prefill(cfg, p, t))(params,
                                                                 tokens)
        assert logits.shape == (2, cfg.vocab)
        smax = 32
        kc = jnp.zeros((cfg.n_layers, 2, cfg.n_kv_heads, smax, cfg.d_head),
                       jnp.bfloat16).at[:, :, :, :16].set(
            cache[0].astype(jnp.bfloat16))
        vc = jnp.zeros_like(kc).at[:, :, :, :16].set(
            cache[1].astype(jnp.bfloat16))
        lg, (kc2, vc2) = jax.jit(
            lambda p, t, c, n: decode_step(cfg, p, t, c, n))(
            params, jnp.ones((2, 1), jnp.int32), (kc, vc), jnp.int32(16))
        assert lg.shape == (2, 1, cfg.vocab) and _finite(lg)
        assert kc2.shape == kc.shape

    def test_decode_matches_prefill_logits(self, name):
        """Decoding token t with the cache == prefill logits at position t."""
        from repro.models.transformer import decode_step, init_lm, prefill
        cfg = dataclasses.replace(get_arch(name).reduced_cfg, remat=False)
        params = init_lm(jax.random.key(1), cfg)
        toks = jax.random.randint(jax.random.key(2), (1, 8), 0, cfg.vocab)
        full_logits, _ = prefill(cfg, params, toks)
        # prefill returns last-token logits; rebuild by decoding step 7
        _, cache7 = prefill(cfg, params, toks[:, :7])
        smax = 8
        kc = jnp.zeros((cfg.n_layers, 1, cfg.n_kv_heads, smax, cfg.d_head),
                       jnp.float32).at[:, :, :, :7].set(cache7[0])
        vc = jnp.zeros_like(kc).at[:, :, :, :7].set(cache7[1])
        lg, _ = decode_step(cfg, params, toks[:, 7:8], (kc, vc),
                            jnp.int32(7))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits), rtol=2e-2,
                                   atol=2e-2)


@pytest.mark.parametrize("name", MOE_ARCHS)
class TestMoELM:
    def test_train_step(self, name):
        from repro.models.moe import init_moe_lm, moe_train_forward
        cfg = get_arch(name).reduced_cfg
        params = init_moe_lm(jax.random.key(0), cfg)
        batch = jax.tree.map(jnp.asarray, lm_batch(0, 2, 32, cfg.vocab))
        loss = jax.jit(lambda p, b: moe_train_forward(cfg, p, b))(params,
                                                                  batch)
        assert _finite(loss) and float(loss) > 0

    def test_expert_counts(self, name):
        """Every token is routed to exactly top_k experts."""
        from repro.models.moe import init_moe_layer, moe_apply
        cfg = get_arch(name).reduced_cfg
        p = init_moe_layer(jax.random.key(3), cfg)
        x = jax.random.normal(jax.random.key(4), (64, cfg.d_model),
                              jnp.bfloat16)
        y, aux = moe_apply(p, x, cfg)
        assert y.shape == x.shape and _finite(y) and _finite(aux)


@pytest.mark.parametrize("name", GNN_ARCHS)
class TestGNN:
    def test_train_step(self, name):
        arch = get_arch(name)
        cfg = arch.reduced_cfg
        rng = np.random.default_rng(0)
        n, e, g = 64, 256, getattr(cfg, "n_graphs", 4)
        batch = {
            "src": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
            "dst": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        }
        if name in ("schnet", "equiformer-v2"):
            batch.update({
                "species": jnp.asarray(rng.integers(0, 10, n)
                                       .astype(np.int32)),
                "positions": jnp.asarray(
                    rng.standard_normal((n, 3)).astype(np.float32)),
                "graph_ids": jnp.asarray((np.arange(n) % g)
                                         .astype(np.int32)),
                "energy": jnp.zeros((g,), jnp.float32),
            })
            from repro.models.gnn.equiformer_v2 import equiformer_loss
            from repro.models.gnn.schnet import schnet_loss
            loss_fn = schnet_loss if name == "schnet" else equiformer_loss
        elif name == "meshgraphnet":
            from repro.models.gnn.meshgraphnet import mgn_loss
            batch.update({
                "node_feat": jnp.asarray(rng.standard_normal(
                    (n, cfg.d_node_in)).astype(np.float32)),
                "edge_feat": jnp.asarray(rng.standard_normal(
                    (e, cfg.d_edge_in)).astype(np.float32)),
                "target": jnp.zeros((n, cfg.d_out), jnp.float32),
            })
            loss_fn = mgn_loss
        else:
            from repro.models.gnn.pna import pna_loss
            deg = np.zeros(n)
            np.add.at(deg, np.asarray(batch["dst"]), 1)
            batch.update({
                "node_feat": jnp.asarray(rng.standard_normal(
                    (n, cfg.d_in)).astype(np.float32)),
                "in_degree": jnp.asarray(deg.astype(np.int32)),
                "labels": jnp.asarray(rng.integers(0, cfg.n_classes, n)
                                      .astype(np.int32)),
            })
            loss_fn = pna_loss
        params = arch.init_params(jax.random.key(0), cfg)
        opt = adamw_init(params)

        def step(p, o, b):
            loss, grads = jax.value_and_grad(
                lambda pp: loss_fn(cfg, pp, b))(p)
            np_, no_, gn = adamw_update(grads, o, p, AdamWConfig(lr=1e-3))
            return np_, no_, loss

        p2, o2, loss = jax.jit(step)(params, opt, batch)
        assert _finite(loss)
        # params actually moved
        moved = any(
            not np.allclose(np.asarray(a, np.float32),
                            np.asarray(b, np.float32))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
        assert moved


class TestDLRM:
    def test_train_step(self):
        from repro.models.dlrm import dlrm_loss, init_dlrm
        arch = get_arch("dlrm-mlperf")
        cfg = arch.reduced_cfg
        params = init_dlrm(jax.random.key(0), cfg)
        batch = jax.tree.map(jnp.asarray,
                             dlrm_batch(0, 32, cfg.vocab_sizes,
                                        cfg.multi_hot))
        loss = jax.jit(lambda p, b: dlrm_loss(cfg, p, b))(params, batch)
        assert _finite(loss) and 0.1 < float(loss) < 3.0

    def test_pallas_lookup_matches_xla(self):
        from repro.models.dlrm import dlrm_forward, init_dlrm
        arch = get_arch("dlrm-mlperf")
        cfg = arch.reduced_cfg
        params = init_dlrm(jax.random.key(0), cfg)
        batch = jax.tree.map(jnp.asarray,
                             dlrm_batch(1, 16, cfg.vocab_sizes,
                                        cfg.multi_hot))
        a = dlrm_forward(cfg, params, batch, impl="xla")
        b = dlrm_forward(cfg, params, batch, impl="pallas")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)

    def test_retrieval(self):
        from repro.models.dlrm import init_dlrm, retrieval_score
        arch = get_arch("dlrm-mlperf")
        cfg = arch.reduced_cfg
        params = init_dlrm(jax.random.key(0), cfg)
        rng = np.random.default_rng(0)
        batch = {
            "dense": jnp.asarray(rng.standard_normal((1, 13))
                                 .astype(np.float32)),
            "sparse": jnp.zeros((1, cfg.n_sparse, 1), jnp.int32),
            "cand": jnp.asarray(rng.standard_normal(
                (5000, cfg.embed_dim)).astype(np.float32)),
        }
        scores = retrieval_score(cfg, params, batch)
        assert scores.shape == (5000,) and _finite(scores)


def test_all_archs_have_4_cells():
    for name in ARCH_NAMES:
        assert len(get_arch(name).cells) == 4, name


def test_equiformer_rotation_invariance():
    from repro.models.gnn.equiformer_v2 import (equiformer_forward,
                                                init_equiformer)
    arch = get_arch("equiformer-v2")
    cfg = arch.reduced_cfg
    params = init_equiformer(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    n, e, g = 48, 128, cfg.n_graphs
    batch = {
        "species": jnp.asarray(rng.integers(0, 10, n).astype(np.int32)),
        "positions": jnp.asarray(rng.standard_normal((n, 3))
                                 .astype(np.float32) * 2),
        "src": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        "dst": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        "graph_ids": jnp.asarray((np.arange(n) % g).astype(np.int32)),
    }
    rot = np.linalg.qr(rng.standard_normal((3, 3)))[0]
    if np.linalg.det(rot) < 0:
        rot[:, 0] *= -1
    e1 = equiformer_forward(cfg, params, batch)
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] @ jnp.asarray(rot.T, jnp.float32)
    e2 = equiformer_forward(cfg, params, batch2)
    rel = float(jnp.abs(e1 - e2).max() / (jnp.abs(e1).max() + 1e-9))
    assert rel < 5e-3
