"""Overload robustness: deadline-aware load shedding, the per-lane
circuit breaker (open → solo-degraded → half-open probe → close, bit-
identical throughout), and the cancel-vs-retirement race property.
"""
import numpy as np
import pytest

from repro.algorithms import REGISTRY
from repro.core import SystemConfig
from repro.graph import rmat_graph
from repro.launch.serve import (CancelledError, ContinuousScheduler,
                                GatewayStats, OverloadError, Ticket,
                                _Breaker)
from repro.testing.faults import InjectedFault, SliceFaultInjector


def _graph(seed=3):
    return rmat_graph(scale=6, edge_factor=8, seed=seed, weighted=False)


def _states_equal(a, b):
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


class PackedOnlyFault(SliceFaultInjector):
    """Fail packed-roster slices only — solo (B=1) slices succeed.
    The breaker's reason to exist: a cohabitation-triggered failure
    that isolation routes around."""

    def __init__(self, times=None):
        self.times = times
        self.fired = 0

    def before_slice(self, ticket_ids):
        if len(ticket_ids) < 2:
            return
        if self.times is not None and self.fired >= self.times:
            return
        self.fired += 1
        raise InjectedFault(f"packed cohabitation failure "
                            f"(tickets={ticket_ids})")


# ---------------------------------------------------------------------------
class TestShedding:
    def _loaded(self, service_times=(1.0, 1.0)):
        sched = ContinuousScheduler(max_batch=2, slice_len=2)
        sched.stats.service_times_s.extend(service_times)
        program = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        g = _graph()
        return sched, program, config, g

    def test_hopeless_deadline_is_shed(self):
        sched, program, config, g = self._loaded()
        for _ in range(4):  # two full waves already waiting
            sched.submit(program, g, config)
        with pytest.raises(OverloadError) as ei:
            sched.submit(program, g, config, deadline_s=0.5)
        assert ei.value.code == "overload_shed"
        assert ei.value.detail["projected_delay_s"] > 0.5
        assert ei.value.detail["queued"] == 4
        assert sched.stats.shed == 1
        assert sched.stats.snapshot()["shed"] == 1

    def test_feasible_deadline_is_admitted(self):
        sched, program, config, g = self._loaded()
        for _ in range(4):
            sched.submit(program, g, config)
        t = sched.submit(program, g, config, deadline_s=100.0)
        assert t is not None and sched.stats.shed == 0

    def test_no_deadline_never_shed(self):
        sched, program, config, g = self._loaded(service_times=(50.0,))
        for _ in range(8):
            sched.submit(program, g, config)  # arbitrarily deep queue
        assert sched.stats.shed == 0

    def test_cold_gateway_never_sheds(self):
        # no completions yet -> no projection -> no shedding, however
        # tight the deadline
        sched = ContinuousScheduler(max_batch=2, slice_len=2)
        program = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        g = _graph()
        for _ in range(6):
            sched.submit(program, g, config, deadline_s=1e-9)
        assert sched.stats.shed == 0

    def test_projection_math(self):
        s = GatewayStats()
        assert s.projected_delay_s(0, 4) is None
        s.service_times_s.extend([2.0, 4.0])   # mean 3.0
        assert s.projected_delay_s(0, 4) == 3.0    # next wave
        assert s.projected_delay_s(7, 4) == 6.0    # one full wave ahead
        assert s.projected_delay_s(8, 4) == 9.0

    def test_projection_ignores_queue_wait(self):
        # a past congestion episode leaves huge *end-to-end* latencies
        # behind; the projection must be built from service time alone,
        # or the gateway keeps shedding long after the queue drained
        s = GatewayStats()
        t = Ticket(None, None, None, None, None, None)
        t.enqueued_at, t.admitted_at = 0.0, 99.0   # 99 s stuck queued
        t.completed_at = 100.0                     # 1 s of actual work
        s.record_done(t, "converged")
        assert s.latencies_s == [100.0]
        assert s.projected_delay_s(0, 4) == 1.0

    def test_service_window_is_bounded(self):
        s = GatewayStats()
        n = GatewayStats.SERVICE_WINDOW + 8
        for i in range(n):
            t = Ticket(None, None, None, None, None, None)
            t.enqueued_at = t.admitted_at = float(i)
            t.completed_at = float(i) + (100.0 if i < 8 else 1.0)
            s.record_done(t, "converged")
        assert len(s.service_times_s) == GatewayStats.SERVICE_WINDOW
        assert len(s.latencies_s) == n     # observability keeps it all
        # the early 100 s outliers aged out of the projection entirely
        assert s.projected_delay_s(0, 4) == 1.0

    def test_post_congestion_queue_drained_admits_again(self):
        sched, program, config, g = self._loaded(service_times=(0.1,))
        sched.stats.latencies_s.extend([50.0] * 8)  # congestion scars
        assert sched.queued() == 0
        t = sched.submit(program, g, config, deadline_s=1.0)
        assert t is not None and sched.stats.shed == 0

    def test_shed_request_leaves_no_lane_state(self):
        sched, program, config, g = self._loaded()
        for _ in range(4):
            sched.submit(program, g, config)
        queued_before = sched.queued()
        with pytest.raises(OverloadError):
            sched.submit(program, g, config, deadline_s=1e-9)
        assert sched.queued() == queued_before
        sched.run_until_idle()  # the shed submit poisoned nothing
        assert sched.stats.converged == 4


# ---------------------------------------------------------------------------
class TestBreakerUnit:
    def test_state_machine_walk(self):
        stats = GatewayStats()
        b = _Breaker(threshold=2, cooldown=2)
        assert b.route() == "packed"
        b.record_fault(stats)
        assert b.state == "closed"       # one strike is not an outage
        b.record_fault(stats)
        assert b.state == "open" and b.route() == "solo"
        assert stats.breaker_opens == 1
        b.tick(stats)
        assert b.route() == "solo"       # still cooling
        b.tick(stats)
        assert b.state == "half_open" and b.route() == "probe"
        b.record_clean(stats)
        assert b.state == "closed" and stats.breaker_closes == 1

    def test_faulty_probe_reopens(self):
        stats = GatewayStats()
        b = _Breaker(threshold=1, cooldown=1)
        b.record_fault(stats)
        b.tick(stats)
        assert b.state == "half_open"
        b.record_fault(stats)            # probe failed
        assert b.state == "open" and stats.breaker_opens == 2

    def test_clean_slice_resets_consecutive_count(self):
        stats = GatewayStats()
        b = _Breaker(threshold=2, cooldown=2)
        b.record_fault(stats)
        b.record_clean(stats)            # intermittent, not consecutive
        b.record_fault(stats)
        assert b.state == "closed"

    def test_rejects_degenerate_params(self):
        with pytest.raises(ValueError):
            _Breaker(threshold=0)
        with pytest.raises(ValueError):
            _Breaker(cooldown=0)


class TestBreakerIntegration:
    def test_packed_fault_opens_breaker_and_degrades_solo(self):
        # SSSP with 1-iteration slices: enough dispatch rounds remain
        # after the breaker opens for the solo-degraded routing (and
        # the half-open probe) to actually run
        program = REGISTRY["SSSP"]()
        config = SystemConfig.from_name("DG1")
        graphs = [rmat_graph(scale=7, edge_factor=8, seed=s,
                             weighted=True) for s in (3, 4, 5, 6)]

        clean = ContinuousScheduler(max_batch=4, slice_len=1)
        ref = [clean.submit(program, g, config) for g in graphs]
        clean.run_until_idle()

        sched = ContinuousScheduler(
            max_batch=4, slice_len=1, breaker_threshold=2,
            breaker_cooldown=2, fault_injector=PackedOnlyFault())
        tickets = [sched.submit(program, g, config) for g in graphs]
        sched.run_until_idle()

        s = sched.stats
        assert s.breaker_opens >= 1       # packed faults tripped it
        assert s.solo_degraded_slices > 0  # open => isolated B=1 routing
        assert s.quarantined == 0          # degraded, never sacrificed
        for rt, t in zip(ref, tickets):
            assert t.result(0).converged
            assert _states_equal(rt.result(0).state, t.result(0).state)

    def test_breaker_closes_after_fault_clears(self):
        program = REGISTRY["SSSP"]()
        config = SystemConfig.from_name("DG1")
        graphs = [rmat_graph(scale=7, edge_factor=8, seed=s,
                             weighted=True) for s in (3, 4, 5, 6)]
        # the fault burns out after enough packed failures to open the
        # breaker once (3 raises: dispatch + its in-recovery whole-
        # roster retry, then the next dispatch), so the eventual
        # half-open probe runs clean; 1-iteration slices + a short
        # cooldown leave work for the probe to run on
        sched = ContinuousScheduler(
            max_batch=4, slice_len=1, breaker_threshold=2,
            breaker_cooldown=1, fault_injector=PackedOnlyFault(times=3))
        tickets = [sched.submit(program, g, config) for g in graphs]
        sched.run_until_idle()
        s = sched.stats
        assert s.breaker_opens == 1
        assert s.breaker_probes >= 1
        assert s.breaker_closes == 1       # recovered to packed routing
        assert all(t.result(0).converged for t in tickets)

    def test_breaker_counters_in_snapshot(self):
        snap = ContinuousScheduler().stats.snapshot()
        for key in ("breaker_opens", "breaker_closes", "breaker_probes",
                    "solo_degraded_slices", "shed", "recovered_tickets"):
            assert snap[key] == 0


# ---------------------------------------------------------------------------
class TestCancelRetirementRace:
    def test_cancel_racing_retirement_property(self, monkeypatch):
        """Seeded interleavings of ``cancel()`` against slot
        retirement: whatever wins, every ticket finishes exactly once
        and ``result()`` never deadlocks."""
        finishes = {}
        orig = Ticket._finish

        def counting_finish(self, result, error, now):
            finishes[self.id] = finishes.get(self.id, 0) + 1
            orig(self, result, error, now)

        monkeypatch.setattr(Ticket, "_finish", counting_finish)

        program = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        graphs = [_graph(seed=s) for s in (3, 4)]
        for seed in range(8):
            rng = np.random.default_rng(seed)
            finishes.clear()
            sched = ContinuousScheduler(max_batch=2, slice_len=1)
            tickets = [sched.submit(program, graphs[i % 2], config)
                       for i in range(4)]
            # one victim cancelled at a random poll boundary — from
            # "still queued" through "about to retire" to "already done"
            victim = tickets[int(rng.integers(len(tickets)))]
            cancel_at = int(rng.integers(12))
            for round_ in range(10_000):
                if round_ == cancel_at:
                    victim.cancel()
                    victim.cancel()     # double-cancel must be a no-op
                if not sched.pending():
                    break
                sched.poll()
            if victim.cancelled and not victim.done():
                sched.poll()            # queued-cancel needs one round
            for t in tickets:
                assert t.done(), (seed, t.id)      # no deadlock
                assert finishes[t.id] == 1, (seed, t.id)  # exactly once
                if t is victim and t.cancelled and t._error is not None:
                    with pytest.raises(CancelledError):
                        t.result(0)
                else:
                    assert t.result(0).converged
            # the lane's accounting agrees with the ticket's terminal
            # state: no slot both cancelled and completed
            s = sched.stats
            assert s.cancelled + s.completed == len(tickets)
