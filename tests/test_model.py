"""Specialization model (Sec. IV): Table V reproduction + partial model."""
import pytest

from repro.core import (TABLE_III, GraphProfile, specialize,
                        specialize_partial)
from repro.core.config_space import SystemConfig
from repro.graph.datasets import PAPER_STATS

TABLE_V = {
    "AMZ": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
    "DCT": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
    "EML": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
    "OLS": dict(PR="SDR", SSSP="SDR", MIS="TG0", CLR="TG0", BC="SDR",
                CC="DD1"),
    "RAJ": dict(PR="SDR", SSSP="SDR", MIS="SDR", CLR="SDR", BC="SDR",
                CC="DD1"),
    "WNG": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
}


def _profile(name):
    vc, rc, ic = PAPER_STATS[name][7:10]
    return GraphProfile.from_classes(vc, rc, ic)


@pytest.mark.parametrize("gname", sorted(TABLE_V))
@pytest.mark.parametrize("app", ["PR", "SSSP", "MIS", "CLR", "BC", "CC"])
def test_table_v_prediction(gname, app):
    got = specialize(TABLE_III[app], _profile(gname)).name
    assert got == TABLE_V[gname][app], (gname, app)


def test_all_36_match():
    n_match = sum(
        specialize(TABLE_III[app], _profile(g)).name == TABLE_V[g][app]
        for g in TABLE_V for app in TABLE_V[g])
    assert n_match == 36


class TestPartialModel:
    """Sec. IV-B / Sec. VI interdependence: no DRFrlx -> different
    push/pull recommendation."""

    def test_mis_raj_flips_to_pull(self):
        # the paper's flagship example: MIS x RAJ is SDR with DRFrlx,
        # TG0 (pull) without it
        prof = _profile("RAJ")
        assert specialize(TABLE_III["MIS"], prof).name == "SDR"
        assert specialize_partial(TABLE_III["MIS"], prof).name == "TG0"

    def test_partial_never_emits_rlx(self):
        for g in TABLE_V:
            for app in TABLE_V[g]:
                cfg = specialize_partial(TABLE_III[app], _profile(g))
                assert cfg.consistency.value != "R", (g, app)

    def test_source_control_still_pushes(self):
        for g in TABLE_V:
            cfg = specialize_partial(TABLE_III["SSSP"], _profile(g))
            assert cfg.prop.value == "S"


def test_config_names_roundtrip():
    for name in ("TG0", "SGR", "SD1", "DD1", "SG0", "TDR"):
        assert SystemConfig.from_name(name).name == name
