"""Specialization model (Sec. IV): Table V reproduction + partial model."""
import pytest

from repro.core import (TABLE_III, GraphProfile, specialize,
                        specialize_partial)
from repro.core.config_space import SystemConfig
from repro.graph.datasets import PAPER_STATS

TABLE_V = {
    "AMZ": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
    "DCT": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
    "EML": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
    "OLS": dict(PR="SDR", SSSP="SDR", MIS="TG0", CLR="TG0", BC="SDR",
                CC="DD1"),
    "RAJ": dict(PR="SDR", SSSP="SDR", MIS="SDR", CLR="SDR", BC="SDR",
                CC="DD1"),
    "WNG": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR",
                CC="DD1"),
}


def _profile(name):
    vc, rc, ic = PAPER_STATS[name][7:10]
    return GraphProfile.from_classes(vc, rc, ic)


@pytest.mark.parametrize("gname", sorted(TABLE_V))
@pytest.mark.parametrize("app", ["PR", "SSSP", "MIS", "CLR", "BC", "CC"])
def test_table_v_prediction(gname, app):
    got = specialize(TABLE_III[app], _profile(gname)).name
    assert got == TABLE_V[gname][app], (gname, app)


def test_all_36_match():
    n_match = sum(
        specialize(TABLE_III[app], _profile(g)).name == TABLE_V[g][app]
        for g in TABLE_V for app in TABLE_V[g])
    assert n_match == 36


#: Pinned reconstruction of the partial model's (Sec. IV-B: no DRFrlx)
#: predictions on the published Table II classes.  Derived from the
#: documented reading in core/model.py: push loses DRFrlx so it emits
#: *1-consistency; AI==source needs volume M/H (not just any volume) to
#: justify push; target/symmetric apps need volume H; imbalance drops out
#: entirely (its push win was exactly the relaxed-atomics MLP).  This
#: table is the regression anchor — a refactor that shifts any cell is a
#: semantic change to the model, not a cleanup.
TABLE_V_PARTIAL = {
    "AMZ": dict(PR="SG1", SSSP="SG1", MIS="SG1", CLR="SG1", BC="SG1",
                CC="DD1"),
    "DCT": dict(PR="SG1", SSSP="SG1", MIS="SG1", CLR="SG1", BC="SG1",
                CC="DD1"),
    "EML": dict(PR="SG1", SSSP="SG1", MIS="SG1", CLR="SG1", BC="SG1",
                CC="DD1"),
    "OLS": dict(PR="SD1", SSSP="SD1", MIS="TG0", CLR="TG0", BC="SD1",
                CC="DD1"),
    "RAJ": dict(PR="TG0", SSSP="SD1", MIS="TG0", CLR="TG0", BC="SD1",
                CC="DD1"),
    "WNG": dict(PR="SG1", SSSP="SG1", MIS="SG1", CLR="SG1", BC="SG1",
                CC="DD1"),
}


@pytest.mark.parametrize("gname", sorted(TABLE_V_PARTIAL))
@pytest.mark.parametrize("app", ["PR", "SSSP", "MIS", "CLR", "BC", "CC"])
def test_partial_model_prediction(gname, app):
    got = specialize_partial(TABLE_III[app], _profile(gname)).name
    assert got == TABLE_V_PARTIAL[gname][app], (gname, app)


def test_partial_all_36_pinned():
    n_match = sum(
        specialize_partial(TABLE_III[app], _profile(g)).name
        == TABLE_V_PARTIAL[g][app]
        for g in TABLE_V_PARTIAL for app in TABLE_V_PARTIAL[g])
    assert n_match == 36


class TestPartialModel:
    """Sec. IV-B / Sec. VI interdependence: no DRFrlx -> different
    push/pull recommendation."""

    def test_mis_raj_flips_to_pull(self):
        # the paper's flagship example: MIS x RAJ is SDR with DRFrlx,
        # TG0 (pull) without it
        prof = _profile("RAJ")
        assert specialize(TABLE_III["MIS"], prof).name == "SDR"
        assert specialize_partial(TABLE_III["MIS"], prof).name == "TG0"

    def test_partial_never_emits_rlx(self):
        for g in TABLE_V:
            for app in TABLE_V[g]:
                cfg = specialize_partial(TABLE_III[app], _profile(g))
                assert cfg.consistency.value != "R", (g, app)

    def test_source_control_still_pushes(self):
        for g in TABLE_V:
            cfg = specialize_partial(TABLE_III["SSSP"], _profile(g))
            assert cfg.prop.value == "S"


def test_config_names_roundtrip():
    for name in ("TG0", "SGR", "SD1", "DD1", "SG0", "TDR"):
        assert SystemConfig.from_name(name).name == name
