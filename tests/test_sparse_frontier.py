"""Sparse-frontier edge gathering: the O(m_f) push path.

Covers the acceptance criteria of the sparse-frontier PR: round-trip and
overflow properties of the sparse containers, the CSR frontier-edge
gather (empty/full/capacity-1/padding), the gathered segment-reduce
entry point against its numpy oracle, and — at system level — that a
small-frontier BFS iteration provably reduces over only the gathered
[cap_e] slice (reducer call shape + occupancy trace), produces
bit-identical results to the dense path across every config cell, and
falls back to dense on capacity overflow instead of dropping edges.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.algorithms import bfs, sssp
from repro.algorithms.reference import bfs_np, sssp_np
from repro.core import ALL_CONFIGS, EdgeContext, SystemConfig, run
from repro.core.frontier import (dense_to_sparse, gather_frontier_edges,
                                 sparse_to_dense)
from repro.kernels.segment_reduce import (gathered_segment_reduce,
                                          gathered_segment_reduce_ref)
from repro.graph import powerlaw_graph, random_graph

CONFIG_NAMES = [c.name for c in ALL_CONFIGS]


@pytest.fixture(scope="module")
def rand_g():
    return random_graph(64, 400, seed=0, weighted=True, block_size=32)


@pytest.fixture(scope="module")
def sf_g():
    return powerlaw_graph(200, 1500, alpha=1.2, seed=1, weighted=True,
                          block_size=32)


def _gather_ref(ids, row_ptr):
    """Numpy oracle: concatenated CSR edge ranges of the listed vertices."""
    return np.concatenate(
        [np.arange(row_ptr[v], row_ptr[v + 1]) for v in ids if v >= 0]
        or [np.empty(0, np.int64)])


class TestSparseContainers:
    @given(st.integers(1, 96), st.integers(0, 2**31 - 1), st.integers(1, 96))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_property(self, v, seed, capacity):
        """capacity >= count: exact mask round-trip, no overflow;
        capacity < count: ids hold the first `capacity` set bits and the
        true count survives the truncation."""
        rng = np.random.default_rng(seed)
        mask = jnp.asarray(rng.random(v) < rng.random())
        front = dense_to_sparse(mask, capacity)
        n_set = int(np.asarray(mask).sum())
        assert int(front.count) == n_set
        assert bool(front.overflowed) == (n_set > capacity)
        ids = np.asarray(front.ids)
        expect = np.flatnonzero(np.asarray(mask))[:capacity]
        np.testing.assert_array_equal(ids[ids >= 0], expect)
        if n_set <= capacity:
            back = sparse_to_dense(front.ids, v)
            np.testing.assert_array_equal(np.asarray(back), np.asarray(mask))

    def test_capacity_one(self):
        front = dense_to_sparse(jnp.asarray([True, True, True]), 1)
        assert np.asarray(front.ids).tolist() == [0]
        assert int(front.count) == 3 and bool(front.overflowed)

    def test_sparse_to_dense_ignores_padding(self):
        ids = jnp.asarray([-1, 2, -1, 0, -1], jnp.int32)
        mask = sparse_to_dense(ids, 4)
        np.testing.assert_array_equal(np.asarray(mask),
                                      [True, False, True, False])


class TestGatherFrontierEdges:
    def test_empty_frontier(self, rand_g):
        front = dense_to_sparse(jnp.zeros((rand_g.n_nodes,), bool), 8)
        fe = gather_frontier_edges(front.ids,
                                   jnp.asarray(rand_g.row_ptr_out), 16)
        assert int(fe.count) == 0 and not bool(fe.overflowed)
        assert np.all(np.asarray(fe.edge_ids) == -1)

    def test_full_frontier_is_identity(self, rand_g):
        """Every vertex in the frontier at capacity E gathers exactly
        the CSR edge order, arange(E)."""
        front = dense_to_sparse(jnp.ones((rand_g.n_nodes,), bool),
                                rand_g.n_nodes)
        fe = gather_frontier_edges(front.ids,
                                   jnp.asarray(rand_g.row_ptr_out),
                                   rand_g.n_edges)
        assert int(fe.count) == rand_g.n_edges and not bool(fe.overflowed)
        np.testing.assert_array_equal(np.asarray(fe.edge_ids),
                                      np.arange(rand_g.n_edges))

    def test_capacity_one_overflows_not_drops_silently(self, rand_g):
        rp = np.asarray(rand_g.row_ptr_out)
        v = int(np.argmax(np.diff(rp)))  # a vertex with max out-degree
        ids = jnp.asarray([v], jnp.int32)
        fe = gather_frontier_edges(ids, jnp.asarray(rand_g.row_ptr_out), 1)
        assert int(fe.count) == rp[v + 1] - rp[v]
        assert bool(fe.overflowed) == (int(fe.count) > 1)
        assert int(np.asarray(fe.edge_ids)[0]) == rp[v]

    def test_padding_ids_anywhere_are_skipped(self, rand_g):
        ids = jnp.asarray([-1, 3, -1, 7, -1, -1], jnp.int32)
        fe = gather_frontier_edges(ids, jnp.asarray(rand_g.row_ptr_out),
                                   rand_g.n_edges)
        ref = _gather_ref([3, 7], np.asarray(rand_g.row_ptr_out))
        assert int(fe.count) == ref.size
        got = np.asarray(fe.edge_ids)
        np.testing.assert_array_equal(got[got >= 0], ref)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 64))
    @settings(max_examples=15, deadline=None)
    def test_gather_matches_numpy_reference(self, seed, capacity):
        rng = np.random.default_rng(seed)
        g = random_graph(48, 300, seed=seed % 7, weighted=False,
                         block_size=16)
        mask = rng.random(g.n_nodes) < 0.15
        front = dense_to_sparse(jnp.asarray(mask), g.n_nodes)
        fe = gather_frontier_edges(front.ids,
                                   jnp.asarray(g.row_ptr_out), capacity)
        ref = _gather_ref(np.flatnonzero(mask), np.asarray(g.row_ptr_out))
        assert int(fe.count) == ref.size
        assert bool(fe.overflowed) == (ref.size > capacity)
        got = np.asarray(fe.edge_ids)
        np.testing.assert_array_equal(got[got >= 0], ref[:capacity])
        assert np.all(got[min(ref.size, capacity):] == -1)


class TestGatheredSegmentReduce:
    @given(st.integers(0, 2**31 - 1), st.sampled_from(["sum", "min", "max"]))
    @settings(max_examples=15, deadline=None)
    def test_matches_reference(self, seed, kind):
        rng = np.random.default_rng(seed)
        n, segs = 64, 9
        ids = rng.integers(-1, segs, n).astype(np.int32)
        vals = rng.normal(size=n).astype(np.float32)
        got = gathered_segment_reduce(jnp.asarray(vals), jnp.asarray(ids),
                                      segs, kind)
        ref = gathered_segment_reduce_ref(vals, ids, segs, kind)
        np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-6)

    def test_int_min_identity_matches_dense_convention(self):
        """Empty segments hold iinfo.max — the same value the dense
        masked path substitutes, so sparse/dense stay bit-identical."""
        out = gathered_segment_reduce(
            jnp.asarray([5], jnp.int32), jnp.asarray([-1], jnp.int32),
            3, "min")
        assert np.asarray(out).tolist() == [np.iinfo(np.int32).max] * 3


class TestSparsePathSystem:
    def test_reduces_only_gathered_edges(self, sf_g, monkeypatch):
        """The sparse branch's reducer sees [cap_e] values, never [E]:
        a sparse iteration costs O(cap_e) gathered work by construction."""
        import repro.core.executor as ex
        shapes = []
        orig = ex.gathered_segment_reduce

        def spy(values, segment_ids, num_segments, kind, **kwargs):
            shapes.append(values.shape)
            return orig(values, segment_ids, num_segments, kind, **kwargs)

        monkeypatch.setattr(ex, "gathered_segment_reduce", spy)
        r = run(bfs(), sf_g, SystemConfig.from_name("DG1"))
        np.testing.assert_array_equal(np.asarray(r.state["depth"]),
                                      bfs_np(sf_g))
        cap = EdgeContext(sf_g, SystemConfig.from_name("DG1")) \
            .sparse_edge_capacity
        assert shapes and all(s == (cap,) for s in shapes)
        assert cap < sf_g.n_edges  # strictly less work than a dense scan

    def test_occupancy_trace_marks_sparse_push_iterations(self, sf_g):
        r = run(bfs(), sf_g, SystemConfig.from_name("DD1"))
        assert r.occupancy_trace is not None
        assert len(r.occupancy_trace) == r.iterations
        cap = EdgeContext(sf_g, SystemConfig.from_name("DD1")) \
            .sparse_edge_capacity
        # iteration 0 pushes the source's own out-edges
        deg0 = int(np.asarray(sf_g.out_degree)[0])
        assert r.occupancy_trace[0] == pytest.approx(deg0 / cap)
        assert r.sparse_iterations >= 1
        # pull iterations are inherently dense
        for letter, occ in zip(r.direction_trace, r.occupancy_trace):
            if letter == "T":
                assert occ == -1.0
            else:
                assert occ == -1.0 or 0.0 <= occ <= 1.0

    @pytest.mark.parametrize("cfg", CONFIG_NAMES)
    def test_bit_identical_to_dense_path_all_configs(self, rand_g, cfg):
        """sparse_edge_capacity=0 disables the gather entirely; BFS
        depths (int MIN monoid — exact arithmetic) must agree
        bit-for-bit with the sparse-enabled run in every cell of the
        design space.  Float-SUM phases (BC backward) are only
        ULP-close, not bit-identical, because the gathered order sums
        edges differently than the chunked schedule."""
        sparse = run(bfs(), rand_g, SystemConfig.from_name(cfg))
        dense = run(bfs(), rand_g, SystemConfig.from_name(cfg),
                    sparse_edge_capacity=0)
        assert dense.occupancy_trace is None or \
            all(o == -1.0 for o in dense.occupancy_trace)
        np.testing.assert_array_equal(np.asarray(sparse.state["depth"]),
                                      np.asarray(dense.state["depth"]))
        np.testing.assert_array_equal(np.asarray(sparse.state["depth"]),
                                      bfs_np(rand_g))

    def test_capacity_overflow_falls_back_to_dense(self, sf_g):
        """A 1-edge capacity can't hold any real frontier: every
        iteration must fall back to the dense path and still converge to
        the oracle (nothing silently dropped)."""
        r = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                sparse_edge_capacity=1)
        np.testing.assert_array_equal(np.asarray(r.state["depth"]),
                                      bfs_np(sf_g))
        assert all(o == -1.0 or o <= 1.0 for o in r.occupancy_trace)

    def test_sssp_sparse_matches_oracle(self, sf_g):
        r = run(sssp(), sf_g, SystemConfig.from_name("DGR"))
        assert r.sparse_iterations >= 1
        got = np.asarray(r.state["dist"])
        ref = sssp_np(sf_g)
        mask = np.isfinite(ref)
        np.testing.assert_allclose(got[mask], ref[mask], atol=1e-4)
        assert np.array_equal(np.isfinite(got), mask)

    def test_static_configs_never_gather(self, sf_g):
        for cfg in ("SG1", "TG0"):
            r = run(bfs(), sf_g, SystemConfig.from_name(cfg))
            assert all(o == -1.0 for o in r.occupancy_trace)

    def test_non_gatherable_phase_stays_dense(self, rand_g):
        """A frontier mask that only steers the direction heuristic
        (gatherable left False: every source contributes) must never
        take the gathered path — it would drop non-frontier sources."""
        from repro.core import MIN, EdgePhase
        ctx = EdgeContext(rand_g, SystemConfig.from_name("DG1"))
        state = {"x": jnp.arange(rand_g.n_nodes, dtype=jnp.int32),
                 "f": jnp.zeros((rand_g.n_nodes,), bool).at[0].set(True)}
        phase = EdgePhase(monoid=MIN, vprop=lambda st, s, w: st["x"][s],
                          frontier=lambda st: st["f"])
        out, occ = ctx.propagate_sparse(state, phase, jnp.asarray(False),
                                        dtype=jnp.int32)
        assert float(occ) == -1.0
        ref = ctx.propagate_dynamic(state, phase, jnp.asarray(False),
                                    dtype=jnp.int32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
