"""Batched multi-graph serving executor: packing is invisible.

Covers the ISSUE-5 contract: (1) block-diagonal pack/unpack round-trips
— per-graph slices of the packed edge orders equal the originals and
padding edges are self-loops confined to padding vertices; (2)
``run_batch`` results are **bit-identical** to per-graph sequential
``run()`` (states, iteration counts, convergence flags, direction and
occupancy traces) across the full addressable config matrix for BFS and
SSSP; (3) ragged-batch padding invariance — adding graphs to a batch
never changes another graph's results; (4) bucket keys are stable under
within-quantum size perturbations; (5) the plan cache amortizes repeat
batches and the whole batch costs one timed dispatch.

Plus the ISSUE-7 property battery (``TestHostPacking``,
``TestInterleavingProperties``): the host-side pack/unpack the gateway
repacks with between slices is bit-equal to the device path, and
**arbitrary** seeded arrival/retirement interleavings through the
continuous scheduler preserve unbatch-equals-sequential, lane/bucket
stability, and plan-cache warmth.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.algorithms import REGISTRY
from repro.core import (ALL_CONFIGS, PLAN_CACHE, SystemConfig, run,
                        run_batch)
from repro.core.batch import (BatchedEdgeContext, GraphBatch, bucket_key,
                              bucket_shape, get_graph_batch, pack_graphs)
from repro.core.executor import STATS
from repro.graph import (random_graph, regular_graph, rmat_batch,
                         rmat_graph)

CONFIG_NAMES = [c.name for c in ALL_CONFIGS]


def _results_identical(s, b):
    assert b.engine == "batched"
    assert s.iterations == b.iterations
    assert s.converged == b.converged
    assert s.direction_trace == b.direction_trace
    assert s.occupancy_trace == b.occupancy_trace
    assert set(s.state) == set(b.state)
    for k in s.state:
        assert bool(jnp.array_equal(s.state[k], b.state[k])), k


@pytest.fixture(scope="module")
def mixed_graphs():
    """Two small graphs of different (n, m) in the SAME padding bucket,
    so they genuinely pack into one B=2 block-diagonal batch (a
    different-bucket pair would silently degrade every test here to
    B=1 singletons)."""
    from repro.graph import grid_graph
    graphs = [rmat_graph(5, 8, seed=1, weighted=True),
              grid_graph(7, seed=0, weighted=True)]
    assert (graphs[0].n_nodes, graphs[0].n_edges) \
        != (graphs[1].n_nodes, graphs[1].n_edges)      # ragged...
    assert bucket_key(graphs[0]) == bucket_key(graphs[1])  # ...one batch
    return graphs


class TestBucketShape:
    @given(st.integers(1, 1 << 20), st.integers(1, 1 << 22))
    @settings(max_examples=50, deadline=None)
    def test_shape_properties(self, n, m):
        n_q, m_q = bucket_shape(n, m)
        # quantized shapes cover the graph and are powers of two
        assert n_q >= n and m_q >= m
        assert n_q & (n_q - 1) == 0 and m_q & (m_q - 1) == 0
        assert n_q <= max(2 * n, 16) and m_q <= max(2 * m, 16)
        # edge padding always has a padding vertex to live on
        if m_q > m:
            assert n_q > n

    @given(st.integers(4, 1 << 12), st.integers(4, 1 << 14))
    @settings(max_examples=50, deadline=None)
    def test_key_stability_within_quantum(self, n, m):
        """Perturbing (n, m) without crossing a power-of-two boundary
        keeps the bucket key — sizes in one quantum batch together."""
        n_q, m_q = bucket_shape(n, m)
        n2 = max(n_q // 2 + 1, min(n_q - 1, n + 1))
        m2 = max(m_q // 2 + 1, min(m_q - 1, m + 1))
        if bucket_shape(n2, 1)[0] == n_q and bucket_shape(1, m2)[1] == m_q:
            assert bucket_shape(n2, m2) == (n_q, m_q)
        # crossing the boundary changes it
        assert bucket_shape(n_q + 1, m)[0] == 2 * n_q

    def test_key_deterministic_across_instances(self):
        a = regular_graph(100, 4, seed=1)
        b = regular_graph(100, 4, seed=2)  # same shape, different edges
        assert bucket_key(a) == bucket_key(b)
        assert bucket_key(a) != bucket_key(
            regular_graph(1000, 4, seed=1))


class TestPackRoundtrip:
    @given(st.integers(0, 500))
    @settings(max_examples=5, deadline=None)
    def test_edge_orders_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        graphs = [random_graph(int(rng.integers(20, 90)),
                               int(rng.integers(60, 400)),
                               seed=seed + i, weighted=True,
                               block_size=32)
                  for i in range(3)]
        batch = pack_graphs(graphs)
        p = batch.packed
        assert p.n_nodes == batch.size * batch.n_q
        assert p.n_edges == batch.size * batch.m_q
        for i, g in enumerate(graphs):
            vo, eo = i * batch.n_q, i * batch.m_q
            n, m = g.n_nodes, g.n_edges
            # the real edge rows are the original orders, offset
            np.testing.assert_array_equal(
                np.asarray(p.src[eo:eo + m]) - vo, np.asarray(g.src))
            np.testing.assert_array_equal(
                np.asarray(p.dst[eo:eo + m]) - vo, np.asarray(g.dst))
            np.testing.assert_array_equal(
                np.asarray(p.weight[eo:eo + m]), np.asarray(g.weight))
            np.testing.assert_array_equal(
                np.asarray(p.dst_in[eo:eo + m]) - vo,
                np.asarray(g.dst_in))
            np.testing.assert_array_equal(
                np.asarray(p.row_ptr_out[vo:vo + n + 1]) - eo,
                np.asarray(g.row_ptr_out))
            np.testing.assert_array_equal(
                np.asarray(p.row_ptr_in[vo:vo + n + 1]) - eo,
                np.asarray(g.row_ptr_in))
            np.testing.assert_array_equal(
                np.asarray(p.out_degree[vo:vo + n]),
                np.asarray(g.out_degree))
            # padding edges are self-loops on padding vertices only
            pad_src = np.asarray(p.src[eo + m:eo + batch.m_q])
            pad_dst = np.asarray(p.dst[eo + m:eo + batch.m_q])
            np.testing.assert_array_equal(pad_src, pad_dst)
            assert (pad_src >= vo + n).all()
            assert (pad_src < vo + batch.n_q).all()
        # block-diagonal: every edge stays inside its graph's range
        blk_of = np.asarray(p.src) // batch.n_q
        assert (blk_of == np.asarray(p.dst) // batch.n_q).all()

    def test_state_roundtrip(self, mixed_graphs):
        batch = pack_graphs(mixed_graphs)
        rng = np.random.default_rng(0)
        states = [{"x": jnp.asarray(rng.standard_normal(g.n_nodes)
                                    .astype(np.float32)),
                   "flag": jnp.asarray(bool(i % 2)),
                   "m": jnp.asarray(rng.integers(
                       0, 9, (g.n_nodes, 3)).astype(np.int32))}
                  for i, g in enumerate(mixed_graphs)]
        packed = batch.pack_state(states)
        assert packed["x"].shape == (batch.n_total,)
        assert packed["flag"].shape == (batch.size,)
        assert packed["m"].shape == (batch.n_total, 3)
        for orig, got in zip(states, batch.unpack_state(packed)):
            for k in orig:
                assert bool(jnp.array_equal(orig[k], got[k])), k

    def test_pack_rejects_mixed_block_size(self):
        with pytest.raises(ValueError, match="block_size"):
            pack_graphs([regular_graph(50, 4, seed=0, block_size=32),
                         regular_graph(50, 4, seed=1, block_size=64)])

    def test_pack_rejects_bad_state_shape(self, mixed_graphs):
        batch = pack_graphs(mixed_graphs)
        bad = [{"x": jnp.zeros((7,))} for _ in mixed_graphs]
        with pytest.raises(ValueError, match="per-vertex"):
            batch.pack_state(bad)


class TestBitIdenticalToSequential:
    """The acceptance core: run_batch == per-graph run(), bit for bit,
    across every addressable config, for BFS and SSSP."""

    @pytest.fixture(scope="class")
    def apps(self, mixed_graphs):
        out = {}
        for name in ("BFS", "SSSP"):
            prog = REGISTRY[name]()
            out[name] = (prog, mixed_graphs)
        return out

    @pytest.mark.parametrize("cfg", CONFIG_NAMES)
    @pytest.mark.parametrize("app", ["BFS", "SSSP"])
    def test_matrix(self, apps, app, cfg):
        prog, graphs = apps[app]
        config = SystemConfig.from_name(cfg)
        seq = [run(prog, g, config) for g in graphs]
        bat = run_batch(prog, graphs, config)
        for s, b in zip(seq, bat):
            _results_identical(s, b)

    def test_iteration_counts_differ_per_graph(self):
        """Per-graph convergence masking: a long-diameter graph and a
        short one in the same batch keep their own iteration counts."""
        from repro.graph import grid_graph
        prog = REGISTRY["BFS"]()
        graphs = [grid_graph(7, seed=0), rmat_graph(5, 8, seed=3)]
        assert bucket_key(graphs[0]) == bucket_key(graphs[1])  # one batch
        config = SystemConfig.from_name("DG0")
        bat = run_batch(prog, graphs, config)
        seq = [run(prog, g, config) for g in graphs]
        assert [r.iterations for r in bat] == \
            [r.iterations for r in seq]
        assert bat[0].iterations != bat[1].iterations
        for s, b in zip(seq, bat):
            _results_identical(s, b)


class TestRaggedPaddingInvariance:
    """Adding a (padded) graph to a batch never changes another
    graph's results — block-diagonal packing keeps graphs disjoint."""

    @pytest.mark.parametrize("cfg", ["DG1", "SG0"])
    def test_batch_composition_invariance(self, cfg):
        from repro.graph import grid_graph
        prog = REGISTRY["BFS"]()
        g1 = rmat_graph(5, 8, seed=11)
        g2 = grid_graph(7, seed=12)          # same bucket: duo packs B=2
        g3 = regular_graph(40, 5, seed=13)   # different bucket
        assert bucket_key(g1) == bucket_key(g2)
        assert bucket_key(g1) != bucket_key(g3)
        config = SystemConfig.from_name(cfg)
        solo = run_batch(prog, [g1], config)[0]
        duo = run_batch(prog, [g1, g2], config)[0]
        trio = run_batch(prog, [g1, g3, g2], config)[0]
        _results_identical(solo, duo)
        _results_identical(solo, trio)

    def test_multi_bucket_and_max_batch(self):
        """Graphs spanning buckets (and max_batch splits) still return
        sequential-identical results in input order."""
        prog = REGISTRY["BFS"]()
        graphs = [rmat_graph(5, 8, seed=21),
                  rmat_graph(8, 8, seed=22),   # far bigger: own bucket
                  rmat_graph(5, 8, seed=23),
                  rmat_graph(5, 8, seed=24)]
        config = SystemConfig.from_name("DGR")
        bat = run_batch(prog, graphs, config, max_batch=2)
        for g, b in zip(graphs, bat):
            _results_identical(run(prog, g, config), b)


class TestServingAmortization:
    def test_one_dispatch_per_batch(self):
        prog = REGISTRY["BFS"]()
        graphs = rmat_batch(4, 5, seed=31)
        config = SystemConfig.from_name("DG1")
        run_batch(prog, graphs, config)  # warm compile + caches
        STATS.reset()
        rs = run_batch(prog, graphs, config)
        assert STATS.dispatches == 1           # whole batch, one dispatch
        assert all(r.dispatches == 1 for r in rs)
        assert all(r.engine == "batched" for r in rs)

    def test_repeat_traffic_hits_plan_cache(self):
        prog = REGISTRY["BFS"]()
        graphs = rmat_batch(3, 5, seed=41)
        config = SystemConfig.from_name("DG0")
        run_batch(prog, graphs, config)
        before = PLAN_CACHE.stats()["by_kind"]
        b_pack = dict(before.get("batch_pack", {}))
        b_ctx = dict(before.get("batch_context", {}))
        run_batch(prog, graphs, config)
        after = PLAN_CACHE.stats()["by_kind"]
        assert after["batch_pack"]["hits"] == b_pack.get("hits", 0) + 1
        assert after["batch_pack"]["misses"] == b_pack.get("misses", 0)
        assert after["batch_context"]["hits"] == b_ctx.get("hits", 0) + 1

    def test_batch_reuses_pack_for_same_tuple_only(self):
        graphs = rmat_batch(2, 5, seed=51)
        b1 = get_graph_batch(tuple(graphs))
        assert get_graph_batch(tuple(graphs)) is b1
        assert get_graph_batch(tuple(reversed(graphs))) is not b1

    def test_sparse_capacity_zero_disables_batchwide(self):
        prog = REGISTRY["BFS"]()
        graphs = rmat_batch(2, 5, seed=61)
        config = SystemConfig.from_name("DG1")
        seq = [run(prog, g, config, sparse_edge_capacity=0)
               for g in graphs]
        bat = run_batch(prog, graphs, config, sparse_edge_capacity=0)
        for s, b in zip(seq, bat):
            _results_identical(s, b)
            assert all(o == -1.0 for o in b.occupancy_trace)


def _serve_graphs():
    """The mixed-bucket pair as a plain cached helper: the @given
    property tests below cannot take pytest fixtures (the hypothesis
    fallback shim hides the test signature from pytest)."""
    global _SERVE_GRAPHS
    try:
        return _SERVE_GRAPHS
    except NameError:
        from repro.graph import grid_graph
        _SERVE_GRAPHS = [rmat_graph(5, 8, seed=1, weighted=True),
                         grid_graph(7, seed=0, weighted=True)]
        assert bucket_key(_SERVE_GRAPHS[0]) == bucket_key(_SERVE_GRAPHS[1])
        return _SERVE_GRAPHS


def _serve_seq():
    global _SERVE_SEQ
    try:
        return _SERVE_SEQ
    except NameError:
        prog = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        _SERVE_SEQ = (prog, config,
                      {id(g): run(prog, g, config)
                       for g in _serve_graphs()})
        return _SERVE_SEQ


class TestHostPacking:
    """The numpy pack/unpack pair the gateway repacks with between
    slices must be bit-equal to the jnp pair — otherwise every slice
    boundary would perturb results."""

    @given(st.integers(0, 1000))
    @settings(max_examples=5, deadline=None)
    def test_host_pack_matches_device_pack(self, seed):
        mixed_graphs = _serve_graphs()
        batch = pack_graphs(mixed_graphs)
        rng = np.random.default_rng(seed)
        states = [{"x": rng.standard_normal(g.n_nodes).astype(np.float32),
                   "it": np.int32(rng.integers(0, 99)),
                   "m": rng.integers(-5, 5, (g.n_nodes, 2)).astype(
                       np.int32)}
                  for g in mixed_graphs]
        host = batch.pack_state_host(states, pad={"x": 1.5})
        dev = batch.pack_state(
            [{k: jnp.asarray(v) for k, v in s.items()} for s in states],
            pad={"x": 1.5})
        for k in host:
            assert np.array_equal(np.asarray(host[k]),
                                  np.asarray(dev[k])), k
        for h, d in zip(batch.unpack_state_host(host),
                        batch.unpack_state(dev)):
            for k in h:
                assert np.array_equal(np.asarray(h[k]),
                                      np.asarray(d[k])), k

    def test_host_roundtrip_is_identity(self, mixed_graphs):
        batch = pack_graphs(mixed_graphs)
        rng = np.random.default_rng(0)
        states = [{"x": rng.standard_normal(g.n_nodes).astype(np.float32)}
                  for g in mixed_graphs]
        out = batch.unpack_state_host(batch.pack_state_host(states))
        for orig, got in zip(states, out):
            assert np.array_equal(orig["x"], got["x"])


class TestInterleavingProperties:
    """Gateway property battery: random arrival/cancellation
    interleavings through the continuous scheduler never change what a
    request computes, which lane it lands on, or cache warmth."""

    @given(st.integers(0, 10_000))
    @settings(max_examples=8, deadline=None)
    def test_arbitrary_interleavings_match_sequential(self, seed):
        from repro.launch.serve import CancelledError, ContinuousScheduler
        prog, config, seq = _serve_seq()
        graphs = _serve_graphs()
        rng = np.random.default_rng(seed)
        sched = ContinuousScheduler(
            max_batch=int(rng.integers(1, 4)),
            slice_len=int(rng.integers(1, 5)))
        n_req = int(rng.integers(3, 9))
        plan = [(int(rng.integers(0, 5)),            # arrival round
                 graphs[int(rng.integers(0, len(graphs)))],
                 bool(rng.random() < 0.2))           # cancel it?
                for _ in range(n_req)]
        tickets = []
        for rnd in range(5):
            for due, g, cancel in plan:
                if due == rnd:
                    t = sched.submit(prog, g, config)
                    tickets.append((g, cancel, t))
                    if cancel:
                        t.cancel()
            sched.poll()
        sched.run_until_idle()
        for g, cancel, t in tickets:
            if cancel:
                with pytest.raises(CancelledError):
                    t.result(timeout=1)
            else:
                res, s = t.result(timeout=1), seq[id(g)]
                assert res.iterations == s.iterations
                assert res.converged and not res.timed_out
                assert res.direction_trace == s.direction_trace
                for k in s.state:
                    assert bool(jnp.array_equal(res.state[k],
                                                s.state[k])), k
        # bucket/lane stability: same-bucket graphs shared one lane
        assert len(sched._lanes) == 1
        assert len({bucket_key(g) for g in graphs}) == 1

    def test_steady_roster_never_touches_pack_cache(self):
        """Repeat waves over an unchanged roster are fully warm: no
        batch rebuilds, so not even a cache *lookup* — the lane reuses
        its bound batch/context outright."""
        from repro.launch.serve import ContinuousScheduler
        prog, config, _ = _serve_seq()
        graphs = _serve_graphs()
        sched = ContinuousScheduler(max_batch=len(graphs), slice_len=2)
        for g in graphs:                       # wave 0: roster grows
            sched.submit(prog, g, config)
        sched.run_until_idle()
        sched.reset_stats()
        pack0 = PLAN_CACHE.kind_stats("batch_pack")
        for _ in range(3):                     # repeat waves
            for g in graphs:
                sched.submit(prog, g, config)
            sched.run_until_idle()
        assert sched.stats.roster_rebuilds == 0
        assert PLAN_CACHE.kind_stats("batch_pack") == pack0

    def test_repack_events_hit_plan_cache(self):
        """When roster membership *does* churn (max_batch=1 forces an
        alternating pair to swap the slot), every rebuild after the
        first cycle is a pure batch_pack/batch_context cache hit —
        per-kind hit counters from PLAN_CACHE prove the repack stayed
        plan-cache-warm."""
        from repro.launch.serve import ContinuousScheduler
        prog, config, _ = _serve_seq()
        g1, g2 = _serve_graphs()
        sched = ContinuousScheduler(max_batch=1, slice_len=2)
        for g in (g1, g2):                     # first cycle may miss
            sched.submit(prog, g, config)
            sched.run_until_idle()
        sched.reset_stats()
        pack0 = PLAN_CACHE.kind_stats("batch_pack")
        ctx0 = PLAN_CACHE.kind_stats("batch_context")
        cycles = 3
        for _ in range(cycles):                # every swap is a rebuild
            for g in (g1, g2):
                sched.submit(prog, g, config)
                sched.run_until_idle()
        assert sched.stats.roster_rebuilds == 2 * cycles
        pack1 = PLAN_CACHE.kind_stats("batch_pack")
        ctx1 = PLAN_CACHE.kind_stats("batch_context")
        assert pack1["misses"] == pack0["misses"]
        assert ctx1["misses"] == ctx0["misses"]
        assert pack1["hits"] == pack0["hits"] + 2 * cycles
        assert ctx1["hits"] == ctx0["hits"] + 2 * cycles
