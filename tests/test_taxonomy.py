"""Eqs. 1-7 + Table II faithfulness."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.taxonomy import (PAPER_GPU, classify_volume_kb, imbalance,
                                 profile_graph, reuse, reuse_from_an,
                                 volume_kb)
from repro.graph import powerlaw_graph, regular_graph
from repro.graph.datasets import PAPER_AN, PAPER_STATS, paper_graph


class TestTableII:
    """Published |V|,|E|,AN_L,AN_R,imbalance -> published classes."""

    @pytest.mark.parametrize("name", sorted(PAPER_STATS))
    def test_volume_value_and_class(self, name):
        v, e, *_ = PAPER_STATS[name]
        kb = volume_kb(v, e, PAPER_GPU)
        assert kb == pytest.approx(PAPER_STATS[name][4], rel=5e-3)
        assert classify_volume_kb(kb, PAPER_GPU) == PAPER_STATS[name][7]

    @pytest.mark.parametrize("name", sorted(PAPER_STATS))
    def test_reuse_class(self, name):
        an_l, an_r = PAPER_AN[name]
        avg = PAPER_STATS[name][3]
        r = reuse_from_an(an_l, an_r, avg)
        from repro.core.taxonomy import classify_reuse
        assert classify_reuse(r, PAPER_GPU) == PAPER_STATS[name][8]

    @pytest.mark.parametrize("name", sorted(PAPER_STATS))
    def test_imbalance_class(self, name):
        from repro.core.taxonomy import classify_imbalance
        assert classify_imbalance(PAPER_STATS[name][6],
                                  PAPER_GPU) == PAPER_STATS[name][9]


class TestSyntheticRecreations:
    """The generated stand-ins reproduce the paper's reuse/imbalance
    classes when measured with our own Eq. 2-7 implementation."""

    @pytest.mark.parametrize("name", sorted(PAPER_STATS))
    def test_classes_match(self, name):
        g = paper_graph(name, scale=16)
        p = profile_graph(g, PAPER_GPU)
        assert p.reuse_class == PAPER_STATS[name][8]
        assert p.imbalance_class == PAPER_STATS[name][9]


class TestMetricProperties:
    @given(st.integers(100, 2000), st.floats(0.0, 1.0), st.integers(0, 5))
    @settings(max_examples=10, deadline=None)
    def test_reuse_bounded(self, n, locality, seed):
        g = regular_graph(n, 4, locality=locality, seed=seed, block_size=64)
        r = reuse(g, PAPER_GPU)
        assert 0.0 <= r <= 1.0

    def test_reuse_monotone_in_locality(self):
        rs = [reuse(regular_graph(2000, 8, locality=l, seed=7,
                                  block_size=256), PAPER_GPU)
              for l in (0.0, 0.5, 0.95)]
        assert rs[0] < rs[1] < rs[2]

    def test_imbalance_zero_for_regular(self):
        g = regular_graph(2048, 4, seed=0, block_size=256)
        assert imbalance(g, PAPER_GPU) < 0.05

    def test_imbalance_high_for_powerlaw(self):
        g = powerlaw_graph(4096, 40000, alpha=1.6, seed=0,
                           max_degree=2000, block_size=256)
        assert imbalance(g, PAPER_GPU) > 0.25
