"""Graph substrate: structure invariants, generators, partitioner, sampler,
and the executor design-space equivalence property."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import ALL_CONFIGS, STATIC_CONFIGS, SystemConfig, run
from repro.graph import (Graph, graph_stats, powerlaw_graph, random_graph,
                         regular_graph)
from repro.graph.partition import partition_edges_1d, partition_vertices
from repro.graph.sampler import NeighborSampler


class TestStructure:
    def test_orderings_same_edge_set(self, small_graph):
        g = small_graph
        a = set(zip(np.asarray(g.src).tolist(), np.asarray(g.dst).tolist()))
        b = set(zip(np.asarray(g.src_in).tolist(),
                    np.asarray(g.dst_in).tolist()))
        assert a == b and len(a) == g.n_edges

    def test_row_ptrs(self, small_graph):
        g = small_graph
        assert g.row_ptr_out[-1] == g.n_edges
        assert g.row_ptr_in[-1] == g.n_edges
        np.testing.assert_array_equal(
            np.diff(np.asarray(g.row_ptr_out)), np.asarray(g.out_degree))

    def test_owned_order_binned(self, small_graph):
        g = small_graph
        d = np.asarray(g.dst)[np.asarray(g.perm_owned)]
        blocks = d // g.block_size
        assert np.all(np.diff(blocks) >= 0)          # block-sorted
        bp = np.asarray(g.block_ptr)
        assert bp[-1] == g.n_edges

    def test_no_self_loops_no_dupes(self, small_graph):
        g = small_graph
        s, d = np.asarray(g.src), np.asarray(g.dst)
        assert not np.any(s == d)
        assert len(set(zip(s.tolist(), d.tolist()))) == g.n_edges

    def test_symmetric(self, small_graph):
        g = small_graph
        pairs = set(zip(np.asarray(g.src).tolist(),
                        np.asarray(g.dst).tolist()))
        assert all((b, a) in pairs for a, b in pairs)


class TestPartition:
    def test_edges_1d_covers_all(self, small_graph):
        g = small_graph
        part = partition_edges_1d(g, 8)
        real = part.dst < g.n_nodes
        assert real.sum() == g.n_edges
        pairs = set(zip(part.src[real].tolist(), part.dst[real].tolist()))
        orig = set(zip(np.asarray(g.src).tolist(),
                       np.asarray(g.dst).tolist()))
        assert pairs == orig

    def test_vertex_partition_owner(self, small_graph):
        g = small_graph
        part = partition_vertices(g, 4)
        per = part.vertex_offsets
        for d in range(4):
            real = part.dst[d] < g.n_nodes
            t = part.dst[d][real]
            assert np.all((t >= per[d]) & (t < per[d + 1]) | (t >= per[-1]))


class TestSampler:
    def test_sampled_edges_exist(self, small_graph):
        g = small_graph
        s = NeighborSampler(g, fanouts=(4, 3), seed=0)
        seeds = np.arange(16)
        blocks = s.sample(seeds)
        assert len(blocks) == 2
        edges = set(zip(np.asarray(g.src_in).tolist(),
                        np.asarray(g.dst_in).tolist()))
        blk = blocks[0]
        for src, dl, ok in zip(blk.src_global, blk.dst_local,
                               blk.edge_mask):
            if ok:
                assert (int(src), int(blk.seeds[dl])) in edges

    def test_fanout_shapes(self, small_graph):
        s = NeighborSampler(small_graph, fanouts=(5,), seed=1)
        blk = s.sample_hop(np.arange(10), 5)
        assert blk.src_global.shape == (50,)
        assert blk.dst_local.shape == (50,)


class TestExecutorEquivalence:
    """Paper invariant made executable: the 12 configs are semantically
    identical — only performance differs (hypothesis property)."""

    @given(st.integers(0, 10000))
    @settings(max_examples=5, deadline=None)
    def test_pagerank_config_equivalence(self, seed):
        from repro.algorithms import pagerank
        g = random_graph(100, 600, seed=seed, block_size=32)
        ref = None
        for cfg in STATIC_CONFIGS[::3]:
            out = np.asarray(
                run(pagerank(), g, cfg, max_iters=10).state["rank"])
            if ref is None:
                ref = out
            else:
                np.testing.assert_allclose(out, ref, atol=1e-5)

    @given(st.integers(2, 64), st.integers(0, 100))
    @settings(max_examples=5, deadline=None)
    def test_chunking_invariance(self, n_chunks, seed):
        """DRFrlx partial-reduction reordering never changes the result —
        the commutative-monoid legality argument (DESIGN.md §2)."""
        from repro.algorithms import sssp
        g = random_graph(80, 500, seed=seed, weighted=True, block_size=32)
        base = np.asarray(run(
            sssp(), g, SystemConfig.from_name("SG0")).state["dist"])
        chunked = np.asarray(run(
            sssp(), g, SystemConfig.from_name("SGR", n_chunks=n_chunks))
            .state["dist"])
        mask = np.isfinite(base)
        np.testing.assert_allclose(chunked[mask], base[mask], atol=1e-4)


def test_graph_stats(small_graph):
    st_ = graph_stats(small_graph)
    assert st_.n_nodes == small_graph.n_nodes
    assert st_.avg_degree == pytest.approx(
        small_graph.n_edges / small_graph.n_nodes)
