"""Distributed-path tests: run in a subprocess with 8 host devices so the
main test session keeps its single real device (dryrun.py contract)."""
import importlib.util
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

#: the seed shipped these tests ahead of the repro.dist module itself;
#: skip (don't fail) until a PR lands the collectives/pipeline layer.
_HAVE_DIST = importlib.util.find_spec("repro.dist") is not None
_needs_dist = pytest.mark.skipif(
    not _HAVE_DIST, reason="repro.dist not implemented yet")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@_needs_dist
def test_distributed_pagerank_llc_vs_owned():
    """Both cluster-scale coherence schedules match the numpy oracle."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.graph import powerlaw_graph
        from repro.graph.partition import partition_edges_1d
        from repro.core.config_space import SystemConfig
        from repro.dist.collectives import make_distributed_pagerank_step
        from repro.algorithms.reference import pagerank_np

        g = powerlaw_graph(512, 3000, alpha=1.0, seed=3, block_size=64)
        part = partition_edges_1d(g, 8)
        mesh = jax.make_mesh((8,), ("data",))
        ref = pagerank_np(g)
        for cname in ("SGR", "SD1"):
            cfg = SystemConfig.from_name(cname)
            step = make_distributed_pagerank_step(mesh, cfg, g.n_nodes)
            rank = jnp.full((g.n_nodes,), 1.0 / g.n_nodes)
            inv = (1.0 / np.maximum(np.asarray(g.out_degree), 1)).astype(
                np.float32)
            # note: dangling handled outside for this test graph (none)
            with mesh:
                step = jax.jit(step)
                for _ in range(60):
                    rank = step(rank, jnp.asarray(inv),
                                jnp.asarray(part.src), jnp.asarray(part.dst))
            got = np.asarray(rank)
            err = np.abs(got - ref).max()
            assert err < 1e-3, (cname, err)
            print("ok", cname, err)
    """)
    assert out.count("ok") == 2


@_needs_dist
def test_pipeline_parallel_identity():
    """4-stage pipeline of per-stage affine fns == sequential composition."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.dist.pp import pipeline_apply

        mesh = jax.make_mesh((4, 2), ("stage", "data"))
        n_stages, m, mb, d = 4, 6, 8, 16
        key = jax.random.key(0)
        w = jax.random.normal(key, (n_stages, d, d)) * 0.3

        def stage_fn(params, x):
            return jnp.tanh(x @ params["w"])

        fn = pipeline_apply(mesh, "stage", stage_fn, n_microbatches=m)
        x = jax.random.normal(jax.random.key(1), (m, mb, d))
        with mesh:
            y = jax.jit(fn)({"w": w}, x)
        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ w[s])
        err = float(jnp.abs(y - ref).max())
        assert err < 1e-5, err
        print("pp ok", err)
    """)
    assert "pp ok" in out


def test_lm_sharded_train_step_runs():
    """Reduced LM train step actually executes SPMD on an 8-device mesh."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs.base import axes_for_mesh
        from repro.configs.registry import get_arch
        from repro.optim.adamw import adamw_init
        from repro.data.synthetic import lm_batch
        import dataclasses

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ax = axes_for_mesh(mesh)
        arch = get_arch("starcoder2-7b", axes=ax)
        cfg = dataclasses.replace(arch.reduced_cfg, dp_axes=("data",),
                                  tp_axis="model", sp_axis=None)
        from repro.models.transformer import init_lm, train_forward
        params = init_lm(jax.random.key(0), cfg)
        opt = adamw_init(params)
        batch = jax.tree.map(jnp.asarray, lm_batch(0, 8, 64, cfg.vocab))
        from repro.optim.adamw import AdamWConfig, adamw_update

        def step(p, o, b):
            loss, g = jax.value_and_grad(
                lambda pp: train_forward(cfg, pp, b))(p)
            np_, no_, gn = adamw_update(g, o, p, AdamWConfig())
            return np_, no_, loss

        with mesh:
            p2, o2, loss = jax.jit(step)(params, opt, batch)
        assert np.isfinite(float(loss))
        print("sharded train ok", float(loss))
    """)
    assert "sharded train ok" in out


def test_dryrun_single_cell_subprocess():
    """The dry-run entry point works end to end for one cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "dlrm-mlperf", "--shape", "serve_p99", "--mesh", "single",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=str(REPO))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(
        (Path("/tmp/dryrun_test") /
         "dlrm-mlperf__serve_p99__single.json").read_text())
    assert res["ok"] and res["n_devices"] == 256
