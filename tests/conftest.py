# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import powerlaw_graph
    return powerlaw_graph(400, 2400, alpha=1.0, seed=3, weighted=True,
                          block_size=64)


@pytest.fixture(scope="session")
def tiny_graph():
    from repro.graph import regular_graph
    return regular_graph(96, 4, locality=0.4, seed=1, weighted=True,
                         block_size=32)
