"""Documentation freshness (ISSUE 10 satellites).

The README's knob tables are generated from ``src/repro/doctables.py``;
this suite pins both directions of freshness — every documented knob
exists in the target callable's signature and every signature knob has
a documented row — plus byte-for-byte README blocks, and runs the
dead-relative-link checker over every markdown file in the repo.
"""
import sys
from pathlib import Path

import pytest

from repro import doctables

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from tools.check_docs_links import broken_links  # noqa: E402


@pytest.fixture(scope="module")
def readme_text():
    return (ROOT / "README.md").read_text()


class TestKnobTables:
    @pytest.mark.parametrize("section", sorted(doctables.SECTIONS))
    def test_documented_knobs_match_signature(self, section):
        """A knob added to the code without a doc row (or a doc row for
        a removed knob) fails here, naming the drift."""
        doc = doctables.doc_knobs(section)
        sig = doctables.signature_knobs(section)
        assert doc == sig, (
            f"knob table {section!r} drifted: undocumented={sorted(sig - doc)} "
            f"stale_rows={sorted(doc - sig)} — edit src/repro/doctables.py "
            "and run `python -m repro.doctables --write`")

    def test_readme_blocks_are_fresh(self, readme_text):
        assert doctables.check_text(readme_text) == []

    def test_stale_block_is_detected(self, readme_text):
        stale = readme_text.replace("| `engine=` |", "| `enigne=` |")
        assert any("out of date" in p for p in doctables.check_text(stale))

    def test_missing_markers_raise_on_inject(self):
        with pytest.raises(ValueError, match="markers"):
            doctables.inject("no markers here\n")

    def test_inject_is_idempotent(self, readme_text):
        assert doctables.inject(readme_text) == readme_text


class TestDocLinks:
    def test_no_dead_relative_links(self):
        bad = broken_links(ROOT)
        assert bad == [], "dead links: " + "; ".join(
            f"{md} -> {target}" for md, target in bad)

    def test_checker_catches_a_planted_dead_link(self, tmp_path):
        (tmp_path / "a.md").write_text(
            "[ok](b.md) and [dead](missing.md) and "
            "[ext](https://example.com) and `[i](j)`\n")
        (tmp_path / "b.md").write_text("see [anchor](a.md#top)\n")
        bad = broken_links(tmp_path)
        assert [(str(md), t) for md, t in bad] == [("a.md", "missing.md")]
