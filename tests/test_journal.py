"""Write-ahead journal: record/replay round trips, torn-line
tolerance, graph persistence, and the replay-idempotence property —
recovering the same journal twice yields the same ticket set, restore
states and stats counters (replay appends nothing).
"""
import numpy as np
import pytest

from repro.algorithms import REGISTRY
from repro.core import SystemConfig
from repro.graph import rmat_batch, rmat_graph
from repro.launch.journal import (JOURNAL_FILE, WriteAheadJournal,
                                  graph_fingerprint)
from repro.launch.serve import ContinuousScheduler
from repro.testing.faults import GatewayKillFault, SimulatedProcessDeath


def _graph(seed=5):
    return rmat_graph(scale=6, edge_factor=8, seed=seed, weighted=True)


def _states_equal(a, b):
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


def _killed_journal(tmp_path, n=4, after_slices=2):
    """A journal left behind by a gateway killed mid-stream."""
    program = REGISTRY["BFS"]()
    config = SystemConfig.from_name("DG1")
    pool = rmat_batch(2, 6, seed=9)
    sched = ContinuousScheduler(
        max_batch=2, slice_len=2, journal_dir=str(tmp_path),
        fault_injector=GatewayKillFault(after_slices=after_slices))
    tickets = [sched.submit(program, pool[i % 2], config)
               for i in range(n)]
    with pytest.raises(SimulatedProcessDeath):
        sched.run_until_idle()
    return tickets


class TestJournalRecords:
    def test_submit_commit_retire_round_trip(self, tmp_path):
        j = WriteAheadJournal(tmp_path)
        g = _graph()
        program = REGISTRY["SSSP"]()
        config = SystemConfig.from_name("TG0")
        jid = j.record_submit(program, g, config, key=None, max_iters=50,
                              deadline_s=2.5, knobs={"use_pallas": False})
        j.record_admit(jid)
        state = {"dist": np.arange(4, dtype=np.float32)}
        j.record_commit(jid, 3, state, 2, "ST", [0.5, 0.25])
        tickets, report = j.replay()
        assert report["torn"] == 0 and report["orphan"] == 0
        rec = tickets[jid]
        assert rec["submit"]["program"] == "SSSP"
        assert rec["submit"]["config"] == "TG0"
        assert rec["submit"]["deadline_s"] == 2.5
        assert rec["admitted"] and rec["retired"] is None
        assert rec["commits"][0]["it"] == 3
        assert rec["commits"][0]["trace"] == "ST"
        cp, faults = j.store_for(jid).load_latest()
        assert faults == [] and cp.it == 3
        assert np.array_equal(cp.state["dist"], state["dist"])
        j.record_retire(jid, "converged")
        assert j.unfinished() == {}
        # a retired ticket's checkpoint store is deleted
        assert not (tmp_path / "tickets" / jid).exists()

    def test_jids_survive_reopen(self, tmp_path):
        j = WriteAheadJournal(tmp_path)
        g = _graph()
        program = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        first = j.record_submit(program, g, config, key=None,
                                max_iters=None, deadline_s=None, knobs={})
        j2 = WriteAheadJournal(tmp_path)
        second = j2.record_submit(program, g, config, key=None,
                                  max_iters=None, deadline_s=None,
                                  knobs={})
        assert first != second  # a reopened journal never reuses ids

    def test_torn_final_line_skipped_not_fatal(self, tmp_path):
        j = WriteAheadJournal(tmp_path)
        g = _graph()
        jid = j.record_submit(REGISTRY["BFS"](), g,
                              SystemConfig.from_name("DG1"), key=None,
                              max_iters=None, deadline_s=None, knobs={})
        with open(tmp_path / JOURNAL_FILE, "a") as f:
            f.write('deadbeef {"type": "retire", "jid"')  # torn write
        tickets, report = j.replay()
        assert report["torn"] == 1
        assert tickets[jid]["retired"] is None  # the torn retire is void

    def test_orphan_records_counted(self, tmp_path):
        j = WriteAheadJournal(tmp_path)
        j.record_admit("jid-99999999")
        _, report = j.replay()
        assert report["orphan"] == 1


class TestGraphPersistence:
    def test_round_trip_bit_identical(self, tmp_path):
        j = WriteAheadJournal(tmp_path)
        g = _graph()
        fp = j.persist_graph(g)
        # a fresh instance has a cold cache: forces the real disk path
        g2 = WriteAheadJournal(tmp_path).load_graph(fp)
        for name in ("src", "dst", "weight", "row_ptr_out", "row_ptr_in",
                     "out_degree", "in_degree", "perm_owned"):
            a, b = np.asarray(getattr(g, name)), np.asarray(
                getattr(g2, name))
            assert a.dtype == b.dtype and np.array_equal(a, b), name
        assert (g2.n_nodes, g2.n_edges, g2.block_size) \
            == (g.n_nodes, g.n_edges, g.block_size)
        assert graph_fingerprint(g2) == fp

    def test_identical_graphs_share_one_copy(self, tmp_path):
        j = WriteAheadJournal(tmp_path)
        fp1 = j.persist_graph(_graph(seed=5))
        fp2 = j.persist_graph(_graph(seed=5))
        fp3 = j.persist_graph(_graph(seed=6))
        assert fp1 == fp2 and fp1 != fp3
        assert len(list((tmp_path / "graphs").iterdir())) == 2

    def test_loaded_graph_cached_per_fingerprint(self, tmp_path):
        j = WriteAheadJournal(tmp_path)
        fp = j.persist_graph(_graph())
        j2 = WriteAheadJournal(tmp_path)
        assert j2.load_graph(fp) is j2.load_graph(fp)


class TestReplayIdempotence:
    def test_recover_twice_yields_same_ticket_set(self, tmp_path):
        """The satellite property: replay appends nothing, so two
        recoveries of one journal see identical worlds."""
        _killed_journal(tmp_path)
        size_after_kill = (tmp_path / JOURNAL_FILE).stat().st_size

        worlds = []
        for _ in range(2):
            sched = ContinuousScheduler(max_batch=2, slice_len=2)
            recovered = sched.recover(str(tmp_path))
            worlds.append({
                "jids": [t.jid for t in recovered],
                "restores": {
                    t.jid: (t._restore[1] if t._restore else 0)
                    for t in recovered},
                "states": {
                    t.jid: (t._restore[0] if t._restore else None)
                    for t in recovered},
                "recovered": sched.stats.recovered_tickets,
                "submitted": sched.stats.submitted,
            })
        a, b = worlds
        assert a["jids"] == b["jids"] and len(a["jids"]) > 0
        assert a["restores"] == b["restores"]
        assert a["recovered"] == b["recovered"]
        assert a["submitted"] == b["submitted"]
        for jid in a["states"]:
            sa, sb = a["states"][jid], b["states"][jid]
            assert (sa is None) == (sb is None)
            if sa is not None:
                assert _states_equal(sa, sb)
        # recovery itself wrote nothing to the journal
        assert (tmp_path / JOURNAL_FILE).stat().st_size == size_after_kill

    def test_recover_then_drain_then_recover_is_empty(self, tmp_path):
        _killed_journal(tmp_path)
        sched = ContinuousScheduler(max_batch=2, slice_len=2,
                                    journal_dir=str(tmp_path))
        recovered = sched.recover(str(tmp_path))
        assert recovered
        sched.run_until_idle()
        assert all(t.done() for t in recovered)
        # every ticket retired through the journal: nothing left
        assert ContinuousScheduler(max_batch=2, slice_len=2) \
            .recover(str(tmp_path)) == []

    def test_recover_skips_tickets_already_live(self, tmp_path):
        """A scheduler journaling to X that calls ``recover(X)`` must
        not duplicate its own live submissions — one jid, one Ticket."""
        _killed_journal(tmp_path)
        program = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        sched = ContinuousScheduler(max_batch=2, slice_len=2,
                                    journal_dir=str(tmp_path))
        live = sched.submit(program, _graph(seed=7), config)
        assert live.jid is not None
        recovered = sched.recover(str(tmp_path))
        assert recovered                      # the killed tickets return
        assert live.jid not in {t.jid for t in recovered}
        # recovering again with everything live re-admits nothing
        assert sched.recover(str(tmp_path)) == []
        jids = [t.jid for lane in sched._lanes.values()
                for t in [*lane.queue, *lane.tickets]
                if t is not None and t.jid is not None]
        assert len(jids) == len(set(jids))    # no jid held twice
        sched.run_until_idle()
        assert live.done() and all(t.done() for t in recovered)
        # every ticket retired exactly once: the journal is now empty
        assert ContinuousScheduler(max_batch=2, slice_len=2) \
            .recover(str(tmp_path)) == []

    def test_recovered_results_bit_identical_to_uninterrupted(
            self, tmp_path):
        program = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        pool = rmat_batch(2, 6, seed=9)
        ref = ContinuousScheduler(max_batch=2, slice_len=2)
        ref_tickets = [ref.submit(program, pool[i % 2], config)
                       for i in range(4)]
        ref.run_until_idle()

        killed = _killed_journal(tmp_path)
        fresh = ContinuousScheduler(max_batch=2, slice_len=2)
        recovered = fresh.recover(str(tmp_path))
        fresh.run_until_idle()
        by_jid = {t.jid: t for t in killed if t.done()}
        by_jid.update({t.jid: t for t in recovered})
        for rt, kt in zip(ref_tickets, sorted(by_jid)):
            assert _states_equal(rt.result(0).state,
                                 by_jid[kt].result(0).state)
