"""Durable checkpoint store: format, corruption handling, resume
bit-identity, and crash-kill recovery through ``run(checkpoint_dir=)``.
"""
import os
from pathlib import Path

import numpy as np
import pytest

from repro.algorithms import REGISTRY
from repro.core import SystemConfig, run
from repro.core.durability import (CHECKPOINT_MAGIC, CheckpointStore)
from repro.core.resilience import Checkpoint, ExecutionFault
from repro.graph import rmat_graph
from repro.testing.faults import ProcessKillFault, SimulatedProcessDeath


def _graph():
    return rmat_graph(scale=7, edge_factor=8, seed=11, weighted=False)


def _cp(it, v=0.0, done=False):
    return Checkpoint(it=it, done=done,
                      state={"dist": np.full(8, v, np.float32),
                             "frontier": np.zeros(8, bool)},
                      dir_buf=None, occ_buf=None)


def _states_equal(a, b):
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


class TestStoreFormat:
    def test_save_load_round_trip(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(_cp(3, 1.5))
        cp, faults = store.load_latest()
        assert faults == []
        assert cp.it == 3 and not cp.done
        assert np.array_equal(cp.state["dist"],
                              np.full(8, 1.5, np.float32))
        assert cp.state["frontier"].dtype == np.bool_

    def test_generations_ordered_and_pruned(self, tmp_path):
        store = CheckpointStore(tmp_path, keep=3)
        for it in range(6):
            store.save(_cp(it, float(it)))
        gens = store.generations()
        cps, faults = store.load_all()
        assert not faults
        # oldest generation stays pinned (cold-restart floor), the
        # middle ones rotate out
        its = [c.it for c in cps]
        assert its == sorted(its)
        assert its[0] == 0 and its[-1] == 5
        assert len(gens) == 3

    def test_keep_below_one_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="keep"):
            CheckpointStore(tmp_path, keep=0)

    def test_keep_one_still_resumes_from_newest(self, tmp_path):
        # keep=1 must never prune away the checkpoint just saved —
        # that would silently degrade every resume to a cold restart
        store = CheckpointStore(tmp_path, keep=1)
        for it in range(5):
            store.save(_cp(it, float(it)))
        cp, faults = store.load_latest()
        assert faults == [] and cp.it == 4
        # the initial generation survives too (cold-restart floor)
        cps, _ = store.load_all()
        assert [c.it for c in cps] == [0, 4]

    def test_atomic_no_tmp_left_behind(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(_cp(1))
        assert not [p for p in Path(tmp_path).iterdir()
                    if p.name.startswith(".tmp-")]

    def test_header_magic_on_disk(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(_cp(1))
        newest = store.generations()[0]
        assert newest.read_bytes()[:len(CHECKPOINT_MAGIC)] \
            == CHECKPOINT_MAGIC


class TestCorruption:
    def _corrupt(self, path, how):
        raw = bytearray(path.read_bytes())
        if how == "truncate":
            path.write_bytes(bytes(raw[: len(raw) // 2]))
        elif how == "bitflip":
            raw[-1] ^= 0x40
            path.write_bytes(bytes(raw))
        elif how == "magic":
            raw[0] ^= 0xFF
            path.write_bytes(bytes(raw))
        elif how == "short":
            path.write_bytes(b"xy")

    @pytest.mark.parametrize("how,reason", [
        ("truncate", "truncated"),
        ("bitflip", "checksum_mismatch"),
        ("magic", "bad_magic"),
        ("short", "short_header"),
    ])
    def test_each_corruption_is_structured(self, tmp_path, how, reason):
        store = CheckpointStore(tmp_path)
        store.save(_cp(1))
        self._corrupt(store.generations()[0], how)
        cp, faults = store.load_latest()
        assert cp is None
        assert len(faults) == 1
        assert faults[0]["kind"] == "corrupt_checkpoint"
        assert faults[0]["reason"] == reason

    def test_corrupt_newest_falls_back_to_previous(self, tmp_path):
        store = CheckpointStore(tmp_path)
        for it in (1, 2, 3):
            store.save(_cp(it, float(it)))
        self._corrupt(store.generations()[0], "bitflip")
        cps, faults = store.load_all()
        assert [f["kind"] for f in faults] == ["corrupt_checkpoint"]
        assert cps[-1].it == 2  # previous generation survives

    def test_all_corrupt_means_cold_restart(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(_cp(1))
        store.save(_cp(2))
        for gen in store.generations():
            self._corrupt(gen, "truncate")
        cps, faults = store.load_all()
        assert cps == [] and len(faults) == 2

    def test_load_raises_structured_execution_fault(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(_cp(1))
        gen = store.generations()[0]
        self._corrupt(gen, "bitflip")
        with pytest.raises(ExecutionFault) as ei:
            store.load(gen)
        assert ei.value.code == "corrupt_checkpoint"

    def test_fingerprint_mismatch_rejected(self, tmp_path):
        CheckpointStore(tmp_path, fingerprint={"jid": "a"}).save(_cp(1))
        other = CheckpointStore(tmp_path, fingerprint={"jid": "b"})
        cp, faults = other.load_latest()
        assert cp is None
        assert faults[0]["kind"] == "checkpoint_mismatch"


class TestDurableRun:
    def test_resume_after_kill_is_bit_identical(self, tmp_path):
        g = _graph()
        program = REGISTRY["PR"]()
        config = SystemConfig.from_name("DG1")
        clean = run(program, g, config, checkpoint_every=4)
        with pytest.raises(SimulatedProcessDeath):
            run(program, g, config, checkpoint_every=4,
                checkpoint_dir=str(tmp_path),
                fault_injector=ProcessKillFault(
                    at_iteration=max(4, clean.iterations - 4),
                    point="after_segment"))
        resumed = run(program, g, config, checkpoint_every=4,
                      checkpoint_dir=str(tmp_path))
        assert resumed.converged
        assert _states_equal(clean.state, resumed.state)
        assert resumed.iterations == clean.iterations

    def test_rerun_of_finished_run_converges_from_disk(self, tmp_path):
        g = _graph()
        program = REGISTRY["BFS"]()
        config = SystemConfig.from_name("DG1")
        first = run(program, g, config, checkpoint_every=4,
                    checkpoint_dir=str(tmp_path))
        again = run(program, g, config, checkpoint_every=4,
                    checkpoint_dir=str(tmp_path))
        assert again.converged
        assert _states_equal(first.state, again.state)

    def test_corrupt_newest_generation_still_recovers(self, tmp_path):
        g = _graph()
        program = REGISTRY["PR"]()
        config = SystemConfig.from_name("DG1")
        clean = run(program, g, config, checkpoint_every=4)
        with pytest.raises(SimulatedProcessDeath):
            run(program, g, config, checkpoint_every=4,
                checkpoint_dir=str(tmp_path),
                fault_injector=ProcessKillFault(
                    at_iteration=max(4, clean.iterations - 4)))
        store = CheckpointStore(str(tmp_path))
        newest = store.generations()[0]
        raw = bytearray(newest.read_bytes())
        raw[-1] ^= 0x40
        newest.write_bytes(bytes(raw))
        resumed = run(program, g, config, checkpoint_every=4,
                      checkpoint_dir=str(tmp_path))
        assert resumed.converged
        assert _states_equal(clean.state, resumed.state)
        # the corruption is surfaced in the fault history, not hidden
        hist = (resumed.fault or {}).get("history", [])
        assert any(h.get("kind") == "corrupt_checkpoint" for h in hist)

    def test_same_shape_different_graph_never_resumes(self, tmp_path):
        # the fingerprint covers graph *content*, not just shape: a
        # reused checkpoint_dir holding a killed run on graph A must
        # cold-restart (checkpoint_mismatch), never adopt A's state,
        # when pointed at a same-shape graph B with different weights
        g = rmat_graph(scale=7, edge_factor=8, seed=11, weighted=True)
        program = REGISTRY["SSSP"]()
        config = SystemConfig.from_name("DG1")
        clean_a = run(program, g, config, checkpoint_every=4)
        with pytest.raises(SimulatedProcessDeath):
            run(program, g, config, checkpoint_every=4,
                checkpoint_dir=str(tmp_path),
                fault_injector=ProcessKillFault(
                    at_iteration=max(4, clean_a.iterations - 4),
                    point="after_segment"))
        import dataclasses
        g2 = dataclasses.replace(
            g, weight=np.asarray(g.weight) * 2.0,
            weight_in=np.asarray(g.weight_in) * 2.0)
        clean_b = run(program, g2, config, checkpoint_every=4)
        resumed = run(program, g2, config, checkpoint_every=4,
                      checkpoint_dir=str(tmp_path))
        assert resumed.converged
        assert _states_equal(clean_b.state, resumed.state)
        hist = (resumed.fault or {}).get("history", [])
        assert any(h.get("kind") == "checkpoint_mismatch" for h in hist)

    def test_different_key_never_resumes(self, tmp_path):
        # same program/config/graph, different PRNG key: the killed
        # run's checkpoints must be rejected, not silently adopted
        import jax
        g = _graph()
        program = REGISTRY["MIS"]()
        config = SystemConfig.from_name("DG1")
        k1, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
        # MIS settles in a handful of rounds: checkpoint every
        # iteration and kill after the first so a mid-run boundary
        # really lands on disk before the death
        clean1 = run(program, g, config, key=k1, checkpoint_every=1)
        assert clean1.iterations >= 2
        with pytest.raises(SimulatedProcessDeath):
            run(program, g, config, key=k1, checkpoint_every=1,
                checkpoint_dir=str(tmp_path),
                fault_injector=ProcessKillFault(
                    at_iteration=1, point="after_segment"))
        clean2 = run(program, g, config, key=k2, checkpoint_every=1)
        resumed = run(program, g, config, key=k2, checkpoint_every=1,
                      checkpoint_dir=str(tmp_path))
        assert resumed.converged
        assert _states_equal(clean2.state, resumed.state)
        hist = (resumed.fault or {}).get("history", [])
        assert any(h.get("kind") == "checkpoint_mismatch" for h in hist)

    def test_kill_then_resume_replays_only_lost_segment(self, tmp_path):
        g = _graph()
        program = REGISTRY["PR"]()
        config = SystemConfig.from_name("DG1")
        clean = run(program, g, config, checkpoint_every=4)
        kill_at = max(4, clean.iterations - 4)
        with pytest.raises(SimulatedProcessDeath):
            run(program, g, config, checkpoint_every=4,
                checkpoint_dir=str(tmp_path),
                fault_injector=ProcessKillFault(at_iteration=kill_at,
                                                point="after_segment"))
        cp, faults = CheckpointStore(str(tmp_path)).load_latest()
        assert faults == []
        # the killed segment never persisted: at most one segment of
        # work is lost, everything older is on disk
        assert 0 < kill_at - cp.it <= 4
