"""Gateway fault injection: bad inputs fail fast, neighbours unharmed.

Covers the ISSUE-7 fault battery: (1) structurally malformed graphs —
negative row offsets, dangling edge endpoints, NaN weights, length
mismatches — are rejected at admission with a structured
:class:`AdmissionError` and never reach (or poison) an in-flight
batch; (2) cancellation retires cleanly both while queued and
mid-flight, with cohabitants bit-identical to solo; (3) per-request
deadlines return the partial state flagged ``timed_out`` — exactly the
state a sequential ``run(max_iters=...)`` of the completed iterations
produces — while batch-mates still converge bit-identically; (4)
bounded-queue backpressure rejects excess arrivals without losing
accepted work.

The ISSUE-8 extension adds mid-flight execution faults: an injected
NaN or runner exception inside a packed slice must quarantine *only*
the offending slot (structured :class:`ExecutionFault` on
``Ticket.result()``, outcome ``"faulted"`` in the stats) while every
cohabitant resumes from its parked state bit-identical to a solo run.
"""
import dataclasses

import numpy as np
import pytest

from repro.algorithms import REGISTRY
from repro.core import SystemConfig, run
from repro.core.resilience import ExecutionFault
from repro.graph import grid_graph, rmat_graph
from repro.graph.structure import validate_graph
from repro.launch.serve import (AdmissionError, CancelledError,
                                ContinuousScheduler, GatewayBackpressure)
from repro.testing.faults import SliceExceptionFault, SliceNaNFault

CFG = SystemConfig.from_name("DG1")


@pytest.fixture(scope="module")
def good_pair():
    """Same-bucket pair: a fault injected alongside one must leave the
    other's in-batch result untouched."""
    return [rmat_graph(5, 8, seed=1, weighted=True),
            grid_graph(7, seed=0, weighted=True)]


def _corrupt(g, **field_edits):
    return dataclasses.replace(g, **field_edits)


def _neg_offsets(g):
    rp = np.asarray(g.row_ptr_out).copy()
    rp[1] = -3
    return _corrupt(g, row_ptr_out=rp)


def _dangling_edge(g):
    dst = np.asarray(g.dst).copy()
    dst[0] = g.n_nodes + 5
    return _corrupt(g, dst=dst)


def _nan_weights(g):
    w = np.asarray(g.weight).copy()
    w[::7] = np.nan
    return _corrupt(g, weight=w)


def _short_degree(g):
    return _corrupt(g, out_degree=np.asarray(g.out_degree)[:-1])


def _decreasing_offsets(g):
    rp = np.asarray(g.row_ptr_out).copy()
    rp[2] = rp[3] + 1                        # non-negative but decreasing
    return _corrupt(g, row_ptr_out=rp)


FAULTS = {"negative_offsets": _neg_offsets,
          "decreasing_offsets": _decreasing_offsets,
          "dangling_edge": _dangling_edge,
          "nan_weights": _nan_weights,
          "length_mismatch": _short_degree}


class TestAdmissionRejection:
    @pytest.mark.parametrize("fault", sorted(FAULTS))
    def test_malformed_graph_rejected_with_structured_error(
            self, good_pair, fault):
        bad = FAULTS[fault](good_pair[0])
        assert validate_graph(bad)           # the validator sees it...
        sched = ContinuousScheduler()
        with pytest.raises(AdmissionError) as exc:
            sched.submit(REGISTRY["BFS"](), bad, CFG)
        assert exc.value.code == "invalid_graph"
        assert exc.value.errors                # ...and submit surfaces it
        assert sched.stats.rejected == 1
        assert sched.stats.submitted == 0      # never entered a lane
        assert not sched.pending()

    def test_valid_graph_passes_validator(self, good_pair):
        assert validate_graph(good_pair[0]) == []

    def test_negative_and_decreasing_offsets_reported_distinctly(
            self, good_pair):
        """The two CSR offset defects get their own messages: a negative
        entry vs a decreasing run (a negative-length adjacency row),
        the latter naming the offending row."""
        neg = validate_graph(_neg_offsets(good_pair[0]))
        assert any("negative offsets" in e for e in neg), neg
        dec = validate_graph(_decreasing_offsets(good_pair[0]))
        assert any("decrease at row 2" in e for e in dec), dec
        assert not any("negative offsets" in e for e in dec), dec

    def test_rejection_never_poisons_in_flight_batch(self, good_pair):
        """A malformed arrival mid-stream leaves the already-admitted
        cohort's results bit-identical to sequential."""
        prog = REGISTRY["BFS"]()
        seq = [run(prog, g, CFG) for g in good_pair]
        sched = ContinuousScheduler(max_batch=4, slice_len=2)
        tickets = [sched.submit(prog, g, CFG) for g in good_pair]
        sched.poll()                         # cohort is now in flight
        for fault in FAULTS.values():
            with pytest.raises(AdmissionError):
                sched.submit(prog, fault(good_pair[0]), CFG)
        sched.run_until_idle()
        for t, s in zip(tickets, seq):
            res = t.result(timeout=1)
            assert res.converged and res.iterations == s.iterations
            for k in s.state:
                assert np.array_equal(np.asarray(res.state[k]),
                                      np.asarray(s.state[k])), k


class TestCancellation:
    def test_cancel_while_queued(self, good_pair):
        sched = ContinuousScheduler()
        t = sched.submit(REGISTRY["BFS"](), good_pair[0], CFG)
        t.cancel()
        sched.poll()
        with pytest.raises(CancelledError):
            t.result(timeout=1)
        assert sched.stats.cancelled == 1
        assert sched.stats.completed == 0    # cancelled != completed
        assert not sched.pending()

    def test_cancel_mid_flight_retires_cleanly(self, good_pair):
        """Cancelling an in-flight request frees its slot at the next
        slice boundary; its batch-mate finishes bit-identical to solo."""
        prog = REGISTRY["BFS"]()
        seq = run(prog, good_pair[1], CFG)
        sched = ContinuousScheduler(max_batch=4, slice_len=1)
        t_cancel = sched.submit(prog, good_pair[0], CFG)
        t_mate = sched.submit(prog, good_pair[1], CFG)
        sched.poll()                         # both mid-flight now
        assert not t_cancel.done()
        t_cancel.cancel()
        sched.run_until_idle()
        with pytest.raises(CancelledError):
            t_cancel.result(timeout=1)
        res = t_mate.result(timeout=1)
        assert res.iterations == seq.iterations and res.converged
        for k in seq.state:
            assert np.array_equal(np.asarray(res.state[k]),
                                  np.asarray(seq.state[k])), k


class TestDeadlines:
    def test_expired_deadline_returns_flagged_partial_state(
            self, good_pair):
        """deadline_s=0 expires at the first slice boundary: the result
        carries ``timed_out=True`` and exactly the state sequential
        ``run(max_iters=<completed iterations>)`` produces; the
        cohabitant without a deadline converges bit-identical to solo."""
        prog = REGISTRY["BFS"]()
        g_slow, g_mate = good_pair[1], good_pair[0]
        full = run(prog, g_slow, CFG)
        seq_mate = run(prog, g_mate, CFG)
        slice_len = 2
        assert full.iterations > slice_len   # the deadline truly cuts it
        sched = ContinuousScheduler(max_batch=4, slice_len=slice_len)
        t_dead = sched.submit(prog, g_slow, CFG, deadline_s=0.0)
        t_mate = sched.submit(prog, g_mate, CFG)
        sched.run_until_idle()
        res = t_dead.result(timeout=1)
        assert res.timed_out and not res.converged
        assert res.iterations == slice_len   # one slice, then expired
        partial = run(prog, g_slow, CFG, max_iters=res.iterations)
        for k in partial.state:
            assert np.array_equal(np.asarray(res.state[k]),
                                  np.asarray(partial.state[k])), k
        assert sched.stats.timed_out == 1
        mate = t_mate.result(timeout=1)
        assert mate.converged and not mate.timed_out
        assert mate.iterations == seq_mate.iterations
        for k in seq_mate.state:
            assert np.array_equal(np.asarray(mate.state[k]),
                                  np.asarray(seq_mate.state[k])), k

    def test_generous_deadline_never_fires(self, good_pair):
        prog = REGISTRY["BFS"]()
        sched = ContinuousScheduler(max_batch=2, slice_len=4)
        t = sched.submit(prog, good_pair[0], CFG, deadline_s=3600.0)
        sched.run_until_idle()
        res = t.result(timeout=1)
        assert res.converged and not res.timed_out
        assert sched.stats.timed_out == 0


class TestBackpressure:
    def test_bounded_queue_rejects_excess_then_recovers(self, good_pair):
        prog = REGISTRY["BFS"]()
        sched = ContinuousScheduler(max_batch=2, slice_len=4, max_queue=2)
        accepted = [sched.submit(prog, good_pair[i % 2], CFG)
                    for i in range(2)]
        with pytest.raises(GatewayBackpressure):
            sched.submit(prog, good_pair[0], CFG)
        assert sched.stats.backpressure_rejections == 1
        sched.run_until_idle()               # queue drains...
        late = sched.submit(prog, good_pair[0], CFG)  # ...and recovers
        sched.run_until_idle()
        for t in accepted + [late]:
            assert t.result(timeout=1).converged

    def test_iteration_limit_outcome(self, good_pair):
        """max_iters through the gateway matches sequential run()'s
        non-converged partial result."""
        prog = REGISTRY["BFS"]()
        seq = run(prog, good_pair[1], CFG, max_iters=3)
        assert not seq.converged
        sched = ContinuousScheduler(max_batch=2, slice_len=3)
        t = sched.submit(prog, good_pair[1], CFG, max_iters=3)
        sched.run_until_idle()
        res = t.result(timeout=1)
        assert not res.converged and not res.timed_out
        assert res.iterations == seq.iterations == 3
        for k in seq.state:
            assert np.array_equal(np.asarray(res.state[k]),
                                  np.asarray(seq.state[k])), k


class TestExecutionFaults:
    """ISSUE-8: mid-flight faults are contained to the offending slot."""

    def _pool(self):
        return [rmat_graph(5, 8, seed=s, weighted=False)
                for s in (1, 2, 3, 4)]

    def _check_cohabitants(self, prog, pool, tickets, skip, exact=True):
        for j, (g, t) in enumerate(zip(pool, tickets)):
            if j == skip:
                continue
            res = t.result(timeout=1)
            solo = run(prog, g, CFG)
            assert res.converged and res.iterations == solo.iterations, j
            for k in solo.state:
                a = np.asarray(res.state[k])
                b = np.asarray(solo.state[k])
                if exact or a.dtype.kind != "f":
                    assert np.array_equal(a, b), (j, k)
                else:
                    assert np.allclose(a, b, atol=1e-6), (j, k)

    def test_nan_slot_quarantined_cohabitants_bit_identical(self):
        """A NaN injected into one PR slot trips the per-slice sentinel:
        that ticket alone raises a structured ExecutionFault and every
        cohabitant's result stays bit-identical to the in-batch run."""
        prog = REGISTRY["PR"]()
        pool = self._pool()
        sched = ContinuousScheduler(max_batch=4, slice_len=3)
        tickets = [sched.submit(prog, g, CFG) for g in pool]
        sched.fault_injector = SliceNaNFault(ticket_id=tickets[1].id)
        sched.run_until_idle()
        with pytest.raises(ExecutionFault) as exc:
            tickets[1].result(timeout=1)
        assert exc.value.code == "sentinel"
        assert "nan" in exc.value.detail["sentinels"]
        clean = ContinuousScheduler(max_batch=4, slice_len=3)
        ref = [clean.submit(prog, g, CFG) for g in pool]
        clean.run_until_idle()
        for j in (0, 2, 3):
            a = tickets[j].result(timeout=1)
            b = ref[j].result(timeout=1)
            assert a.iterations == b.iterations
            assert np.array_equal(np.asarray(a.state["rank"]),
                                  np.asarray(b.state["rank"])), j
        s = sched.stats
        assert s.quarantined == 1 and s.faulted == 1
        assert s.sentinel_trips == 1
        assert s.completed == len(pool)      # faulted is terminal too

    def test_transient_slice_exception_is_retried(self):
        """One injected dispatch failure: the slice retries whole under
        the default RetryPolicy and every request still converges
        bit-identical to solo — no quarantine, retry counted."""
        prog = REGISTRY["BFS"]()
        pool = self._pool()
        sched = ContinuousScheduler(
            max_batch=4, slice_len=3,
            fault_injector=SliceExceptionFault(times=1))
        tickets = [sched.submit(prog, g, CFG) for g in pool]
        sched.run_until_idle()
        self._check_cohabitants(prog, pool, tickets, skip=None)
        s = sched.stats
        assert s.slice_retries >= 1 and s.quarantined == 0
        assert s.recovery_seconds > 0

    def test_persistent_fault_isolated_to_one_slot(self):
        """An exception that follows one ticket through the roster *and*
        the retry forces solo isolation: the offender is quarantined
        with a structured error, cohabitants finish bit-identical."""
        prog = REGISTRY["BFS"]()
        pool = self._pool()
        sched = ContinuousScheduler(max_batch=4, slice_len=3)
        tickets = [sched.submit(prog, g, CFG) for g in pool]
        sched.fault_injector = SliceExceptionFault(ticket_id=tickets[2].id)
        sched.run_until_idle()
        with pytest.raises(ExecutionFault) as exc:
            tickets[2].result(timeout=1)
        assert exc.value.code == "slice_exception"
        assert "ticket" in exc.value.detail
        self._check_cohabitants(prog, pool, tickets, skip=2)
        s = sched.stats
        assert s.quarantined == 1 and s.faulted == 1
        assert s.slice_retries >= 1

    def test_empty_snapshot_schema_is_none_safe(self):
        """snapshot() at zero completed requests: every schema key is
        present, counters are zero, and the percentile/throughput
        summaries are None rather than raising on empty samples."""
        snap = ContinuousScheduler().stats.snapshot()
        for key in ("faulted", "quarantined", "slice_retries",
                    "sentinel_trips", "recovery_seconds"):
            assert snap[key] == 0, key
        for key in ("latency_p50_ms", "latency_p99_ms",
                    "queue_delay_p50_ms", "mean_occupancy",
                    "throughput_rps"):
            assert snap[key] is None, key
        assert snap["completed"] == 0 and snap["submitted"] == 0
