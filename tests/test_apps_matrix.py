"""The PR/CC/CLR/MIS port to the fused stack (ISSUE 6).

Covers the tentpole contract for the four legacy apps: fused-vs-host
engine bit-identity across the design-space spread, batch-vs-sequential
identity (bit-exact for the order-independent monoids CC/CLR/MIS,
allclose for the float-SUM apps PR/BC whose packed schedule reduces
edges in a different order), direction traces populated for all six
apps (including CC's alternating hooking direction, previously
silently untraced), per-graph PRNG key decorrelation for the
randomized apps, PageRank's true-V normalization under padding, the
``state_pad`` packing protocol, ``autotune="measure"`` compatibility,
and the BENCH_matrix artifact's perf-gate integration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import REGISTRY, bc, cc, coloring, mis, pagerank
from repro.algorithms._random import graph_key
from repro.algorithms.reference import (cc_np, is_maximal_independent_set,
                                        is_proper_coloring, pagerank_np)
from repro.core import SystemConfig, run, run_batch
from repro.core.batch import bucket_key, pack_graphs
from repro.graph import grid_graph, powerlaw_graph, rmat_graph

# spread over the three axes: pull / push x coherence x consistency /
# dynamic — the full grid runs in benchmarks
CONFIGS = ["TG0", "SG1", "SDR", "DD1"]
PORTED = {"PR": pagerank, "CC": cc, "CLR": coloring, "MIS": mis}
#: exact batching classes: min/max monoids are order-independent
EXACT_BATCH = ("CC", "CLR", "MIS")


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(200, 1200, alpha=1.0, seed=3, weighted=False,
                          block_size=64)


@pytest.fixture(scope="module")
def batch_graphs():
    """Two ragged graphs in one padding bucket (real padding rows)."""
    gs = [rmat_graph(5, 8, seed=1), grid_graph(7, seed=0)]
    assert bucket_key(gs[0]) == bucket_key(gs[1])
    return gs


def _key_for(name, i):
    """The documented run_batch default-key derivation."""
    return jax.random.fold_in(jax.random.key(0), i)


def _assert_identical(a, b):
    assert a.iterations == b.iterations
    assert a.converged == b.converged
    assert a.direction_trace == b.direction_trace
    assert a.occupancy_trace == b.occupancy_trace
    assert set(a.state) == set(b.state)
    for k in a.state:
        np.testing.assert_array_equal(np.asarray(a.state[k]),
                                      np.asarray(b.state[k]), err_msg=k)


class TestFusedVsHost:
    """The ported apps keep the engines bit-identical, like BFS/SSSP/BC."""

    @pytest.mark.parametrize("cfg", CONFIGS)
    @pytest.mark.parametrize("app", list(PORTED))
    def test_bit_identical(self, graph, app, cfg):
        prog = PORTED[app]()
        key = jax.random.key(7) if prog.randomized else None
        config = SystemConfig.from_name(cfg)
        host = run(prog, graph, config, key=key, engine="host")
        fused = run(prog, graph, config, key=key, engine="fused")
        _assert_identical(host, fused)

    @pytest.mark.parametrize("app,oracle", [
        ("PR", lambda g, st: np.abs(np.asarray(st["rank"])
                                    - pagerank_np(g)).max() < 1e-4),
        ("CC", lambda g, st: np.array_equal(np.asarray(st["label"]),
                                            cc_np(g))),
        ("CLR", lambda g, st: is_proper_coloring(
            g, np.asarray(st["color"]))),
    ])
    def test_fused_matches_oracle_on_dynamic_cell(self, graph, app,
                                                  oracle):
        prog = PORTED[app]()
        key = jax.random.key(7) if prog.randomized else None
        r = run(prog, graph, SystemConfig.from_name("DD1"), key=key)
        assert oracle(graph, r.state)


class TestBatchVsSequential:
    @pytest.mark.parametrize("cfg", ["SG1", "DD1"])
    @pytest.mark.parametrize("app", list(PORTED) + ["BC"])
    def test_unbatching(self, batch_graphs, app, cfg):
        prog = (PORTED.get(app) or bc)()
        config = SystemConfig.from_name(cfg)
        keys = ([_key_for(app, i) for i in range(len(batch_graphs))]
                if prog.randomized else None)
        bat = run_batch(prog, batch_graphs, config, keys=keys)
        for i, (g, b) in enumerate(zip(batch_graphs, bat)):
            s = run(prog, g, config,
                    key=None if keys is None else keys[i])
            if app in EXACT_BATCH:
                _assert_identical(s, b)
            else:  # float SUM: packed schedule reassociates chunk sums
                assert s.iterations == b.iterations
                assert s.direction_trace == b.direction_trace
                np.testing.assert_allclose(
                    np.asarray(b.extract(prog)),
                    np.asarray(s.extract(prog)), rtol=1e-5, atol=1e-7)

    def test_pagerank_true_v_normalization(self, batch_graphs):
        """Batched ranks normalize by each graph's true V, not the
        padded bucket size: every member's ranks still sum to 1."""
        bat = run_batch(pagerank(), batch_graphs,
                        SystemConfig.from_name("SG1"))
        for g, r in zip(batch_graphs, bat):
            assert np.asarray(r.state["rank"]).shape == (g.n_nodes,)
            assert float(np.asarray(r.state["rank"]).sum()) \
                == pytest.approx(1.0, abs=1e-3)

    def test_mis_converges_under_padding(self, batch_graphs):
        """state_pad marks padding rows removed — a zero fill would
        leave them undecided and batched MIS could never converge."""
        bat = run_batch(mis(), batch_graphs,
                        SystemConfig.from_name("SG1"))
        for g, r in zip(batch_graphs, bat):
            assert r.converged
            assert is_maximal_independent_set(
                g, np.asarray(r.extract(mis())))


class TestDirectionTraces:
    @pytest.mark.parametrize("app", list(REGISTRY))
    def test_all_six_apps_trace_on_dynamic_cell(self, graph, app):
        prog = REGISTRY[app]()
        key = jax.random.key(7) if prog.randomized else None
        r = run(prog, graph, SystemConfig.from_name("DD1"), key=key)
        assert r.direction_trace is not None
        assert len(r.direction_trace) == r.iterations
        assert set(r.direction_trace) <= {"S", "T"}

    def test_cc_alternates_per_round(self, graph):
        """The hooking direction alternates push/pull per round and —
        the ISSUE's bug — actually lands in the trace."""
        r = run(cc(), graph, SystemConfig.from_name("DD1"))
        expect = "".join("ST"[i % 2] for i in range(r.iterations))
        assert r.direction_trace == expect

    def test_cc_static_configs_fold_the_wish(self, graph):
        assert set(run(cc(), graph,
                       SystemConfig.from_name("SG1")).direction_trace) \
            == {"S"}
        assert set(run(cc(), graph,
                       SystemConfig.from_name("TG0")).direction_trace) \
            == {"T"}


class TestKeyDecorrelation:
    def test_batch_members_draw_independent_priorities(self, batch_graphs):
        """keys=None on a randomized app derives per-graph keys — the
        old shared default gave identical priorities batch-wide."""
        g = batch_graphs[0]
        for prog_f, check in ((coloring, is_proper_coloring),
                              (mis, is_maximal_independent_set)):
            prog = prog_f()
            a, b = run_batch(prog, [g, g], SystemConfig.from_name("SG1"))
            xa, xb = (np.asarray(r.extract(prog)) for r in (a, b))
            assert not np.array_equal(xa, xb)
            assert check(g, xa) and check(g, xb)

    def test_default_batch_keys_are_reproducible(self, batch_graphs):
        ra = run_batch(coloring(), batch_graphs,
                       SystemConfig.from_name("SG1"))
        rb = run_batch(coloring(), batch_graphs,
                       SystemConfig.from_name("SG1"))
        for a, b in zip(ra, rb):
            np.testing.assert_array_equal(np.asarray(a.state["color"]),
                                          np.asarray(b.state["color"]))

    def test_sequential_default_key_is_per_graph(self, batch_graphs):
        g1, g2 = batch_graphs
        assert not np.array_equal(
            np.asarray(jax.random.key_data(graph_key(g1, salt=1))),
            np.asarray(jax.random.key_data(graph_key(g2, salt=1))))


class TestStatePadProtocol:
    def test_pack_state_fills_padding(self, batch_graphs):
        batch = pack_graphs(tuple(batch_graphs))
        states = [{"status": jnp.zeros((g.n_nodes,), jnp.int32),
                   "x": jnp.ones((g.n_nodes,), jnp.float32)}
                  for g in batch_graphs]
        packed = batch.pack_state(states, pad={"status": 2})
        status = np.asarray(packed["status"])
        x = np.asarray(packed["x"])
        for i, g in enumerate(batch_graphs):
            lo = i * batch.n_q
            real, padding = slice(lo, lo + g.n_nodes), \
                slice(lo + g.n_nodes, lo + batch.n_q)
            assert (status[real] == 0).all()
            assert (status[padding] == 2).all()   # per-key fill
            assert (x[padding] == 0).all()        # default fill


class TestAutotuneMeasure:
    @pytest.mark.parametrize("app", ["PR", "CC"])
    def test_results_invariant(self, app, monkeypatch, tmp_path):
        import repro.kernels.autotune as at
        monkeypatch.setattr(at, "DEFAULT_CACHE_PATH",
                            str(tmp_path / "autotune_cache.json"))
        g = powerlaw_graph(128, 700, alpha=1.0, seed=9, weighted=False,
                           block_size=32)
        prog = PORTED[app]()
        base = run(prog, g, SystemConfig.from_name("SDR"))
        tuned = run(prog, g, SystemConfig.from_name("SDR"),
                    autotune="measure")
        _assert_identical(base, tuned)
