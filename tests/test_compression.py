"""Gradient compression + error feedback properties."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import CompressedReducer


def _grads(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (64, 32)) * scale,
            "b": jax.random.normal(k2, (32,)) * scale}


class TestCompression:
    def test_wire_dtype(self):
        cr = CompressedReducer(jnp.bfloat16)
        g = _grads(jax.random.key(0))
        st = cr.init_state(g)
        wires, _ = cr.compress(g, st)
        assert all(w.dtype == jnp.bfloat16 for w in jax.tree.leaves(wires))

    def test_single_round_error_bounded(self):
        cr = CompressedReducer(jnp.bfloat16)
        g = _grads(jax.random.key(1))
        out, _ = cr.reduce(g, cr.init_state(g))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-2, atol=1e-2)

    def test_error_feedback_unbiased_over_time(self):
        """With a CONSTANT gradient, error feedback makes the time-average
        of the compressed stream converge to the true gradient (the
        property plain bf16 rounding lacks)."""
        cr = CompressedReducer(jnp.bfloat16)
        g = jax.tree.map(lambda x: x * (1 + 2 ** -10),
                         _grads(jax.random.key(2), scale=1e-3))
        st = cr.init_state(g)
        total = jax.tree.map(jnp.zeros_like, g)
        n = 64
        for _ in range(n):
            out, st = cr.reduce(g, st)
            total = jax.tree.map(lambda t, o: t + o, total, out)
        for t, gg in zip(jax.tree.leaves(total), jax.tree.leaves(g)):
            err = np.abs(np.asarray(t) / n - np.asarray(gg)).max()
            scale = np.abs(np.asarray(gg)).max()
            assert err < 2e-4 * max(scale, 1e-6) + 1e-8, err

    def test_residual_carries_information(self):
        cr = CompressedReducer(jnp.bfloat16)
        g = _grads(jax.random.key(3), scale=1e-4)
        st = cr.init_state(g)
        _, st2 = cr.reduce(g, st)
        # residual is nonzero for values below bf16 resolution boundaries
        assert any(np.abs(np.asarray(r)).max() > 0
                   for r in jax.tree.leaves(st2))

    def test_with_reduce_fn(self):
        cr = CompressedReducer(jnp.bfloat16)
        g = _grads(jax.random.key(4))
        out, _ = cr.reduce(g, cr.init_state(g),
                           reduce_fn=lambda t: jax.tree.map(
                               lambda x: x * 0.5, t))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b) * 0.5,
                                       rtol=1e-2, atol=1e-2)
