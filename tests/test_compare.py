"""The CI perf-regression gate (benchmarks/compare.py).

The gate diffs within-run speedup metrics against committed baselines
and must: pass on unchanged numbers, fail (exit 1) on an injected 2x
regression, refuse (exit 2) incompatible or missing baselines, and
tolerate single-cell noise that the geomean absorbs.
"""
import copy
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.compare import (ARTIFACTS, compare_artifact, compare_dirs,
                                extract_metrics, update_baselines)


def _dispatch_artifact(speedup=1.5):
    return {
        "workload": {"generator": "rmat", "scale": 10, "seed": 7},
        "configs": {c: {"fused_speedup": speedup,
                        "host": {}, "fused": {}}
                    for c in ("SG0", "TG0", "DG1", "DDR")},
        "summary": {},
    }


def _batch_artifact(speedup=2.0):
    return {
        "workload": {"generator": "rmat_batch", "scale": 6, "seed": 7},
        "smoke": False,
        "configs": {c: {"1": {"speedup": 1.0},
                        "16": {"speedup": speedup}}
                    for c in ("SG0", "DG1")},
    }


def _autotune_artifact(speedup=1.3):
    return {
        "smoke": True,
        "workloads": {
            "rmat": {"generator": "rmat_graph",
                     "params": {"scale": 7},
                     "configs": {c: {"speedup": speedup}
                                 for c in ("SG0", "TD0")}},
        },
    }


def _resilience_artifact(efficiency=0.97, identical=True, recovery=1.3):
    return {
        "workload": {"generator": "rmat", "scale": 10, "seed": 7},
        "smoke": True,
        "checkpoint_every": 32,
        "configs": {c: {"efficiency": efficiency,
                        "bit_identical": identical}
                    for c in ("TG0", "DG1")},
        "recovery": {"recovery_speedup": recovery},
    }


def _chaos_artifact(identical=True, lost_work=0.2, contained=True):
    return {
        "smoke": True,
        "workload": {"core_app": "PR", "core_k": 4,
                     "gateway_apps": ["BFS", "SSSP"]},
        "core": {"bit_identical": identical,
                 "lost_work_ratio": lost_work},
        "gateway": {"apps": {a: {"bit_identical": identical}
                             for a in ("BFS", "SSSP")},
                    "lost_work_ratio": lost_work},
        "overload": {"contained": contained},
    }


def _matrix_artifact(gain=1.4, source="synthetic"):
    return {
        "smoke": True,
        "workload": {"scale": 256, "block_size": 64,
                     "ref_config": "TG0",
                     "configs": ["TG0", "SG1", "DD1"]},
        "inputs": {g: {"source": source} for g in ("DCT", "RAJ")},
        "cells": {f"{g}/{a}": {"specialization_gain": gain,
                               "best": "DD1", "configs": {}}
                  for g in ("DCT", "RAJ") for a in ("PR", "CC")},
    }


def _specialize_artifact(acc=0.83, partial_ok=True, e2e_ok=True,
                         speedup=1.34):
    return {
        "smoke": True,
        "workload": {"matrix": {"scale": 256, "ref_config": "TG0"},
                     "tol": 0.10, "max_depth": 6,
                     "n_workloads": 42,
                     "configs": ["DD1", "SG1", "TG0"]},
        "model": {"path": "results/specialize_model.json", "version": 1,
                  "classes": ["DD1", "SG1", "TG0"], "depth": 6,
                  "n_leaves": 17, "label_histogram": {"DD1": 30}},
        "accuracy": {"learned": acc, "learned_tol": acc,
                     "static_partial": 0.45, "static_partial_tol": 0.55},
        "e2e": {"geomean_us": {"learned": 2628.0,
                               "always": {"DD1": 3511.0}},
                "best_always": {"config": "DD1", "geomean_us": 3511.0},
                "speedup_vs_best_always": speedup},
        "gate": {"accuracy_ge_partial": partial_ok,
                 "e2e_ge_best_always": e2e_ok},
    }


class TestExtractAndCompare:
    def test_extract_metric_names(self):
        m = extract_metrics("dispatch", _dispatch_artifact())
        assert m["dispatch/SG0/fused_speedup"] == 1.5
        m = extract_metrics("batch", _batch_artifact())
        assert m["batch/DG1/B16/speedup"] == 2.0
        m = extract_metrics("autotune", _autotune_artifact())
        assert m["autotune/rmat/TD0/speedup"] == 1.3
        m = extract_metrics("matrix", _matrix_artifact())
        assert m["matrix/DCT/PR/specialization_gain"] == 1.4
        assert m["matrix/RAJ/CC/specialization_gain"] == 1.4
        with pytest.raises(ValueError):
            extract_metrics("nope", {})

    def test_identical_passes(self):
        base = _dispatch_artifact()
        rep = compare_artifact("dispatch", base, copy.deepcopy(base))
        assert rep["status"] == "ok"
        assert rep["geomean_ratio"] == pytest.approx(1.0)

    def test_injected_2x_regression_fails(self):
        base = _batch_artifact(speedup=2.0)
        cur = _batch_artifact(speedup=1.0)  # batched advantage halved
        rep = compare_artifact("batch", base, cur)
        assert rep["status"] == "regression"
        # only the B16 cells regressed (2x); B1 cells unchanged
        assert rep["geomean_ratio"] == pytest.approx(2.0 ** 0.5)
        assert rep["worst"][0][1] == pytest.approx(2.0)

    def test_single_cell_noise_is_absorbed_by_geomean(self):
        base = _dispatch_artifact(speedup=1.5)
        cur = copy.deepcopy(base)
        cur["configs"]["SG0"]["fused_speedup"] = 1.2  # one noisy cell
        rep = compare_artifact("dispatch", base, cur)
        assert rep["status"] == "ok"

    def test_uniform_regression_beyond_threshold_fails(self):
        base = _dispatch_artifact(speedup=1.5)
        cur = _dispatch_artifact(speedup=1.5 / 1.3)  # 30% everywhere
        assert compare_artifact("dispatch", base, cur)["status"] \
            == "regression"

    def test_improvement_passes(self):
        base = _dispatch_artifact(speedup=1.5)
        cur = _dispatch_artifact(speedup=3.0)
        rep = compare_artifact("dispatch", base, cur)
        assert rep["status"] == "ok"
        assert rep["geomean_ratio"] < 1.0

    def test_changed_workload_is_incompatible(self):
        base = _batch_artifact()
        cur = _batch_artifact()
        cur["workload"]["scale"] = 7  # pinned workload moved
        assert compare_artifact("batch", base, cur)["status"] \
            == "incompatible"
        cur = _autotune_artifact()
        cur["smoke"] = False  # smoke vs full are different workloads
        assert compare_artifact("autotune", _autotune_artifact(),
                                cur)["status"] == "incompatible"

    def test_resilience_caps_and_bit_identity(self):
        """Healthy efficiencies saturate the cap (run-to-run reads
        exactly 1.0); a config losing bit-identity is an unmissable
        regression; a moved checkpoint interval refuses to diff."""
        base = _resilience_artifact(efficiency=0.98, recovery=1.4)
        cur = _resilience_artifact(efficiency=0.93, recovery=1.2)
        rep = compare_artifact("resilience", base, cur)
        assert rep["status"] == "ok"   # both above the caps -> 1.0
        assert rep["geomean_ratio"] == pytest.approx(1.0)
        m = extract_metrics("resilience", base)
        assert m["resilience/TG0/efficiency"] == pytest.approx(0.90)
        assert m["resilience/recovery/speedup"] == pytest.approx(1.1)
        broken = _resilience_artifact(identical=False)
        assert compare_artifact("resilience", base,
                                broken)["status"] == "regression"
        moved = _resilience_artifact()
        moved["checkpoint_every"] = 8
        assert compare_artifact("resilience", base,
                                moved)["status"] == "incompatible"

    def test_chaos_invariants_read_one_when_healthy(self):
        m = extract_metrics("chaos", _chaos_artifact())
        assert m == {
            "chaos/core/identical": 1.0,
            "chaos/core/lost_work_contained": 1.0,
            "chaos/gateway/BFS/identical": 1.0,
            "chaos/gateway/SSSP/identical": 1.0,
            "chaos/gateway/lost_work_contained": 1.0,
            "chaos/overload/contained": 1.0,
        }
        base = _chaos_artifact()
        rep = compare_artifact("chaos", base, copy.deepcopy(base))
        assert rep["status"] == "ok"
        assert rep["geomean_ratio"] == pytest.approx(1.0)

    def test_chaos_lost_identity_blows_the_gate(self):
        # recovery wall-clock may drift freely, but a single lost
        # bit-identity / containment invariant must fail unmissably
        for broken in (_chaos_artifact(identical=False),
                       _chaos_artifact(lost_work=1.0),
                       _chaos_artifact(contained=False)):
            rep = compare_artifact("chaos", _chaos_artifact(), broken)
            assert rep["status"] == "regression"
            assert rep["worst"][0][1] == pytest.approx(1e6)

    def test_specialize_invariants_and_caps(self):
        from benchmarks.compare import SPECIALIZE_CAP
        m = extract_metrics("specialize", _specialize_artifact())
        assert m["specialize/accuracy_ge_partial"] == 1.0
        assert m["specialize/e2e_ge_best_always"] == 1.0
        assert m["specialize/accuracy_learned_tol"] == pytest.approx(0.83)
        # headroom above break-even is capped, like the serve caps
        assert m["specialize/speedup_vs_best_always"] == SPECIALIZE_CAP
        base = _specialize_artifact()
        rep = compare_artifact("specialize", base, copy.deepcopy(base))
        assert rep["status"] == "ok"
        assert rep["geomean_ratio"] == pytest.approx(1.0)

    def test_specialize_broken_acceptance_blows_the_gate(self):
        # either acceptance invariant breaking must fail unmissably;
        # a genuine accuracy drop regresses through the plain ratio
        for broken in (_specialize_artifact(partial_ok=False),
                       _specialize_artifact(e2e_ok=False)):
            rep = compare_artifact("specialize", _specialize_artifact(),
                                   broken)
            assert rep["status"] == "regression"
            assert rep["worst"][0][1] == pytest.approx(1e6)
        worse = _specialize_artifact(acc=0.5)
        rep = compare_artifact("specialize", _specialize_artifact(),
                               worse)
        assert rep["ratios"]["specialize/accuracy_learned_tol"] \
            == pytest.approx(0.83 / 0.5)

    def test_specialize_training_matrix_pins_fingerprint(self):
        base = _specialize_artifact()
        moved = _specialize_artifact()
        moved["workload"]["matrix"]["scale"] = 512
        assert compare_artifact("specialize", base,
                                moved)["status"] == "incompatible"

    def test_chaos_smoke_flag_pins_fingerprint(self):
        base = _chaos_artifact()
        full = _chaos_artifact()
        full["smoke"] = False
        assert compare_artifact("chaos", base, full)["status"] \
            == "incompatible"

    def test_matrix_gain_regression_and_input_source_pinning(self):
        base = _matrix_artifact(gain=1.4)
        rep = compare_artifact("matrix", base,
                               copy.deepcopy(base))
        assert rep["status"] == "ok"
        assert compare_artifact("matrix", base,
                                _matrix_artifact(gain=1.0))["status"] \
            == "regression"
        # fetching the real graphs changes the workload identity: a
        # baseline recorded on synthetic stand-ins must refuse to diff
        assert compare_artifact("matrix", base,
                                _matrix_artifact(source="real"))["status"] \
            == "incompatible"


class TestCompareDirs:
    def _write(self, d, kind, artifact):
        d.mkdir(parents=True, exist_ok=True)
        (d / ARTIFACTS[kind]).write_text(json.dumps(artifact))

    def test_end_to_end_pass_and_injected_fail(self, tmp_path):
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(base, "dispatch", _dispatch_artifact(1.5))
        self._write(cur, "dispatch", _dispatch_artifact(1.45))  # noise
        assert compare_dirs(base, cur, ["dispatch"]) == 0
        # inject a 2x regression across the board -> exit 1
        self._write(cur, "dispatch", _dispatch_artifact(0.75))
        assert compare_dirs(base, cur, ["dispatch"]) == 1

    def test_failure_message_names_artifact_metric_and_values(
            self, tmp_path, capsys):
        """A FAIL line must say *what* regressed: artifact kind, metric
        name, and measured-vs-baseline values — enough to act on from
        the CI log alone."""
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(base, "dispatch", _dispatch_artifact(1.5))
        self._write(cur, "dispatch", _dispatch_artifact(0.75))
        assert compare_dirs(base, cur, ["dispatch"]) == 1
        out = capsys.readouterr().out
        assert "worst [dispatch]: dispatch/SG0/fused_speedup" in out
        assert "measured 0.75 vs baseline 1.5" in out
        assert "+100.0% regression" in out

    def test_missing_baseline_fails_unless_allowed(self, tmp_path):
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(cur, "batch", _batch_artifact())
        assert compare_dirs(base, cur, ["batch"]) == 2
        assert compare_dirs(base, cur, ["batch"],
                            allow_missing=True) == 0

    def test_missing_current_fails_unless_allowed(self, tmp_path):
        """A requested artifact the benchmarks didn't produce must not
        silently un-gate itself (e.g. an --out path drift)."""
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(base, "batch", _batch_artifact())
        cur.mkdir()
        assert compare_dirs(base, cur, ["batch"]) == 2
        assert compare_dirs(base, cur, ["batch"],
                            allow_missing=True) == 0

    def test_incompatible_baseline_exits_2(self, tmp_path):
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(base, "autotune", _autotune_artifact())
        changed = _autotune_artifact()
        changed["workloads"]["rmat"]["params"] = {"scale": 9}
        self._write(cur, "autotune", changed)
        assert compare_dirs(base, cur, ["autotune"]) == 2

    def test_corrupt_baseline_exits_2_with_refresh_hint(
            self, tmp_path, capsys):
        """A truncated/corrupt baseline must FAIL actionably (name the
        path and the --update-baselines procedure), not crash the gate
        with an unhandled JSONDecodeError."""
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(cur, "dispatch", _dispatch_artifact())
        base.mkdir()
        (base / ARTIFACTS["dispatch"]).write_text('{"workload": tru')
        assert compare_dirs(base, cur, ["dispatch"]) == 2
        out = capsys.readouterr().out
        assert "UNREADABLE baseline" in out
        assert str(base / ARTIFACTS["dispatch"]) in out
        assert "--update-baselines" in out

    def test_corrupt_current_exits_2(self, tmp_path, capsys):
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(base, "dispatch", _dispatch_artifact())
        cur.mkdir()
        (cur / ARTIFACTS["dispatch"]).write_text("")
        assert compare_dirs(base, cur, ["dispatch"]) == 2
        assert "UNREADABLE current" in capsys.readouterr().out

    def test_update_baselines_copies(self, tmp_path):
        base, cur = tmp_path / "baselines", tmp_path / "results"
        self._write(cur, "dispatch", _dispatch_artifact())
        update_baselines(base, cur, ["dispatch", "batch"])
        assert (base / ARTIFACTS["dispatch"]).exists()
        assert not (base / ARTIFACTS["batch"]).exists()
        assert compare_dirs(base, cur, ["dispatch"]) == 0
