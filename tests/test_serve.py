"""Streaming gateway: continuous batching is invisible to results.

Covers the ISSUE-7 contract: (1) requests served through the gateway
are bit-identical to sequential ``run()`` regardless of arrival order,
cohort composition, or how many join/retire boundaries they crossed
(PR, the float-SUM program, matches to float tolerance); (2) randomized
programs (CLR/MIS) are deterministic through the gateway — their
default keys depend only on the graph, never on batch composition or
admission order; (3) the threaded front-end serves concurrent clients
correctly; (4) steady-state traffic is plan-cache-warm — re-admitting
known graphs rebuilds nothing; (5) lifecycle instrumentation
(timestamps, counters, snapshot schema) is coherent; (6) the relocated
LM demo still reachable through the old entry point.
"""
import threading

import numpy as np
import pytest

from repro.algorithms import REGISTRY
from repro.core import PLAN_CACHE, SystemConfig, run
from repro.core.batch import bucket_key
from repro.graph import grid_graph, rmat_graph
from repro.launch.serve import ContinuousScheduler, GraphGateway

CFG = SystemConfig.from_name("DG1")


@pytest.fixture(scope="module")
def pool():
    """Two same-bucket graphs (one lane, B=2 packing) plus one from a
    different bucket (its own lane)."""
    g1 = rmat_graph(5, 8, seed=1, weighted=True)
    g2 = grid_graph(7, seed=0, weighted=True)
    g3 = rmat_graph(7, 8, seed=2, weighted=True)
    assert bucket_key(g1) == bucket_key(g2)
    assert bucket_key(g1) != bucket_key(g3)
    return [g1, g2, g3]


def _state_equal(a, b, exact=True):
    assert set(a) == set(b)
    for k in a:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        if exact:
            assert np.array_equal(x, y), k
        else:
            np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-7,
                                       err_msg=k)


def _gateway_matches_sequential(res, seq, exact=True):
    assert res.engine == "gateway"
    assert res.converged == seq.converged
    assert res.iterations == seq.iterations
    assert res.direction_trace == seq.direction_trace
    assert res.occupancy_trace == seq.occupancy_trace
    assert not res.timed_out
    _state_equal(res.state, seq.state, exact=exact)


class TestBitIdenticalThroughGateway:
    @pytest.mark.parametrize("app", ["BFS", "SSSP", "CC", "CLR", "MIS",
                                     "PR"])
    def test_staggered_arrivals_match_sequential(self, pool, app):
        """Requests arriving on different scheduling rounds — so each
        crosses different join/retire boundaries — still reproduce
        sequential ``run()`` (PR to float tolerance, rest bitwise)."""
        prog = REGISTRY[app]()
        seq = {id(g): run(prog, g, CFG) for g in pool}
        sched = ContinuousScheduler(max_batch=4, slice_len=3)
        arrivals = {0: [pool[0]], 1: [pool[2]], 2: [pool[1], pool[0]]}
        tickets = []
        for rnd in range(4):
            for g in arrivals.get(rnd, []):
                tickets.append((g, sched.submit(prog, g, CFG)))
            sched.poll()
        sched.run_until_idle()
        for g, t in tickets:
            _gateway_matches_sequential(t.result(timeout=1), seq[id(g)],
                                        exact=(app != "PR"))

    def test_cohort_independence(self, pool):
        """The same graph served solo and served inside a churning
        cohort returns the identical result."""
        prog = REGISTRY["BFS"]()
        g = pool[0]
        solo_sched = ContinuousScheduler(max_batch=1, slice_len=2)
        t_solo = solo_sched.submit(prog, g, CFG)
        solo_sched.run_until_idle()
        cohort = ContinuousScheduler(max_batch=4, slice_len=2)
        t_in = cohort.submit(prog, g, CFG)
        cohort.submit(prog, pool[1], CFG)
        cohort.poll()                       # duo in flight
        t_late = cohort.submit(prog, g, CFG)  # joins mid-stream
        cohort.run_until_idle()
        for t in (t_solo, t_in, t_late):
            _gateway_matches_sequential(t.result(timeout=1),
                                        run(prog, g, CFG))


class TestRandomizedProgramDeterminism:
    @pytest.mark.parametrize("app", ["CLR", "MIS"])
    def test_keys_independent_of_cohort_and_order(self, pool, app):
        """CLR/MIS default keys derive from the graph alone: admission
        order and batch composition never change the answer."""
        prog = REGISTRY[app]()
        g = pool[0]
        seq = run(prog, g, CFG)
        outcomes = []
        for order in ([g, pool[1]], [pool[1], g], [g]):
            sched = ContinuousScheduler(max_batch=4, slice_len=3)
            ts = {id(x): sched.submit(prog, x, CFG) for x in order}
            sched.run_until_idle()
            outcomes.append(ts[id(g)].result(timeout=1))
        for res in outcomes:
            _gateway_matches_sequential(res, seq)


class TestThreadedGateway:
    def test_concurrent_clients(self, pool):
        prog = REGISTRY["BFS"]()
        seq = {id(g): run(prog, g, CFG) for g in pool}
        n_req, n_clients = 12, 3
        results = [None] * n_req
        with GraphGateway(max_batch=4, slice_len=4) as gw:
            def client(k):
                for i in range(k, n_req, n_clients):
                    g = pool[i % len(pool)]
                    results[i] = (g, gw.submit(prog, g, CFG)
                                  .result(timeout=120))
            threads = [threading.Thread(target=client, args=(k,))
                       for k in range(n_clients)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            snap = gw.stats()
        for g, res in results:
            _gateway_matches_sequential(res, seq[id(g)])
        assert snap["submitted"] == snap["completed"] == n_req
        assert snap["converged"] == n_req
        assert snap["throughput_rps"] > 0

    def test_submit_requires_running_gateway(self, pool):
        gw = GraphGateway()
        with pytest.raises(RuntimeError, match="not running"):
            gw.submit(REGISTRY["BFS"](), pool[0], CFG)


class TestPlanCacheWarmth:
    def test_steady_state_repeat_traffic_rebuilds_nothing(self, pool):
        """Once the roster holds a graph, re-admitting it is pure cache:
        no roster rebuild, no new pack/context/init misses."""
        prog = REGISTRY["BFS"]()
        sched = ContinuousScheduler(max_batch=2, slice_len=4)
        for g in pool[:2]:
            sched.submit(prog, g, CFG)
        sched.run_until_idle()
        assert sched.stats.roster_rebuilds >= 1      # initial growth
        sched.reset_stats()
        pack0 = PLAN_CACHE.kind_stats("batch_pack")
        ctx0 = PLAN_CACHE.kind_stats("batch_context")
        init0 = PLAN_CACHE.kind_stats("init_state")
        for g in pool[:2]:
            sched.submit(prog, g, CFG)
        sched.run_until_idle()
        assert sched.stats.roster_rebuilds == 0
        pack1 = PLAN_CACHE.kind_stats("batch_pack")
        ctx1 = PLAN_CACHE.kind_stats("batch_context")
        init1 = PLAN_CACHE.kind_stats("init_state")
        assert pack1["misses"] == pack0["misses"]
        assert ctx1["misses"] == ctx0["misses"]
        assert init1["misses"] == init0["misses"]
        assert init1["hits"] >= init0["hits"] + 2    # memoized init reused

    def test_lanes_split_by_config_and_bucket(self, pool):
        prog = REGISTRY["BFS"]()
        sched = ContinuousScheduler(max_batch=4, slice_len=2)
        sched.submit(prog, pool[0], CFG)
        sched.submit(prog, pool[1], CFG)              # same lane
        sched.submit(prog, pool[2], CFG)              # other bucket
        sched.submit(prog, pool[0], SystemConfig.from_name("SG0"))
        assert len(sched._lanes) == 3
        sched.run_until_idle()


class TestLifecycleInstrumentation:
    def test_timestamps_and_snapshot_schema(self, pool):
        prog = REGISTRY["BFS"]()
        sched = ContinuousScheduler(max_batch=2, slice_len=2)
        t = sched.submit(prog, pool[0], CFG)
        sched.run_until_idle()
        res = t.result(timeout=1)
        assert res.dispatches >= 1
        assert (t.enqueued_at <= t.admitted_at <= t.first_dispatch_at
                <= t.completed_at)
        snap = sched.stats.snapshot()
        for k in ("submitted", "admitted", "completed", "converged",
                  "timed_out", "cancelled", "rejected",
                  "backpressure_rejections", "slices", "roster_rebuilds",
                  "dispatch_seconds", "latency_p50_ms", "latency_p99_ms",
                  "queue_delay_p50_ms", "mean_occupancy",
                  "throughput_rps"):
            assert k in snap, k
        assert snap["completed"] == snap["converged"] == 1
        assert snap["latency_p50_ms"] > 0
        assert 0 < snap["mean_occupancy"] <= 1
        assert sched.stats.requests[0]["outcome"] == "converged"

    def test_result_timeout_when_not_polled(self, pool):
        sched = ContinuousScheduler()
        t = sched.submit(REGISTRY["BFS"](), pool[0], CFG)
        with pytest.raises(TimeoutError):
            t.result(timeout=0.01)


class TestLMDemoRelocation:
    def test_old_entry_point_forwards_with_deprecation(self, monkeypatch):
        from repro.launch import lm_demo, serve
        called = {}
        monkeypatch.setattr(lm_demo, "main",
                            lambda argv: called.setdefault("argv", argv))
        with pytest.warns(DeprecationWarning, match="lm_demo"):
            serve.main(["--arch", "starcoder2-7b", "--gen", "1"])
        assert called["argv"] == ["--arch", "starcoder2-7b", "--gen", "1"]

    def test_lm_demo_importable(self):
        from repro.launch import lm_demo
        assert callable(lm_demo.main)
