"""All six applications vs. numpy oracles, across the config design space."""
import jax
import numpy as np
import pytest

from repro.algorithms import bc, cc, coloring, mis, pagerank, sssp
from repro.algorithms.reference import (bc_np, cc_np,
                                        is_maximal_independent_set,
                                        is_proper_coloring, pagerank_np,
                                        sssp_np)
from repro.core import STATIC_CONFIGS, SystemConfig, run

# a representative spread of the design space (full grid in benchmarks);
# since the ISSUE-6 port every app also runs the dynamic cells
CONFIGS = ["TG0", "SG0", "SG1", "SGR", "SD1", "SDR", "DG1", "DD1"]


class TestPageRank:
    @pytest.mark.parametrize("cfg", CONFIGS)
    def test_matches_oracle(self, small_graph, cfg):
        r = run(pagerank(), small_graph, SystemConfig.from_name(cfg))
        got = np.asarray(r.extract(pagerank()))
        assert np.abs(got - pagerank_np(small_graph)).max() < 1e-4
        assert r.converged

    def test_all_12_static_configs_agree(self, tiny_graph):
        outs = [np.asarray(run(pagerank(), tiny_graph, c).state["rank"])
                for c in STATIC_CONFIGS]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-5)

    def test_rank_sums_to_one(self, small_graph):
        r = run(pagerank(), small_graph, SystemConfig.from_name("SGR"))
        assert float(np.asarray(r.state["rank"]).sum()) == pytest.approx(
            1.0, abs=1e-3)


class TestSSSP:
    @pytest.mark.parametrize("cfg", CONFIGS)
    def test_matches_oracle(self, small_graph, cfg):
        r = run(sssp(), small_graph, SystemConfig.from_name(cfg))
        got = np.asarray(r.state["dist"])
        ref = sssp_np(small_graph)
        mask = np.isfinite(ref)
        assert np.allclose(got[mask], ref[mask], atol=1e-4)
        assert np.array_equal(np.isfinite(got), mask)


class TestMIS:
    @pytest.mark.parametrize("cfg", ["TG0", "SGR", "SD1", "DD1"])
    def test_is_maximal_independent(self, small_graph, cfg):
        r = run(mis(), small_graph, SystemConfig.from_name(cfg),
                key=jax.random.key(5))
        member = np.asarray(r.extract(mis()))
        assert is_maximal_independent_set(small_graph, member)

    def test_deterministic_given_key(self, small_graph):
        a = run(mis(), small_graph, SystemConfig.from_name("SGR"),
                key=jax.random.key(1))
        b = run(mis(), small_graph, SystemConfig.from_name("SDR"),
                key=jax.random.key(1))
        np.testing.assert_array_equal(np.asarray(a.state["status"]),
                                      np.asarray(b.state["status"]))


class TestColoring:
    @pytest.mark.parametrize("cfg", ["TG0", "SGR", "SD1", "DD1"])
    def test_proper_coloring(self, small_graph, cfg):
        r = run(coloring(), small_graph, SystemConfig.from_name(cfg))
        color = np.asarray(r.extract(coloring()))
        assert is_proper_coloring(small_graph, color)


class TestBC:
    @pytest.mark.parametrize("cfg", ["TG0", "SGR", "SD1", "DD1"])
    def test_matches_brandes(self, small_graph, cfg):
        r = run(bc(), small_graph, SystemConfig.from_name(cfg))
        got = np.asarray(r.extract(bc()))
        ref = bc_np(small_graph)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


class TestCC:
    @pytest.mark.parametrize("cfg", ["DG0", "DG1", "DGR", "DD0", "DD1",
                                     "DDR"])
    def test_matches_components(self, small_graph, cfg):
        r = run(cc(), small_graph, SystemConfig.from_name(cfg))
        np.testing.assert_array_equal(np.asarray(r.state["label"]),
                                      cc_np(small_graph))

    def test_disconnected(self):
        from repro.graph import regular_graph
        import numpy as np
        from repro.graph.structure import Graph
        # two disjoint cliques
        src = np.array([0, 1, 2, 0, 1, 2, 5, 6, 7, 5, 6, 7])
        dst = np.array([1, 2, 0, 2, 0, 1, 6, 7, 5, 7, 5, 6])
        g = Graph.from_coo(src, dst, 10, symmetrize=True, block_size=4)
        r = run(cc(), g, SystemConfig.from_name("DD1"))
        lab = np.asarray(r.state["label"])
        assert lab[0] == lab[1] == lab[2] == 0
        assert lab[5] == lab[6] == lab[7] == 5
        assert lab[3] == 3 and lab[4] == 4 and lab[8] == 8 and lab[9] == 9


class TestPallasPath:
    """use_pallas routes the owned configs through the blocked kernel."""

    @pytest.mark.parametrize("prog,oracle,key", [
        (pagerank, pagerank_np, "rank"), (sssp, sssp_np, "dist")])
    def test_owned_kernel_path(self, tiny_graph, prog, oracle, key):
        r = run(prog(), tiny_graph, SystemConfig.from_name("SDR"),
                use_pallas=True)
        got = np.asarray(r.state[key])
        ref = oracle(tiny_graph)
        mask = np.isfinite(ref)
        assert np.allclose(got[mask], ref[mask], atol=1e-4)
