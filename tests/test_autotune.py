"""Degree-aware autotuner: every candidate plan is a pure perf choice.

Covers the ISSUE-4 contract: (1) every candidate ``(tile_e, block
coarsening/refinement)`` plan in the tuner's grid produces bit-identical
``BlockedSegmentReducer.sum/min/max`` results vs the pure-jnp oracles on
random degree-skewed graphs (integer-valued float32 inputs make every
summation order exact, so "bit-identical" is meaningful for sum too);
(2) tuned plans persist to the degree-signature-keyed JSON cache and a
structurally similar graph recalls them without re-measuring; (3) the
``run(..., autotune=)`` knob changes timing only, never results; (4) the
plan cache exposes per-kind hit/miss counters.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.kernels.autotune as at
from repro.core import ALL_CONFIGS, SystemConfig, run
from repro.core.executor import STATS, EdgeContext
from repro.core.plan_cache import PLAN_CACHE
from repro.graph import powerlaw_graph, regular_graph
from repro.kernels.autotune import (autotune_plan, build_reducer,
                                    candidate_plans, degree_features,
                                    degree_signature, load_disk_cache,
                                    store_disk_entry, suggest_plan, tune)
from repro.kernels.segment_reduce import (DEFAULT_PLAN,
                                          BlockedSegmentReducer, TilingPlan,
                                          coarsen_block_ptr,
                                          gathered_segment_reduce,
                                          gathered_segment_reduce_ref,
                                          segment_max_ref, segment_min_ref,
                                          segment_sum_ref)
from repro.kernels.segment_reduce.kernel import plan_tiles

_REFS = {"sum": segment_sum_ref, "min": segment_min_ref,
         "max": segment_max_ref}


def _order_ids(g, order):
    if order == "owned":
        return np.asarray(g.dst)[np.asarray(g.perm_owned)]
    return np.asarray(g.dst_in)


class TestCandidatePlansBitIdentical:
    """The tuner may only ever trade time, never bits."""

    @given(st.integers(0, 900), st.sampled_from([1.2, 1.8, 2.4]))
    @settings(max_examples=3, deadline=None)
    def test_every_candidate_matches_oracle(self, seed, alpha):
        g = powerlaw_graph(220, 2200, alpha=alpha, seed=seed,
                           block_size=64)
        rng = np.random.default_rng(seed + 1)
        # integer-valued float32: exact under any accumulation order,
        # so sum results must be bit-identical too, not just close
        vals = jnp.asarray(
            rng.integers(-32, 32, g.n_edges).astype(np.float32))
        feats = degree_features(g)
        for order in ("owned", "pull"):
            ids = jnp.asarray(_order_ids(g, order))
            cands = candidate_plans(features=feats, order=order)
            assert cands[0].astuple() == DEFAULT_PLAN.astuple()
            for kind in ("sum", "min", "max"):
                ref = np.asarray(_REFS[kind](vals, ids, g.n_nodes))
                for plan in cands:
                    red = build_reducer(g, order, plan)
                    got = np.asarray(red.reduce(vals, kind))
                    np.testing.assert_array_equal(
                        got, ref,
                        err_msg=f"{order}/{kind}/{plan.astuple()}")

    @given(st.integers(0, 900), st.sampled_from([1, 2, 3, 4, 7]))
    @settings(max_examples=6, deadline=None)
    def test_gathered_splits_bit_identical(self, seed, splits):
        rng = np.random.default_rng(seed)
        cap, v = 700, 150
        ids = rng.integers(-1, v, cap).astype(np.int32)
        vals = rng.integers(-50, 50, cap).astype(np.float32)
        plan = TilingPlan(gather_splits=splits)
        for kind in ("sum", "min", "max"):
            got = np.asarray(gathered_segment_reduce(
                jnp.asarray(vals), jnp.asarray(ids), v, kind, plan=plan))
            ref = gathered_segment_reduce_ref(vals, ids, v, kind)
            np.testing.assert_array_equal(got, ref, err_msg=f"{kind}")

    def test_coarsened_owned_blocks(self):
        """block_mult>1 candidates (sparse graphs whose blocks underfill
        the smallest tile) are exact — the degree-skewed grid above
        never coarsens, so guard the coarsening path explicitly."""
        g = regular_graph(2048, 2, seed=3, block_size=32)
        feats = degree_features(g)
        cands = candidate_plans(features=feats, order="owned")
        assert any(p.block_mult > 1 for p in cands), \
            "fixture no longer produces coarsening candidates"
        vals = jnp.asarray(np.random.default_rng(1).integers(
            -40, 40, g.n_edges).astype(np.float32))
        ids = jnp.asarray(_order_ids(g, "owned"))
        for plan in cands + (TilingPlan(tile_e=256, block_mult=8),):
            red = build_reducer(g, "owned", plan)
            assert red.block_size == 32 * plan.block_mult
            for kind in ("sum", "min", "max"):
                ref = np.asarray(_REFS[kind](vals, ids, g.n_nodes))
                np.testing.assert_array_equal(
                    np.asarray(red.reduce(vals, kind)), ref,
                    err_msg=f"{kind}/{plan.astuple()}")

    def test_refined_pull_blocks(self):
        """block_div refinement (CSC only) is exact at every division."""
        g = regular_graph(256, 6, seed=9, block_size=128)
        vals = jnp.asarray(np.random.default_rng(0).integers(
            0, 99, g.n_edges).astype(np.float32))
        ids = jnp.asarray(_order_ids(g, "pull"))
        ref = np.asarray(segment_sum_ref(vals, ids, g.n_nodes))
        for div in (1, 2, 4):
            red = build_reducer(g, "pull",
                                TilingPlan(tile_e=256, block_div=div))
            assert red.block_size == 128 // div
            np.testing.assert_array_equal(
                np.asarray(red.sum(vals)), ref)


class TestPlanMechanics:
    def test_plan_validation(self):
        with pytest.raises(ValueError):
            TilingPlan(block_mult=2, block_div=2)
        with pytest.raises(ValueError):
            TilingPlan(tile_e=0)
        # refinement needs per-vertex offsets, not a base block_ptr
        with pytest.raises(ValueError):
            BlockedSegmentReducer.from_plan(
                np.zeros(4, np.int32), np.array([0, 4]), 8, 8,
                TilingPlan(block_div=2))

    def test_source_excluded_from_identity(self):
        assert TilingPlan(tile_e=256, source="disk") == \
            TilingPlan(tile_e=256, source="tuned")

    def test_coarsen_block_ptr(self):
        bp = np.array([0, 3, 3, 10, 12, 20])
        assert coarsen_block_ptr(bp, 1) is bp
        np.testing.assert_array_equal(coarsen_block_ptr(bp, 2),
                                      [0, 3, 12, 20])
        np.testing.assert_array_equal(coarsen_block_ptr(bp, 4),
                                      [0, 12, 20])
        np.testing.assert_array_equal(coarsen_block_ptr(bp, 8), [0, 20])

    def test_plan_tiles_returns_int32(self):
        """Satellite: index arrays upload as int32, not int64 — tuned
        large-tile_e plans must not double index-memory traffic."""
        gather, tbid, tfirst = plan_tiles(np.array([0, 5, 9], np.int64),
                                          tile_e=4)
        assert gather.dtype == np.int32
        assert tbid.dtype == np.int32
        assert tfirst.dtype == np.int32
        red = BlockedSegmentReducer(np.array([0, 0, 1, 1, 2, 3, 3, 4, 5]),
                                    np.array([0, 5, 9]), 6, 3, tile_e=4)
        assert red.gather.dtype == jnp.int32
        assert red.lids.dtype == jnp.int32

    def test_suggest_plan_shapes(self):
        g = powerlaw_graph(500, 5000, alpha=1.8, seed=2)
        feats = degree_features(g)
        owned = suggest_plan(feats, "owned")
        pull = suggest_plan(feats, "pull")
        assert owned.block_div == 1  # owned order cannot refine
        assert pull.block_mult == 1 or pull.block_div == 1
        assert suggest_plan(feats, "gathered") == DEFAULT_PLAN
        for p in (owned, pull):
            assert 128 <= p.tile_e <= 4096


class TestPersistence:
    def test_roundtrip_and_signature_warm_hit(self, tmp_path, monkeypatch):
        """A structurally similar graph (same degree signature) recalls
        the tuned plan from disk without re-measuring."""
        path = tmp_path / "autotune_cache.json"
        g1 = powerlaw_graph(300, 3600, alpha=1.7, seed=11)
        p1 = autotune_plan(g1, order="pull", mode="measure", repeats=1,
                           cache_path=path)
        entries = load_disk_cache(path)
        assert len(entries) == 1
        (key, entry), = entries.items()
        assert degree_signature(g1) in key
        assert (entry["tile_e"], entry["block_mult"], entry["block_div"],
                entry["gather_splits"]) == p1.astuple()

        # same generator family + scale => same signature, new identity
        g2 = powerlaw_graph(300, 3600, alpha=1.7, seed=12)
        assert degree_signature(g2) == degree_signature(g1)

        def boom(*a, **k):  # a disk hit must not measure anything
            raise AssertionError("re-measured despite disk hit")
        monkeypatch.setattr(at, "measure_plan", boom)
        p2 = autotune_plan(g2, order="pull", mode="measure",
                           cache_path=path)
        assert p2.astuple() == p1.astuple()
        assert p2.source == "disk"

    def test_corrupt_cache_is_retuned(self, tmp_path):
        path = tmp_path / "autotune_cache.json"
        path.write_text("{not json")
        assert load_disk_cache(path) == {}
        g = regular_graph(128, 4, seed=5)
        plan = autotune_plan(g, order="owned", mode="measure", repeats=1,
                             cache_path=path)
        assert isinstance(plan, TilingPlan)
        assert load_disk_cache(path)  # rewritten with the fresh entry

    def test_store_merges(self, tmp_path):
        path = tmp_path / "c.json"
        store_disk_entry("a", {"tile_e": 128}, path=path)
        store_disk_entry("b", {"tile_e": 256}, path=path)
        entries = load_disk_cache(path)
        assert set(entries) == {"a", "b"}
        assert json.loads(path.read_text())["version"] == 1

    def test_none_path_disables_disk(self, tmp_path, monkeypatch):
        monkeypatch.setattr(at, "DEFAULT_CACHE_PATH",
                            str(tmp_path / "autotune_cache.json"))
        g = regular_graph(128, 4, seed=6)
        plan = autotune_plan(g, order="owned", mode="measure", repeats=1,
                             cache_path=None)
        assert isinstance(plan, TilingPlan)
        assert not (tmp_path / "autotune_cache.json").exists()


class TestPlanCacheKinds:
    def test_per_kind_counters(self, tmp_path):
        PLAN_CACHE.clear()
        g = regular_graph(128, 4, seed=7)
        path = tmp_path / "c.json"
        autotune_plan(g, order="owned", mode="measure", repeats=1,
                      cache_path=path)
        autotune_plan(g, order="owned", mode="measure", repeats=1,
                      cache_path=path)
        stats = PLAN_CACHE.stats()
        assert stats["by_kind"]["tuned_tiling"] == {
            "hits": 1, "misses": 1, "entries": 1}
        # observable through the executor's stats facade too
        assert STATS.plan_cache()["by_kind"]["tuned_tiling"]["hits"] == 1

    def test_clear_resets_kind_counters(self):
        PLAN_CACHE.clear()
        assert PLAN_CACHE.stats()["by_kind"] == {}


class TestExecutorKnob:
    @pytest.fixture(scope="class")
    def graph(self):
        return powerlaw_graph(260, 2600, alpha=1.6, seed=4, weighted=True)

    def _tmp_cache(self, monkeypatch, tmp_path):
        monkeypatch.setattr(at, "DEFAULT_CACHE_PATH",
                            str(tmp_path / "autotune_cache.json"))

    @pytest.mark.parametrize("cfg", ["DD1", "TD0", "SDR"])
    @pytest.mark.parametrize("mode", ["heuristic", "measure"])
    def test_results_invariant_under_autotune(self, graph, cfg, mode,
                                              monkeypatch, tmp_path):
        """Tiling is a perf choice: states, iterations and traces are
        bit-identical with the knob off or on."""
        self._tmp_cache(monkeypatch, tmp_path)
        from repro.algorithms import REGISTRY
        prog = REGISTRY["BFS"]()
        base = run(prog, graph, SystemConfig.from_name(cfg),
                   use_pallas=True)
        tuned = run(prog, graph, SystemConfig.from_name(cfg),
                    use_pallas=True, autotune=mode)
        assert base.iterations == tuned.iterations
        assert base.direction_trace == tuned.direction_trace
        assert base.occupancy_trace == tuned.occupancy_trace
        np.testing.assert_array_equal(np.asarray(base.state["depth"]),
                                      np.asarray(tuned.state["depth"]))

    def test_autotuned_context_is_a_distinct_cell(self, graph,
                                                  monkeypatch, tmp_path):
        """autotune= is part of the context AND exec-fn cache keys: a
        tuned context must never reuse the default context's compiled
        runner (which closes over the default reducers)."""
        self._tmp_cache(monkeypatch, tmp_path)
        cfg = SystemConfig.from_name("TD0")
        base = EdgeContext.create(graph, cfg, use_pallas=True)
        heur = EdgeContext.create(graph, cfg, use_pallas=True,
                                  autotune="heuristic")
        assert base is not heur
        # block_size=256 guarantees the pull heuristic refines blocks,
        # so the resolved plans — and the exec-fn key — must differ
        assert heur.plan_signature != base.plan_signature
        assert heur is EdgeContext.create(graph, cfg, use_pallas=True,
                                          autotune="heuristic")

    def test_bad_mode_raises(self, graph):
        from repro.algorithms import REGISTRY
        with pytest.raises(ValueError, match="autotune"):
            run(REGISTRY["BFS"](), graph, SystemConfig.from_name("SG0"),
                autotune="turbo")

    def test_tune_never_beats_nothing(self, graph):
        """The default plan is always swept, so the winner is never
        slower than the static tiling on the tuner's own numbers."""
        r = tune(graph, order="pull", repeats=2)
        assert any(p.astuple() == DEFAULT_PLAN.astuple()
                   for p, _ in r.measurements)
        assert r.best_seconds <= r.default_seconds
        assert r.speedup_vs_default >= 1.0


class TestFreshCheckout:
    """The autotune disk cache must work from a fresh checkout (no
    results/ directory yet) and must never crash a run when the cache
    path is unwritable — persistence is an optimization, not a
    dependency."""

    def _measured_run(self, graph, monkeypatch, tmp_path, cache_rel):
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(at, "DEFAULT_CACHE_PATH", cache_rel)
        from repro.algorithms import REGISTRY
        return run(REGISTRY["BFS"](), graph,
                   SystemConfig.from_name("TD0"), use_pallas=True,
                   autotune="measure")

    def test_no_results_dir_is_created(self, monkeypatch, tmp_path):
        """Fresh checkout: results/ does not exist; a measured run must
        create it and persist the tuned plan."""
        g = powerlaw_graph(220, 2200, alpha=1.6, seed=9, weighted=True)
        assert not (tmp_path / "results").exists()
        r = self._measured_run(g, monkeypatch, tmp_path,
                               "results/autotune_cache.json")
        assert r.converged
        cache = tmp_path / "results" / "autotune_cache.json"
        assert cache.exists()
        assert load_disk_cache(cache)  # at least one persisted entry

    def test_unwritable_cache_path_does_not_crash(self, monkeypatch,
                                                  tmp_path):
        """`results` existing as a plain *file* makes the cache dir
        uncreatable; the run must still succeed, skipping persistence."""
        g = powerlaw_graph(220, 2200, alpha=1.6, seed=10, weighted=True)
        (tmp_path / "results").write_text("not a directory")
        r = self._measured_run(g, monkeypatch, tmp_path,
                               "results/autotune_cache.json")
        assert r.converged
        assert (tmp_path / "results").is_file()  # untouched
