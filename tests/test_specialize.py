"""The learned best-config specializer (ISSUE 10).

Covers the tentpole contract: the committed model/artifact satisfy the
acceptance invariants (learned accuracy >= the static partial tree on
the committed matrix), the model file round-trips with a versioned
header and rejects wrong versions/corrupt payloads, the serving
fallback chain (learned -> static partial -> caller) degrades with a
structured :class:`SpecializeFallbackWarning` and never crashes,
resolution is cached per graph identity (plan cache) and per degree
signature (memo) so repeat admission re-profiles nothing, and the
``specialize=`` knob threads through ``run``/``run_batch`` and the
gateway with the chosen source stamped on the result.
"""
import json
import warnings
from pathlib import Path

import pytest

from repro.algorithms import REGISTRY
from repro.core import PLAN_CACHE, SystemConfig, run, run_batch
from repro.core import specialize_learned as sl
from repro.graph import grid_graph, rmat_graph
from repro.launch.serve import ContinuousScheduler

ROOT = Path(__file__).resolve().parent.parent
# the committed trio: the baseline matrix is the training set the
# committed model was fitted on (results/BENCH_*.json are gitignored
# run outputs; only these and the model file exist on a fresh checkout)
MATRIX = ROOT / "results" / "baselines" / "BENCH_matrix.json"
ARTIFACT = ROOT / "results" / "baselines" / "BENCH_specialize.json"
MODEL = ROOT / "results" / "specialize_model.json"
CFG = SystemConfig.from_name("TG0")


@pytest.fixture(autouse=True)
def _fresh_memo():
    sl.clear_memo()
    yield
    sl.clear_memo()


def _fit():
    return sl.fit_matrix(json.loads(MATRIX.read_text()))


class TestCommittedModelAccuracy:
    """The acceptance invariants, pinned on the committed artifacts."""

    def test_artifact_gate_invariants_hold(self):
        art = json.loads(ARTIFACT.read_text())
        assert art["gate"]["accuracy_ge_partial"] is True
        assert art["gate"]["e2e_ge_best_always"] is True
        acc = art["accuracy"]
        assert acc["learned_tol"] >= acc["static_partial_tol"]
        assert acc["learned"] >= acc["static_partial"]
        assert art["e2e"]["speedup_vs_best_always"] >= 1.0

    def test_committed_model_matches_committed_matrix(self):
        """Refitting on the committed matrix reproduces the committed
        model's predictions (deterministic training, no drift between
        the two checked-in files)."""
        fresh = _fit()
        committed = sl.load_model(MODEL)
        rows = sl.training_table(json.loads(MATRIX.read_text()))
        assert committed.classes == fresh.classes
        for r in rows:
            assert committed.predict_name(r.features) \
                == fresh.predict_name(r.features)

    def test_training_accuracy_beats_partial_tree(self):
        model = _fit()
        art = json.loads(ARTIFACT.read_text())
        assert model.meta["training_accuracy"] \
            >= art["accuracy"]["static_partial"]


class TestModelFile:
    def test_roundtrip(self, tmp_path):
        model = _fit()
        path = sl.save_model(model, tmp_path / "m.json")
        loaded = sl.load_model(path)
        assert loaded.features == model.features
        assert loaded.classes == model.classes
        rows = sl.training_table(json.loads(MATRIX.read_text()))
        for r in rows:
            assert loaded.predict_name(r.features) \
                == model.predict_name(r.features)

    def test_wrong_version_rejected(self, tmp_path):
        data = _fit().to_json()
        data["version"] = sl.MODEL_VERSION + 1
        p = tmp_path / "m.json"
        p.write_text(json.dumps(data))
        with pytest.raises(sl.ModelFileError) as ei:
            sl.load_model(p)
        assert ei.value.code == "model_version"

    def test_corrupt_payloads_rejected(self, tmp_path):
        p = tmp_path / "m.json"
        for payload in ('{"format": tru', '{"format": "nope"}', "[]",
                        json.dumps({"format": sl.MODEL_FORMAT,
                                    "version": sl.MODEL_VERSION,
                                    "features": [], "classes": ["ZZZ"],
                                    "tree": {}})):
            p.write_text(payload)
            with pytest.raises(sl.ModelFileError) as ei:
                sl.load_model(p)
            assert ei.value.code in ("model_corrupt",)

    def test_missing_file_is_oserror(self, tmp_path):
        with pytest.raises(OSError):
            sl.load_model(tmp_path / "absent.json")


class TestFallbackChain:
    """learned -> static partial -> caller, warning per hop, no crash."""

    def _resolve(self, model_path, graph=None):
        g = graph if graph is not None else rmat_graph(5, 8, seed=11)
        return sl.resolve_config(REGISTRY["BFS"](), g, CFG, "learned",
                                 model_path=model_path)

    def test_missing_model_falls_back_to_partial(self, tmp_path):
        with pytest.warns(sl.SpecializeFallbackWarning,
                          match="code=model_missing"):
            config, source = self._resolve(tmp_path / "absent.json")
        assert source == "static_partial"
        assert isinstance(config, SystemConfig)
        # BFS is DYNAMIC-traversal: both static trees say DD1
        assert config.name == "DD1"

    def test_corrupt_model_falls_back_to_partial(self, tmp_path):
        p = tmp_path / "m.json"
        p.write_text("{not json")
        with pytest.warns(sl.SpecializeFallbackWarning,
                          match="code=model_corrupt"):
            _, source = self._resolve(p)
        assert source == "static_partial"

    def test_wrong_version_falls_back_to_partial(self, tmp_path):
        data = _fit().to_json()
        data["version"] = 999
        p = tmp_path / "m.json"
        p.write_text(json.dumps(data))
        with pytest.warns(sl.SpecializeFallbackWarning,
                          match="code=model_version"):
            _, source = self._resolve(p)
        assert source == "static_partial"

    def test_no_properties_keeps_caller_config(self, tmp_path):
        class Anon:
            name = "not-a-registered-app"
        with pytest.warns(sl.SpecializeFallbackWarning,
                          match="code=no_properties"):
            config, source = sl.resolve_config(
                Anon(), rmat_graph(5, 8, seed=12), CFG, "learned",
                model_path=MODEL)
        assert (config, source) == (CFG, "caller")

    def test_off_is_untouched_and_silent(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            for mode in (None, False, "off"):
                config, source = sl.resolve_config(
                    REGISTRY["BFS"](), rmat_graph(5, 8, seed=13), CFG,
                    mode)
                assert (config, source) == (CFG, "caller")

    def test_unknown_mode_raises(self):
        with pytest.raises(ValueError, match="specialize"):
            sl.resolve_config(REGISTRY["BFS"](),
                              rmat_graph(5, 8, seed=14), CFG, "bogus")

    def test_learned_uses_committed_model(self):
        config, source = self._resolve(MODEL)
        assert source == "learned"
        assert config.name in sl.load_model(MODEL).classes

    def test_predicted_config_inherits_caller_chunks(self):
        caller = SystemConfig.from_name("TG0", n_chunks=4)
        config, _ = sl.resolve_config(REGISTRY["BFS"](),
                                      rmat_graph(5, 8, seed=15), caller,
                                      "learned", model_path=MODEL)
        assert config.n_chunks == 4


class TestResolutionCaching:
    def test_plan_cache_hit_on_repeat_same_graph(self):
        g = rmat_graph(6, 8, seed=21)
        before = PLAN_CACHE.stats()["by_kind"].get(
            "specialized_config", {"hits": 0})["hits"]
        first = sl.resolve_config(REGISTRY["BFS"](), g, CFG, "learned",
                                  model_path=MODEL)
        second = sl.resolve_config(REGISTRY["BFS"](), g, CFG, "learned",
                                   model_path=MODEL)
        assert first == second
        after = PLAN_CACHE.stats()["by_kind"]["specialized_config"]["hits"]
        assert after >= before + 1

    def test_signature_memo_hit_on_fresh_same_shape_graph(self):
        """A *new* graph object with an already-decided degree
        signature reuses the decision without re-profiling (the plan
        cache, keyed on identity, cannot serve this case)."""
        sl.resolve_config(REGISTRY["BFS"](), rmat_graph(6, 8, seed=22),
                          CFG, "learned", model_path=MODEL)
        assert sl.memo_stats()["misses"] >= 1
        hits_before = sl.memo_stats()["hits"]
        sl.resolve_config(REGISTRY["BFS"](), rmat_graph(6, 8, seed=22),
                          CFG, "learned", model_path=MODEL)
        assert sl.memo_stats()["hits"] == hits_before + 1

    def test_fallback_decision_is_cached_too(self, tmp_path):
        """The static-partial fallback is memoized like a prediction:
        repeat admission warns once, not per request."""
        g = rmat_graph(6, 8, seed=23)
        absent = tmp_path / "absent.json"
        with pytest.warns(sl.SpecializeFallbackWarning):
            sl.resolve_config(REGISTRY["BFS"](), g, CFG, "learned",
                              model_path=absent)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            _, source = sl.resolve_config(REGISTRY["BFS"](), g, CFG,
                                          "learned", model_path=absent)
        assert source == "static_partial"


class TestServingIntegration:
    def test_run_stamps_source_and_matches_off(self):
        g = grid_graph(6, seed=0)
        prog = REGISTRY["BFS"]()
        off = run(prog, g, CFG, specialize="off")
        assert (off.config_name, off.config_source) == ("TG0", "caller")
        res = run(prog, g, CFG, specialize="learned")
        assert res.config_source == "learned"
        assert res.config_name is not None
        # resolved config actually ran: rerunning it explicitly matches
        direct = run(prog, g, SystemConfig.from_name(res.config_name))
        assert res.iterations == direct.iterations

    def test_run_static_uses_full_tree(self):
        res = run(REGISTRY["BFS"](), grid_graph(6, seed=0), CFG,
                  specialize="static")
        assert res.config_source == "static"
        assert res.config_name == "DD1"  # DYNAMIC traversal -> DD1

    def test_run_batch_stamps_per_graph(self):
        gs = [rmat_graph(5, 8, seed=1), grid_graph(7, seed=0)]
        results = run_batch(REGISTRY["BFS"](), gs, CFG,
                            specialize="learned")
        assert len(results) == 2
        for r in results:
            assert r.config_source == "learned"
            assert r.config_name is not None

    def test_gateway_resolves_at_admission(self):
        g = rmat_graph(5, 8, seed=31)
        prog = REGISTRY["BFS"]()
        sched = ContinuousScheduler(max_batch=2, slice_len=3)
        t1 = sched.submit(prog, g, CFG, specialize="learned")
        assert t1.config_source == "learned"
        assert sched.stats.snapshot()["specialized"] == 1
        hits_before = PLAN_CACHE.stats()["by_kind"][
            "specialized_config"]["hits"]
        t2 = sched.submit(prog, g, CFG, specialize="learned")
        assert PLAN_CACHE.stats()["by_kind"][
            "specialized_config"]["hits"] >= hits_before + 1
        sched.run_until_idle()
        for t in (t1, t2):
            res = t.result(timeout=1)
            assert res.config_source == "learned"
            assert res.config_name == t1.config.name

    def test_gateway_off_does_not_count_specialized(self):
        sched = ContinuousScheduler(max_batch=2, slice_len=3)
        t = sched.submit(REGISTRY["BFS"](), rmat_graph(5, 8, seed=32),
                         CFG)
        sched.run_until_idle()
        assert sched.stats.snapshot()["specialized"] == 0
        assert t.result(timeout=1).config_source == "caller"


class TestProjectConfig:
    def test_exact_name_wins(self):
        assert sl.project_config("TG0", ["TG0", "SG1"]) == "TG0"

    def test_same_direction_minimizes_axis_mismatch(self):
        # SDR (push, DeNovo, DRFrlx) projected onto push cells: SD1
        # shares coherence (one consistency hop) and beats SG1 (two)
        assert sl.project_config("SDR", ["TG0", "SG1", "SD1"]) == "SD1"
        assert sl.project_config("SDR", ["TG0", "SG1"]) == "SG1"

    def test_no_same_direction_falls_back_to_first_sorted(self):
        assert sl.project_config("SG1", ["TG0", "DD1"]) == "DD1"
