"""Training substrate: checkpoint roundtrip/atomicity, async writer,
elastic resharding, trainer loop with retry/straggler, data pipeline."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ShardedPipeline
from repro.data.synthetic import lm_batch
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.fault_tolerance import (ElasticMesh, PreemptionGuard,
                                         StragglerPolicy,
                                         run_step_with_retry)
from repro.train.trainer import TrainLoopConfig, train_loop


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"w": jnp.arange(10, dtype=jnp.int32),
                  "s": jnp.float32(3.5)}}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_checkpoint(tmp_path, 7, t, extra={"note": "x"})
        like = jax.tree.map(jnp.zeros_like, t)
        restored, step, extra = restore_checkpoint(tmp_path, like)
        assert step == 7 and extra == {"note": "x"}
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_multiple(self, tmp_path):
        for s in (1, 5, 3):
            save_checkpoint(tmp_path, s, _tree())
        assert latest_step(tmp_path) == 5

    def test_no_partial_visible(self, tmp_path):
        # only atomically renamed step dirs count
        (tmp_path / ".tmp_step_00000009").mkdir()
        save_checkpoint(tmp_path, 2, _tree())
        assert latest_step(tmp_path) == 2

    def test_async(self, tmp_path):
        ck = AsyncCheckpointer(tmp_path)
        ck.save(1, _tree())
        ck.save(2, _tree(1))  # waits for previous
        ck.wait()
        assert latest_step(tmp_path) == 2

    def test_restore_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(tmp_path, _tree())


class TestElastic:
    def test_mesh_shrinks(self):
        em = ElasticMesh(model_parallel=1)
        mesh = em.build(jax.devices())
        assert mesh.shape["data"] == len(jax.devices())

    def test_reshard_roundtrip(self):
        em = ElasticMesh(model_parallel=1)
        mesh = em.build()
        t = _tree()
        t2 = em.reshard(t, mesh, None)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFaultTolerance:
    def test_retry_then_succeed(self):
        calls = {"n": 0}

        def flaky(x):
            calls["n"] += 1
            if calls["n"] < 3:
                raise jax.errors.JaxRuntimeError("transient link flap")
            return x + 1

        out = run_step_with_retry(flaky, 1, max_retries=5, backoff_s=0.0)
        assert out == 2 and calls["n"] == 3

    def test_retry_exhausted(self):
        def always(x):
            raise jax.errors.JaxRuntimeError("dead")

        with pytest.raises(jax.errors.JaxRuntimeError):
            run_step_with_retry(always, 1, max_retries=2, backoff_s=0.0)

    def test_straggler_detection(self):
        sp = StragglerPolicy(window=16, threshold=2.0, patience=2)
        for _ in range(10):
            v = sp.observe(1.0)
        assert not v["slow"]
        v = sp.observe(5.0)
        assert v["slow"] and not v["redispatch"]
        v = sp.observe(5.0)
        assert v["redispatch"]

    def test_preemption_guard_flag(self):
        g = PreemptionGuard(signals=())
        assert not g.preempted
        g._handler(None, None)
        assert g.preempted


class TestPipeline:
    def test_ordered_and_deterministic(self):
        p = ShardedPipeline(lambda s: lm_batch(s, 2, 8, 100), depth=2)
        got = [next(p) for _ in range(4)]
        p.close()
        assert [s for s, _ in got] == [0, 1, 2, 3]
        again = lm_batch(2, 2, 8, 100)
        np.testing.assert_array_equal(got[2][1]["tokens"], again["tokens"])


class TestTrainLoop:
    def _setup(self):
        cfg_dim = 16

        def loss_fn(p, b):
            pred = b["x"] @ p["w"]
            return jnp.mean((pred - b["y"]) ** 2)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            p, o, gn = adamw_update(grads, opt_state, params,
                                    AdamWConfig(lr=1e-2))
            return p, o, {"loss": loss}

        def make_batch(s):
            rng = np.random.default_rng(s)
            x = rng.standard_normal((8, cfg_dim)).astype(np.float32)
            return {"x": x, "y": (x.sum(1, keepdims=True) * 0.1)}

        params = {"w": jnp.zeros((cfg_dim, 1), jnp.float32)}
        return step, params, make_batch

    def test_loss_decreases_and_resumes(self, tmp_path):
        step, params, make_batch = self._setup()
        cfg = TrainLoopConfig(total_steps=30, checkpoint_every=10,
                              checkpoint_dir=str(tmp_path))
        p1, o1, hist = train_loop(step, params, make_batch, cfg)
        assert hist[-1]["loss"] < hist[0]["loss"]
        # resume from checkpoint: picks up after the last saved step and
        # continues to the new horizon
        cfg2 = TrainLoopConfig(total_steps=45, checkpoint_every=10,
                               checkpoint_dir=str(tmp_path))
        p2, o2, hist2 = train_loop(step, params, make_batch, cfg2)
        assert hist2[0]["step"] == 30
        assert hist2[-1]["step"] == 44
