"""End-to-end behaviour: the specialization model drives the executor over
real (synthetic-recreation) inputs — the paper's full loop."""
import jax
import numpy as np
import pytest

from repro.algorithms import REGISTRY
from repro.algorithms.reference import (cc_np, is_maximal_independent_set,
                                        is_proper_coloring, pagerank_np,
                                        sssp_np)
from repro.core import run, specialize
from repro.core.taxonomy import profile_graph
from repro.graph.datasets import paper_graph


@pytest.mark.parametrize("gname", ["DCT", "RAJ"])
@pytest.mark.parametrize("app", ["PR", "SSSP", "CC"])
def test_specialized_execution_matches_oracle(gname, app):
    """profile -> specialize -> execute -> verify, end to end."""
    g = paper_graph(gname, scale=32, weighted=(app == "SSSP"))
    profile = profile_graph(g)
    program = REGISTRY[app]()
    config = specialize(program.properties, profile)
    res = run(program, g, config, key=jax.random.key(0))
    assert res.converged
    if app == "PR":
        np.testing.assert_allclose(np.asarray(res.state["rank"]),
                                   pagerank_np(g), atol=1e-4)
    elif app == "SSSP":
        ref = sssp_np(g)
        got = np.asarray(res.state["dist"])
        mask = np.isfinite(ref)
        assert np.allclose(got[mask], ref[mask], atol=1e-3)
    else:
        np.testing.assert_array_equal(np.asarray(res.state["label"]),
                                      cc_np(g))


def test_predicted_config_is_competitive():
    """The model-predicted config is within a reasonable factor of the
    empirical best on a real measurement (paper: within 3.5%; we allow
    2x on CPU where constant factors differ from the simulated GPU)."""
    from repro.core import ALL_CONFIGS
    g = paper_graph("RAJ", scale=32)
    program = REGISTRY["PR"]()
    profile = profile_graph(g)
    predicted = specialize(program.properties, profile)
    times = {}
    for cfg in [predicted] + [c for c in ALL_CONFIGS
                              if c.prop.value != "D"][:6]:
        r = run(program, g, cfg, max_iters=30)
        times[cfg.name] = r.seconds
    best = min(times.values())
    assert times[predicted.name] <= 2.5 * best, times


def test_quickstart_example_runs():
    import subprocess
    import sys
    from pathlib import Path
    repo = Path(__file__).resolve().parent.parent
    env = {"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env})
    out = subprocess.run([sys.executable, str(repo / "examples" /
                                              "quickstart.py")],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "converged" in out.stdout
