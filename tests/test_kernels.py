"""Per-kernel shape/dtype sweeps vs. the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.embedding_bag import embedding_bag_pallas, embedding_bag_ref
from repro.kernels.flash_attention import flash_attention, gqa_ref
from repro.kernels.segment_reduce import (BlockedSegmentReducer,
                                          segment_max_ref, segment_min_ref,
                                          segment_sum_ref)


def _binned(rng, e, v, b):
    raw = rng.integers(0, v, e)
    order = np.argsort(raw // b, kind="stable")
    ids = raw[order]
    bp = np.zeros((v + b - 1) // b + 1, np.int64)
    np.add.at(bp, raw // b + 1, 1)
    return ids, np.cumsum(bp)


class TestSegmentReduce:
    @pytest.mark.parametrize("e,v,b,d", [
        (1000, 300, 64, 1), (4096, 512, 128, 8), (777, 100, 32, 5),
        (64, 512, 128, 1),   # sparser than segments
        (2048, 64, 64, 16),  # single block
    ])
    @pytest.mark.parametrize("kind", ["sum", "min", "max"])
    def test_matches_oracle(self, e, v, b, d, kind):
        rng = np.random.default_rng(e + v)
        ids, bp = _binned(rng, e, v, b)
        vals = rng.standard_normal((e, d)).astype(np.float32)
        x = jnp.asarray(vals if d > 1 else vals[:, 0])
        red = BlockedSegmentReducer(ids, bp, v, b, tile_e=256)
        got = np.asarray(red.reduce(x, kind))
        ref_fn = {"sum": segment_sum_ref, "min": segment_min_ref,
                  "max": segment_max_ref}[kind]
        ref = np.asarray(ref_fn(x, jnp.asarray(ids), v))
        np.testing.assert_allclose(got, ref, atol=1e-4)

    def test_int32_min(self):
        rng = np.random.default_rng(0)
        ids, bp = _binned(rng, 500, 200, 64)
        vals = jnp.asarray(rng.integers(0, 10**6, 500).astype(np.int32))
        red = BlockedSegmentReducer(ids, bp, 200, 64)
        got = np.asarray(red.min(vals))
        ref = np.asarray(segment_min_ref(vals, jnp.asarray(ids), 200))
        np.testing.assert_array_equal(got, ref)

    @pytest.mark.parametrize("kind", ["sum", "min", "max"])
    def test_masked_matches_filtered_oracle(self, kind):
        """masked() == reducing only the surviving edges: the predicate
        entry point used by both the push/owned and pull/CSC paths."""
        rng = np.random.default_rng(42)
        e, v, b = 800, 256, 64
        ids, bp = _binned(rng, e, v, b)
        vals = rng.standard_normal(e).astype(np.float32)
        mask = rng.random(e) < 0.6
        red = BlockedSegmentReducer(ids, bp, v, b)
        got = np.asarray(red.masked(jnp.asarray(vals), jnp.asarray(mask),
                                    kind))
        ident = float(BlockedSegmentReducer.identity(kind, np.float32))
        ref_fn = {"sum": segment_sum_ref, "min": segment_min_ref,
                  "max": segment_max_ref}[kind]
        ref = np.asarray(ref_fn(jnp.where(jnp.asarray(mask),
                                          jnp.asarray(vals), ident),
                                jnp.asarray(ids), v))
        np.testing.assert_allclose(got, ref, atol=1e-4)

    def test_pull_order_sorted_ids(self):
        """CSC (sorted-dst) edge order is trivially block-binned — the
        pull-side fast path needs no extra permutation."""
        rng = np.random.default_rng(7)
        e, v, b = 600, 128, 32
        ids = np.sort(rng.integers(0, v, e))
        bp = np.zeros(v // b + 1, np.int64)
        np.add.at(bp, ids // b + 1, 1)
        bp = np.cumsum(bp)
        vals = rng.standard_normal(e).astype(np.float32)
        red = BlockedSegmentReducer(ids, bp, v, b)
        got = np.asarray(red.sum(jnp.asarray(vals)))
        ref = np.asarray(segment_sum_ref(jnp.asarray(vals),
                                         jnp.asarray(ids), v))
        np.testing.assert_allclose(got, ref, atol=1e-4)

    @given(st.integers(1, 2000), st.integers(16, 400), st.integers(0, 3))
    @settings(max_examples=8, deadline=None)
    def test_sum_property(self, e, v, seed):
        rng = np.random.default_rng(seed)
        b = 64
        ids, bp = _binned(rng, e, v, b)
        vals = rng.standard_normal(e).astype(np.float32)
        red = BlockedSegmentReducer(ids, bp, v, b)
        got = np.asarray(red.sum(jnp.asarray(vals)))
        # total mass is conserved
        assert got.sum() == pytest.approx(vals.sum(), rel=1e-3, abs=1e-3)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,sk,d,causal", [
        (1, 2, 2, 128, 128, 64, True),
        (2, 4, 2, 256, 256, 64, True),
        (1, 8, 2, 128, 256, 128, True),   # GQA + kv longer than q
        (1, 2, 1, 64, 64, 32, False),
    ])
    def test_matches_ref(self, b, hq, hkv, sq, sk, d, causal):
        rng = np.random.default_rng(b + sq)
        q = jnp.asarray(rng.standard_normal((b, hq, sq, d), ).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)).astype(np.float32))
        got = np.asarray(flash_attention(q, k, v, causal=causal, bq=64,
                                         bk=64))
        ref = np.asarray(gqa_ref(q, k, v, causal=causal))
        np.testing.assert_allclose(got, ref, atol=2e-3)

    def test_bf16(self):
        rng = np.random.default_rng(9)
        q = jnp.asarray(rng.standard_normal((1, 2, 64, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 2, 64, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 2, 64, 64)), jnp.bfloat16)
        got = np.asarray(flash_attention(q, k, v, bq=32, bk=32),
                         np.float32)
        ref = np.asarray(gqa_ref(q, k, v), np.float32)
        np.testing.assert_allclose(got, ref, atol=5e-2)

    def test_blocked_xla_matches_pallas(self):
        from repro.models.layers import gqa_attention
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)).astype(np.float32))
        a = np.asarray(gqa_attention(q, k, v, causal=True))
        b = np.asarray(flash_attention(q, k, v, causal=True, bq=64, bk=64))
        np.testing.assert_allclose(a, b, atol=2e-3)


class TestEmbeddingBag:
    @pytest.mark.parametrize("r,d,b,p,mode", [
        (1000, 32, 16, 4, "sum"), (5000, 128, 33, 1, "sum"),
        (200, 64, 8, 8, "mean"), (50, 8, 3, 2, "sum"),
    ])
    def test_matches_oracle(self, r, d, b, p, mode):
        rng = np.random.default_rng(r + b)
        table = jnp.asarray(rng.standard_normal((r, d)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, r, (b, p)).astype(np.int32))
        got = np.asarray(embedding_bag_pallas(table, idx, mode=mode))
        ref = np.asarray(embedding_bag_ref(table, idx, mode=mode))
        np.testing.assert_allclose(got, ref, atol=1e-4)
