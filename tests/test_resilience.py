"""Execution-core resilience (repro.core.resilience).

The contract under test, from ISSUE-8's acceptance gate:

1. **Checkpointing is free of semantic cost** — ``run(...,
   checkpoint_every=K)`` is bit-identical to the unsegmented engine
   (state, iteration count, direction/occupancy traces) for any K, on
   both engines, across design-space configs.
2. **Every injected fault ends well** — for the full seeded fault
   matrix (mode x engine x app), a run either converges to the clean
   answer (recovered, or the fault was harmlessly absorbed /
   result-invariant) or surfaces a structured ``outcome="faulted"``
   result carrying the fault history.  It never returns a silently
   wrong answer.
3. **Bounded rollback works** — the :class:`CheckpointRing` pins the
   initial snapshot, keeps the newest ``capacity-1`` boundaries, and
   ``rollback`` clamps at the pinned snapshot.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.algorithms import REGISTRY
from repro.core import ALL_CONFIGS, SystemConfig, run
from repro.core.resilience import (Checkpoint, CheckpointRing, RetryPolicy,
                                   build_sentinels, check_state_host)
from repro.core.vertex_program import FRONTIER_DIR_KEY, FRONTIER_OCC_KEY
from repro.graph import rmat_graph
from repro.testing.faults import (FAULT_MODES, CompileFault, NaNFault,
                                  RunnerExceptionFault, StaleUpdateFault,
                                  make_fault)

CFG = SystemConfig.from_name("DG1")
APPS = ("BFS", "PR", "MIS")
ENGINES = ("fused", "host")
RETRY = RetryPolicy(max_attempts=6)


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(scale=6, edge_factor=8, seed=3, weighted=False)


@pytest.fixture(scope="module")
def clean(graph):
    """Reference results per (app, engine) — what recovery must match."""
    return {(a, e): run(REGISTRY[a](), graph, CFG, engine=e)
            for a in APPS for e in ENGINES}


#: per-iteration frontier bookkeeping (last direction / occupancy
#: scalar), not part of the algorithm's answer — it legitimately
#: differs when recovery replays from a rollback, degrades the engine,
#: or a knob override changes the sparse capacity
_FRONTIER_KEYS = {FRONTIER_DIR_KEY, FRONTIER_OCC_KEY}


def _assert_states_match(res_state, ref_state, exact: bool,
                         frontier: bool = True):
    for k in ref_state:
        if not frontier and k in _FRONTIER_KEYS:
            continue
        a, b = np.asarray(res_state[k]), np.asarray(ref_state[k])
        if a.dtype.kind == "f" and not exact:
            assert np.allclose(a, b, atol=1e-5, equal_nan=False), k
        else:
            assert np.array_equal(a, b), k


class TestCheckpointedBitIdentity:
    """Segmenting the loop never changes the math."""

    # every 3rd design-space cell: static/topology/dynamic, both
    # granularities — the benchmark covers the full 18 in CI
    CONFIGS = [c.name for c in ALL_CONFIGS][::3]

    @pytest.mark.parametrize("cfg", CONFIGS)
    @pytest.mark.parametrize("app", ["BFS", "PR"])
    def test_fused_checkpointed_matches_plain(self, graph, app, cfg):
        prog = REGISTRY[app]()
        config = SystemConfig.from_name(cfg)
        plain = run(prog, graph, config)
        ckpt = run(prog, graph, config, checkpoint_every=4)
        assert ckpt.converged and ckpt.iterations == plain.iterations
        assert ckpt.outcome == "converged" and ckpt.fault is None
        _assert_states_match(ckpt.state, plain.state, exact=True)
        assert ckpt.direction_trace == plain.direction_trace
        assert ckpt.occupancy_trace == plain.occupancy_trace

    @pytest.mark.parametrize("engine", ENGINES)
    def test_checkpoint_interval_never_changes_result(self, graph, engine):
        prog = REGISTRY["BFS"]()
        ref = run(prog, graph, CFG, engine=engine)
        for k in (1, 3, 1000):
            r = run(prog, graph, CFG, engine=engine, checkpoint_every=k)
            assert r.iterations == ref.iterations
            _assert_states_match(r.state, ref.state, exact=True)
            assert r.direction_trace == ref.direction_trace

    def test_iter_limit_outcome_is_structured(self, graph):
        prog = REGISTRY["PR"]()
        r = run(prog, graph, CFG, checkpoint_every=2, max_iters=3)
        assert not r.converged and r.outcome == "iter_limit"
        plain = run(prog, graph, CFG, max_iters=3)
        assert plain.outcome == "iter_limit"     # plain runs report too
        _assert_states_match(r.state, plain.state, exact=True)


class TestFaultMatrix:
    """Every fault mode x engine x app: recover to the clean answer or
    report a structured fault — never a silently wrong result."""

    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("app", APPS)
    @pytest.mark.parametrize("mode", sorted(FAULT_MODES))
    def test_fault_recovers_or_reports(self, graph, clean, mode, app,
                                       engine):
        prog = REGISTRY[app]()
        inj = make_fault(mode)
        res = run(prog, graph, CFG, engine=engine, checkpoint_every=2,
                  retry=RETRY, fault_injector=inj)
        if res.outcome == "faulted":
            # structured failure: history + final cause, never a state
            # that pretends to be an answer
            assert not res.converged
            assert res.fault["recovered"] is False
            assert res.fault["history"]
            assert res.fault["final"]["kind"] in ("sentinel", "exception")
            return
        assert res.converged and res.outcome == "converged"
        ref = clean[(app, res.engine)]
        if res.fault is None:
            # the injector never tripped anything: either it could not
            # fire (e.g. compile-fault on the host engine), it was
            # result-invariant (overflow falls back densely), or the
            # fixpoint absorbed it (stale on PR) — the answer must
            # still match the clean run
            _assert_states_match(res.state, ref.state, exact=False,
                                 frontier=False)
        else:
            assert res.fault["recovered"] is True
            assert res.attempts > 1
            # recovery re-executes clean: exact for integer fixpoints,
            # float-tolerant when the degradation chain switched
            # engines mid-run (FMA contraction differs per engine)
            _assert_states_match(res.state, ref.state, exact=False,
                                 frontier=False)
            if all(np.asarray(v).dtype.kind != "f"
                   for k, v in ref.state.items()
                   if k not in _FRONTIER_KEYS):
                _assert_states_match(res.state, ref.state, exact=True,
                                     frontier=False)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_persistent_exception_is_faulted_not_wrong(self, graph,
                                                       engine):
        inj = RunnerExceptionFault(at_iteration=0, times=None)
        res = run(REGISTRY["BFS"](), graph, CFG, engine=engine,
                  checkpoint_every=2, retry=RetryPolicy(max_attempts=3),
                  fault_injector=inj)
        assert res.outcome == "faulted" and not res.converged
        assert len(res.fault["history"]) == 3
        assert res.fault["final"]["kind"] == "exception"
        assert res.iterations == 0               # never got past it=0

    def test_nan_without_retry_is_faulted_with_sentinel_detail(self,
                                                               graph):
        res = run(REGISTRY["PR"](), graph, CFG, checkpoint_every=2,
                  fault_injector=NaNFault(at_iteration=2))
        assert res.outcome == "faulted"
        final = res.fault["final"]
        assert final["kind"] == "sentinel"
        assert "nan" in final["sentinels"]
        assert final["engine"] == "fused"

    def test_nan_recovery_is_bit_identical(self, graph, clean):
        res = run(REGISTRY["PR"](), graph, CFG, checkpoint_every=2,
                  retry=RETRY, fault_injector=NaNFault(at_iteration=2))
        assert res.converged and res.fault["recovered"]
        # once=True: the re-execution is clean and stays on the fused
        # engine (rung 0 retries as-is), so the match is bitwise
        assert res.engine == "fused"
        _assert_states_match(res.state, clean[("PR", "fused")].state,
                             exact=True)

    def test_stale_fault_caught_by_certificate(self, graph, clean):
        """A dropped update is invisible to every boundary sentinel by
        construction; only the convergence certificate can reject it.
        Firing on the *final* segment boundary (clean BFS converges at
        it=4 here, so at_iteration=3 hits the done-boundary) leaves a
        quiescent-but-wrong state that no later frontier can heal —
        earlier reverts are re-relaxed by subsequent iterations."""
        prog = REGISTRY["BFS"]()
        inj = StaleUpdateFault(at_iteration=3, fraction=0.5)
        res = run(prog, graph, CFG, checkpoint_every=2, retry=RETRY,
                  fault_injector=inj)
        assert res.converged and res.fault["recovered"]
        assert any(f["kind"] == "sentinel"
                   and "certificate" in f.get("sentinels", ())
                   for f in res.fault["history"])
        _assert_states_match(res.state, clean[("BFS", res.engine)].state,
                             exact=True, frontier=False)

    def test_compile_fault_degrades_to_host_engine(self, graph, clean):
        res = run(REGISTRY["BFS"](), graph, CFG, checkpoint_every=2,
                  retry=RETRY, fault_injector=CompileFault(engine="fused"))
        assert res.converged and res.engine == "host"
        assert res.fault["recovered"]
        _assert_states_match(res.state, clean[("BFS", "host")].state,
                             exact=True, frontier=False)


class TestCheckpointRing:
    def _cp(self, it):
        return Checkpoint(it=it, done=False, state={"x": np.arange(3)},
                          dir_buf=None, occ_buf=None)

    def test_capacity_validation(self):
        # the message must name the offending argument and its value
        for bad in (0, -3):
            with pytest.raises(ValueError, match=f"capacity.*{bad}"):
                CheckpointRing(bad)

    def test_pinned_first_survives_wraparound(self):
        ring = CheckpointRing(capacity=3)
        for it in range(10):
            ring.push(self._cp(it))
        assert len(ring) == 3                    # pinned + 2 newest
        assert ring.latest().it == 9
        assert ring.rollback(1).it == 8
        # deeper rollbacks clamp at the pinned initial snapshot
        assert ring.rollback(50).it == 0

    def test_capacity_one_is_cold_restart(self):
        ring = CheckpointRing(capacity=1)
        for it in range(5):
            ring.push(self._cp(it))
        assert len(ring) == 1
        assert ring.latest().it == 0

    def test_empty_ring_raises(self):
        with pytest.raises(IndexError):
            CheckpointRing().latest()


class TestSentinelBattery:
    def test_battery_order_and_contents(self):
        names = [n for n, _ in build_sentinels(REGISTRY["SSSP"]())]
        assert names[0] == "nan"
        assert "monotone:dist" in names
        assert "dist_nonnegative" in names

    def test_host_checks_catch_nan_and_monotone(self):
        prog = REGISTRY["SSSP"]()
        prev = {"dist": np.asarray([0.0, 2.0, np.inf], np.float32)}
        ok = {"dist": np.asarray([0.0, 1.5, np.inf], np.float32)}
        assert check_state_host(prog, prev, ok) == []
        nan = {"dist": np.asarray([0.0, np.nan, np.inf], np.float32)}
        assert "nan" in check_state_host(prog, prev, nan)
        worse = {"dist": np.asarray([0.0, 3.0, np.inf], np.float32)}
        assert "monotone:dist" in check_state_host(prog, prev, worse)

    def test_validation_errors(self, graph):
        prog = REGISTRY["BFS"]()
        with pytest.raises(ValueError):
            run(prog, graph, CFG, checkpoint_every=-1)
        with pytest.raises(ValueError):
            run(prog, graph, CFG, checkpoint_every=2,
                retry=RetryPolicy(max_attempts=0))
