"""Frontier subsystem: direction heuristic units, the 12-config
correctness matrix for the traversal apps, and the dynamic-direction
trace the acceptance of the 'D' configs hinges on."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import bc, bfs, pagerank, sssp
from repro.algorithms.reference import bc_np, bfs_np, sssp_np
from repro.core import ALL_CONFIGS, EdgeContext, SystemConfig, run
from repro.core.frontier import (ALPHA, BETA, choose_direction,
                                 dense_to_sparse, frontier_density,
                                 frontier_edges, frontier_size,
                                 sparse_to_dense)
from repro.graph import powerlaw_graph, random_graph

CONFIG_NAMES = [c.name for c in ALL_CONFIGS]


@pytest.fixture(scope="module")
def rand_g():
    return random_graph(64, 400, seed=0, weighted=True, block_size=32)


@pytest.fixture(scope="module")
def sf_g():
    return powerlaw_graph(200, 1500, alpha=1.2, seed=1, weighted=True,
                          block_size=32)


class TestHeuristic:
    def _uniform(self, v=100, deg=4):
        return jnp.full((v,), deg, jnp.int32), v * deg, v

    def test_sparse_frontier_pushes(self):
        out_deg, e, v = self._uniform()
        mask = jnp.zeros((v,), bool).at[0].set(True)
        assert not bool(choose_direction(mask, out_deg, e, v, False))

    def test_dense_frontier_pulls(self):
        out_deg, e, v = self._uniform()
        mask = jnp.ones((v,), bool)
        assert bool(choose_direction(mask, out_deg, e, v, False))

    def test_flips_exactly_at_density_threshold(self):
        """push->pull fires when m_f * ALPHA first exceeds |E|."""
        out_deg, e, v = self._uniform()
        thresh = int(e // (4 * ALPHA))  # frontier vertices at the boundary
        below = jnp.arange(v) < thresh
        above = jnp.arange(v) < thresh + 1
        assert not bool(choose_direction(below, out_deg, e, v, False))
        assert bool(choose_direction(above, out_deg, e, v, False))

    def test_hysteresis_pull_sticks_until_beta(self):
        out_deg, e, v = self._uniform()
        # inside the hysteresis band: above V/BETA vertices but below the
        # |E|/ALPHA out-edge trigger, so neither switch fires
        mid = jnp.arange(v) < 6
        tail = jnp.arange(v) < max(1, int(v / BETA) - 1)
        # while pulling, a mid-size frontier keeps pulling...
        assert bool(choose_direction(mid, out_deg, e, v, True))
        # ...but the same frontier from push stays push (no oscillation)
        assert not bool(choose_direction(mid, out_deg, e, v, False))
        # and the shrunk tail flips back to push
        assert not bool(choose_direction(tail, out_deg, e, v, True))

    def test_unvisited_variant_compares_frontiers(self):
        out_deg, e, v = self._uniform()
        half = jnp.arange(v) < v // 2
        none = jnp.zeros((v,), bool)
        # m_f = m_u/1 > m_u/ALPHA -> pull, even though density is only 0.5
        assert bool(choose_direction(half, out_deg, e, v, False,
                                     unvisited=~half))
        # nothing left to discover -> m_f * ALPHA > 0 -> pull (scan ends it)
        assert bool(choose_direction(half, out_deg, e, v, False,
                                     unvisited=none))

    def test_static_configs_constant_fold(self, rand_g):
        mask = jnp.ones((rand_g.n_nodes,), bool)
        push_ctx = EdgeContext(rand_g, SystemConfig.from_name("SG1"))
        pull_ctx = EdgeContext(rand_g, SystemConfig.from_name("TG0"))
        assert not bool(push_ctx.choose_direction(mask, False))
        assert bool(pull_ctx.choose_direction(mask, True))

    def test_measures(self):
        out_deg = jnp.asarray([1, 2, 3, 4], jnp.int32)
        mask = jnp.asarray([True, False, True, False])
        assert int(frontier_size(mask)) == 2
        assert int(frontier_edges(mask, out_deg)) == 4
        assert float(frontier_density(mask, out_deg, 10)) == pytest.approx(0.4)

    def test_sparse_dense_roundtrip(self):
        mask = jnp.asarray([False, True, False, True, True])
        front = dense_to_sparse(mask, capacity=5)
        assert set(np.asarray(front.ids).tolist()) == {1, 3, 4, -1}
        assert int(front.count) == 3 and not bool(front.overflowed)
        back = sparse_to_dense(front.ids, 5)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(mask))

    def test_dense_to_sparse_overflow_is_explicit(self):
        """Vertices past capacity can't fit in ids, but the true count
        survives so callers can fall back to the dense mask."""
        mask = jnp.asarray([True, False, True, True, True])
        front = dense_to_sparse(mask, capacity=2)
        assert np.asarray(front.ids).tolist() == [0, 2]  # first two set bits
        assert int(front.count) == 4 and bool(front.overflowed)


class TestConfigMatrix:
    """All 12 cells of the design space (the now-real D* included) agree
    with the numpy oracles for every traversal app."""

    @pytest.mark.parametrize("cfg", CONFIG_NAMES)
    def test_bfs(self, rand_g, cfg):
        r = run(bfs(), rand_g, SystemConfig.from_name(cfg))
        np.testing.assert_array_equal(np.asarray(r.state["depth"]),
                                      bfs_np(rand_g))

    @pytest.mark.parametrize("cfg", CONFIG_NAMES)
    def test_sssp(self, rand_g, cfg):
        r = run(sssp(), rand_g, SystemConfig.from_name(cfg))
        got = np.asarray(r.state["dist"])
        ref = sssp_np(rand_g)
        mask = np.isfinite(ref)
        np.testing.assert_allclose(got[mask], ref[mask], atol=1e-4)
        assert np.array_equal(np.isfinite(got), mask)

    @pytest.mark.parametrize("cfg", CONFIG_NAMES)
    def test_bc(self, rand_g, cfg):
        r = run(bc(), rand_g, SystemConfig.from_name(cfg))
        np.testing.assert_allclose(np.asarray(r.extract(bc())),
                                   bc_np(rand_g), rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("cfg", ["DD1", "DGR", "TG0", "SGR"])
    def test_bfs_scale_free(self, sf_g, cfg):
        r = run(bfs(), sf_g, SystemConfig.from_name(cfg))
        np.testing.assert_array_equal(np.asarray(r.state["depth"]),
                                      bfs_np(sf_g))


class TestDirectionTrace:
    def test_bfs_switches_both_ways(self, sf_g):
        """Acceptance: a DD1 BFS on a scale-free graph genuinely runs
        >=1 push-phase and >=1 pull-phase iteration."""
        r = run(bfs(), sf_g, SystemConfig.from_name("DD1"))
        assert r.direction_trace is not None
        assert "S" in r.direction_trace and "T" in r.direction_trace
        assert len(r.direction_trace) == r.iterations

    def test_static_configs_never_switch(self, sf_g):
        push = run(bfs(), sf_g, SystemConfig.from_name("SG1"))
        pull = run(bfs(), sf_g, SystemConfig.from_name("TG0"))
        assert set(push.direction_trace) == {"S"}
        assert set(pull.direction_trace) == {"T"}

    def test_frontierless_program_has_no_trace(self, sf_g):
        """All registered apps trace now (ISSUE 6) — a program that
        opts out of the protocol still reports no trace."""
        import dataclasses
        prog = dataclasses.replace(pagerank(), frontier_init=None,
                                   frontier_update=None)
        r = run(prog, sf_g, SystemConfig.from_name("SG1"), max_iters=3)
        assert r.direction_trace is None

    def test_pagerank_traces_since_port(self, sf_g):
        r = run(pagerank(), sf_g, SystemConfig.from_name("SG1"),
                max_iters=3)
        assert set(r.direction_trace) == {"S"}

    def test_frontier_protocol_fields(self, sf_g):
        prog = bfs(source=7)
        init_mask = np.asarray(prog.frontier_init(sf_g))
        assert init_mask.sum() == 1 and init_mask[7]
        st = prog.init(sf_g)
        np.testing.assert_array_equal(
            np.asarray(prog.frontier_update(st)), init_mask)

    def test_pallas_dynamic_path(self, sf_g):
        r = run(bfs(), sf_g, SystemConfig.from_name("DD1"), use_pallas=True)
        np.testing.assert_array_equal(np.asarray(r.state["depth"]),
                                      bfs_np(sf_g))
        assert "S" in r.direction_trace and "T" in r.direction_trace


@pytest.mark.slow
class TestFig5Sweep:
    """Opt-in (-m slow): the benchmark-scale Fig. 5 sweep end-to-end."""

    def test_traversal_cells_report_directions(self, tmp_path):
        from benchmarks.fig5 import run_fig5
        res = run_fig5(out_dir=str(tmp_path), scale=16, apps=["BFS"],
                       graphs=["DCT"])
        row = res["DCT/BFS"]["configs"]
        assert any(c.startswith("D") and row[c].get("directions")
                   for c in row)
