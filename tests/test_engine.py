"""Execution engines: the fused device-resident ``lax.while_loop``
runner vs the host kernel-per-iteration oracle, the plan cache that
amortizes EdgeContext construction, and the vectorized reducer tiling
plan.

Acceptance criteria covered here: the fused engine is bit-identical to
the host engine on state, iterations and both traces across the full
config matrix for BFS/SSSP/BC (the PR 1 oracle apps); a fused run
issues exactly one timed jit dispatch; max_iters truncation reports
``converged=False`` identically on both engines; a repeated 12-cell
EdgeContext construction hits the plan cache; and ``plan_tiles``'s
numpy bucket arithmetic matches the per-block loop it replaced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.algorithms import bc, bfs, sssp
from repro.algorithms.reference import bfs_np
from repro.core import (ALL_CONFIGS, PLAN_CACHE, STATS, EdgeContext,
                        SystemConfig, run)
from repro.core.vertex_program import DENSE_OCC
from repro.graph import powerlaw_graph, random_graph, rmat_graph

CONFIG_NAMES = [c.name for c in ALL_CONFIGS]
APPS = {"BFS": bfs, "SSSP": sssp, "BC": bc}


@pytest.fixture(scope="module")
def rand_g():
    return random_graph(64, 400, seed=0, weighted=True, block_size=32)


@pytest.fixture(scope="module")
def sf_g():
    return powerlaw_graph(200, 1500, alpha=1.2, seed=1, weighted=True,
                          block_size=32)


def _assert_results_identical(a, b):
    assert a.iterations == b.iterations
    assert a.converged == b.converged
    assert a.direction_trace == b.direction_trace
    assert a.occupancy_trace == b.occupancy_trace
    la, lb = jax.tree.leaves(a.state), jax.tree.leaves(b.state)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestFusedVsHost:
    """Fused engine == host engine, bit for bit, over the full matrix."""

    @pytest.mark.parametrize("app", list(APPS))
    @pytest.mark.parametrize("cfg", CONFIG_NAMES)
    def test_matrix_bit_identical(self, rand_g, cfg, app):
        program = APPS[app]()
        host = run(program, rand_g, SystemConfig.from_name(cfg),
                   engine="host")
        fused = run(program, rand_g, SystemConfig.from_name(cfg),
                    engine="fused")
        _assert_results_identical(host, fused)
        assert host.engine == "host" and fused.engine == "fused"

    def test_scale_free_dynamic_cell(self, sf_g):
        """The direction-switching DD1 cell (mixed S/T trace, sparse
        gathers) on a scale-free input — the hardest trace to preserve."""
        host = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                   engine="host")
        fused = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                    engine="fused")
        _assert_results_identical(host, fused)
        assert "S" in fused.direction_trace and "T" in fused.direction_trace
        assert fused.sparse_iterations >= 1
        np.testing.assert_array_equal(np.asarray(fused.state["depth"]),
                                      bfs_np(sf_g))

    def test_pallas_fast_path(self, sf_g):
        host = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                   engine="host", use_pallas=True)
        fused = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                    engine="fused", use_pallas=True)
        _assert_results_identical(host, fused)

    @pytest.mark.parametrize("engine", ["host", "fused"])
    def test_max_iters_truncation(self, sf_g, engine):
        """A truncated run reports converged=False with exactly
        max_iters iterations and max_iters-long traces."""
        r = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                max_iters=2, engine=engine)
        assert not r.converged
        assert r.iterations == 2
        assert len(r.direction_trace) == 2
        assert len(r.occupancy_trace) == 2

    def test_truncation_identical_across_engines(self, sf_g):
        host = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                   max_iters=2, engine="host")
        fused = run(bfs(), sf_g, SystemConfig.from_name("DD1"),
                    max_iters=2, engine="fused")
        _assert_results_identical(host, fused)

    def test_unknown_engine_rejected(self, rand_g):
        with pytest.raises(ValueError, match="unknown engine"):
            run(bfs(), rand_g, SystemConfig.from_name("SG1"),
                engine="gpu")

    def test_frontierless_program_fused(self, rand_g):
        """Programs without the frontier protocol (no traces) run fused
        too — the trace buffers simply stay out of the carry.  All six
        registered apps now speak the protocol (ISSUE 6), so this path
        is covered by an inline smoothing program."""
        from repro.core.vertex_program import SUM, EdgePhase, VertexProgram
        phase = EdgePhase(monoid=SUM,
                          vprop=lambda st, src, w: st["x"][src])
        prog = VertexProgram(
            name="BFS",  # borrow a Table III row; properties are unused
            init=lambda g: {"x": jnp.ones((g.n_nodes,), jnp.float32)},
            step=lambda ctx, st, it: {
                "x": 0.5 * st["x"] + 0.25 * ctx.propagate(st, phase)},
            converged=lambda prev, cur: jnp.asarray(False),
            extract=lambda st: st["x"],
        )
        host = run(prog, rand_g, SystemConfig.from_name("SG1"),
                   max_iters=5, engine="host")
        fused = run(prog, rand_g, SystemConfig.from_name("SG1"),
                    max_iters=5, engine="fused")
        assert fused.direction_trace is None
        assert fused.occupancy_trace is None
        _assert_results_identical(host, fused)


class TestDispatchCount:
    def test_fused_is_one_dispatch(self, sf_g):
        """The whole convergence loop is a single timed jitted
        invocation, however many iterations it runs."""
        STATS.reset()
        r = run(bfs(), sf_g, SystemConfig.from_name("DD1"), engine="fused")
        assert r.iterations > 1  # a real multi-iteration run
        assert STATS.dispatches == 1
        assert r.dispatches == 1

    def test_host_is_one_dispatch_per_iteration(self, sf_g):
        STATS.reset()
        r = run(bfs(), sf_g, SystemConfig.from_name("DD1"), engine="host")
        assert STATS.dispatches == r.iterations
        assert r.dispatches == r.iterations

    def test_fused_without_warmup_still_one_dispatch(self, rand_g):
        STATS.reset()
        run(bfs(), rand_g, SystemConfig.from_name("SG1"), engine="fused",
            warmup=False)
        assert STATS.dispatches == 1


class TestPlanCache:
    def test_repeated_12_cell_construction_hits(self):
        """Binding the same graph to every config twice: the second
        sweep builds nothing (all context-level hits), and even the
        first sweep shares chunked orders across cells."""
        g = random_graph(48, 300, seed=3, weighted=True, block_size=16)
        PLAN_CACHE.clear()
        for cfg in ALL_CONFIGS:
            EdgeContext.create(g, SystemConfig.from_name(cfg.name))
        first = PLAN_CACHE.stats()
        # 18 configs share: 1 device graph + owned edges + chunked
        # orders per (order, n_chunks in {1, 8}) -> far fewer builds
        # than 18 full constructions
        assert first["misses"] < len(ALL_CONFIGS) * 4
        assert first["hits"] > 0
        for cfg in ALL_CONFIGS:
            EdgeContext.create(g, SystemConfig.from_name(cfg.name))
        second = PLAN_CACHE.stats()
        assert second["misses"] == first["misses"]  # nothing rebuilt
        assert second["hits"] == first["hits"] + len(ALL_CONFIGS)

    def test_distinct_graphs_do_not_collide(self):
        g1 = random_graph(32, 150, seed=1, block_size=16)
        g2 = random_graph(32, 150, seed=2, block_size=16)
        c1 = EdgeContext.create(g1, SystemConfig.from_name("SG1"))
        c2 = EdgeContext.create(g2, SystemConfig.from_name("SG1"))
        assert c1 is not c2
        assert c1 is EdgeContext.create(g1, SystemConfig.from_name("SG1"))

    def test_capacity_is_part_of_the_key(self):
        g = random_graph(32, 150, seed=1, block_size=16)
        a = EdgeContext.create(g, SystemConfig.from_name("DG1"))
        b = EdgeContext.create(g, SystemConfig.from_name("DG1"),
                               sparse_edge_capacity=0)
        assert a is not b
        # None normalizes to the documented default capacity
        assert a is EdgeContext.create(
            g, SystemConfig.from_name("DG1"),
            sparse_edge_capacity=EdgeContext.default_sparse_capacity(g))

    def test_eviction_on_graph_collection(self):
        import gc
        PLAN_CACHE.clear()
        g = random_graph(32, 150, seed=5, block_size=16)
        EdgeContext.create(g, SystemConfig.from_name("SG1"))
        assert len(PLAN_CACHE) > 0
        del g
        gc.collect()
        assert len(PLAN_CACHE) == 0

    def test_repeated_runs_reuse_compiled_runner(self, sf_g):
        """Sweep repeats hit the exec_fn cache: the fused while_loop is
        AOT-compiled once per (program, cell, limit), not per run."""
        import time
        program = bfs()
        cfg = SystemConfig.from_name("DD1")
        PLAN_CACHE.clear()
        r1 = run(program, sf_g, cfg, engine="fused")
        hits_before = PLAN_CACHE.stats()["hits"]
        misses_before = PLAN_CACHE.stats()["misses"]
        t0 = time.perf_counter()
        r2 = run(program, sf_g, cfg, engine="fused")
        warm_wall = time.perf_counter() - t0
        after = PLAN_CACHE.stats()
        assert after["misses"] == misses_before  # nothing rebuilt
        assert after["hits"] > hits_before       # context + exec_fn hits
        _assert_results_identical(r1, r2)
        assert warm_wall < 5.0  # no multi-second recompile on repeat

    def test_distinct_programs_get_distinct_runners(self, rand_g):
        """Two program instances must not share a compiled runner even
        on the same cell (the cache pins each program by identity)."""
        cfg = SystemConfig.from_name("SG1")
        a = run(bfs(source=0), rand_g, cfg, engine="fused")
        b = run(bfs(source=1), rand_g, cfg, engine="fused")
        assert int(np.asarray(a.state["depth"])[0]) == 0
        assert int(np.asarray(b.state["depth"])[1]) == 0

    def test_exec_fn_bucket_is_bounded(self, rand_g):
        """A stream of distinct program instances on one long-lived
        graph (exact-BC-style per-root loops) must not accumulate
        unbounded compiled executables."""
        from repro.core import executor
        PLAN_CACHE.clear()
        cfg = SystemConfig.from_name("SG1")
        for src in range(executor._EXEC_FN_CAPACITY + 8):
            run(bfs(source=src % rand_g.n_nodes), rand_g, cfg,
                max_iters=1, engine="fused")
        with PLAN_CACHE._lock:
            n_exec = sum(1 for k in PLAN_CACHE._store
                         if k[1] == "exec_fn")
        assert n_exec <= executor._EXEC_FN_CAPACITY

    def test_cached_context_produces_correct_results(self, sf_g):
        """Reuse through the cache does not change answers (contexts
        are immutable): two runs on the same cell, one cold one warm."""
        PLAN_CACHE.clear()
        r1 = run(bfs(), sf_g, SystemConfig.from_name("DD1"))
        r2 = run(bfs(), sf_g, SystemConfig.from_name("DD1"))
        _assert_results_identical(r1, r2)
        np.testing.assert_array_equal(np.asarray(r2.state["depth"]),
                                      bfs_np(sf_g))


def _plan_tiles_loop_ref(block_ptr, tile_e):
    """The per-block Python loop plan_tiles replaced — kept as oracle."""
    block_ptr = np.asarray(block_ptr, np.int64)
    n_blocks = block_ptr.shape[0] - 1
    gather, tbid, tfirst = [], [], []
    for b in range(n_blocks):
        lo, hi = block_ptr[b], block_ptr[b + 1]
        n = int(hi - lo)
        n_tiles = max(1, -(-n // tile_e))
        idx = np.full(n_tiles * tile_e, -1, np.int64)
        idx[:n] = np.arange(lo, hi)
        for t in range(n_tiles):
            gather.append(idx[t * tile_e:(t + 1) * tile_e])
            tbid.append(b)
            tfirst.append(1 if t == 0 else 0)
    return (np.stack(gather).astype(np.int32),
            np.asarray(tbid, np.int32), np.asarray(tfirst, np.int32))


class TestPlanTilesVectorized:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 17))
    @settings(max_examples=25, deadline=None)
    def test_matches_loop_reference(self, seed, tile_e):
        from repro.kernels.segment_reduce.kernel import plan_tiles
        rng = np.random.default_rng(seed)
        counts = rng.integers(0, 40, int(rng.integers(1, 20)))
        block_ptr = np.concatenate([[0], np.cumsum(counts)])
        got = plan_tiles(block_ptr, tile_e)
        ref = _plan_tiles_loop_ref(block_ptr, tile_e)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(g, r)

    def test_empty_blocks_get_padding_tile(self):
        from repro.kernels.segment_reduce.kernel import plan_tiles
        gather, tbid, tfirst = plan_tiles(np.asarray([0, 0, 3, 3]), 4)
        assert gather.shape == (3, 4)
        np.testing.assert_array_equal(tbid, [0, 1, 2])
        np.testing.assert_array_equal(tfirst, [1, 1, 1])
        np.testing.assert_array_equal(gather[0], [-1, -1, -1, -1])
        np.testing.assert_array_equal(gather[1], [0, 1, 2, -1])
        np.testing.assert_array_equal(gather[2], [-1, -1, -1, -1])

    def test_reducer_exposes_plan_size(self):
        from repro.kernels.segment_reduce import BlockedSegmentReducer
        red = BlockedSegmentReducer(
            np.asarray([0, 0, 1, 5, 9]), np.asarray([0, 3, 5]),
            num_segments=10, block_size=5, tile_e=2)
        assert red.n_tiles == red.gather_idx.shape[0]
        assert red.tile_e == 2


class TestOccupancyDtype:
    """The dense-iteration sentinel is one jnp.float32 scalar from
    every propagate_sparse branch (the while_loop carry requires it)."""

    def test_early_return_is_jnp_float32(self, rand_g):
        """Static config -> the early-return branch."""
        from repro.core import MIN, EdgePhase
        ctx = EdgeContext.create(rand_g, SystemConfig.from_name("SG1"))
        program = bfs()
        state = jax.tree.map(jnp.asarray, program.init(rand_g))
        phase = EdgePhase(monoid=MIN,
                          vprop=lambda st, s, w: st["depth"][s] + 1,
                          spred=lambda st, s: st["active"][s],
                          frontier=lambda st: st["active"],
                          gatherable=True)
        _, occ = ctx.propagate_sparse(state, phase, jnp.asarray(False),
                                      dtype=jnp.int32)
        assert isinstance(occ, jax.Array)
        assert occ.dtype == jnp.float32 and occ.shape == ()
        assert float(occ) == DENSE_OCC

    @pytest.mark.parametrize("pull", [False, True])
    def test_dynamic_branches_are_float32_scalars(self, rand_g, pull):
        from repro.core import MIN, EdgePhase
        ctx = EdgeContext.create(rand_g, SystemConfig.from_name("DG1"))
        program = bfs()
        state = jax.tree.map(jnp.asarray, program.init(rand_g))
        phase = EdgePhase(monoid=MIN,
                          vprop=lambda st, s, w: st["depth"][s] + 1,
                          spred=lambda st, s: st["active"][s],
                          frontier=lambda st: st["active"],
                          gatherable=True)
        _, occ = ctx.propagate_sparse(state, phase, jnp.asarray(pull),
                                      dtype=jnp.int32)
        assert occ.dtype == jnp.float32 and occ.shape == ()
        if pull:
            assert float(occ) == DENSE_OCC  # pull is inherently dense
        else:
            assert 0.0 <= float(occ) <= 1.0  # sparse gather fired

    def test_overflow_fallback_is_float32_sentinel(self, sf_g):
        from repro.core import MIN, EdgePhase
        ctx = EdgeContext.create(sf_g, SystemConfig.from_name("DG1"),
                                 sparse_edge_capacity=1)
        program = bfs()
        state = jax.tree.map(jnp.asarray, program.init(sf_g))
        # widen the frontier so its edges overflow capacity 1
        state = {**state,
                 "active": jnp.ones((sf_g.n_nodes,), bool)}
        phase = EdgePhase(monoid=MIN,
                          vprop=lambda st, s, w: st["depth"][s] + 1,
                          spred=lambda st, s: st["active"][s],
                          frontier=lambda st: st["active"],
                          gatherable=True)
        _, occ = ctx.propagate_sparse(state, phase, jnp.asarray(False),
                                      dtype=jnp.int32)
        assert occ.dtype == jnp.float32 and occ.shape == ()
        assert float(occ) == DENSE_OCC


class TestRmatWorkload:
    def test_rmat_generator_shape_and_symmetry(self):
        g = rmat_graph(scale=6, edge_factor=4, seed=7)
        assert g.n_nodes == 64
        assert g.n_edges > 0
        # symmetric universal input format: every edge has its reverse
        fwd = set(zip(np.asarray(g.src).tolist(),
                      np.asarray(g.dst).tolist()))
        assert all((d, s) in fwd for s, d in fwd)

    def test_dispatch_bench_writes_json(self, tmp_path):
        import json
        from benchmarks.dispatch import run_dispatch
        out = tmp_path / "BENCH_dispatch.json"
        res = run_dispatch(out_path=str(out), scale=5, repeats=1)
        assert out.exists()
        on_disk = json.loads(out.read_text())
        assert on_disk["summary"]["n_configs"] == len(ALL_CONFIGS)
        for cell in on_disk["configs"].values():
            assert cell["fused"]["dispatches"] == 1
            assert (cell["host"]["dispatches"]
                    == cell["host"]["iterations"])
            assert cell["fused"]["us_per_iteration"] > 0
        assert res["workload"]["generator"] == "rmat"
