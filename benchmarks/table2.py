"""Table II reproduction: taxonomy metrics for the six inputs.

Two sections: (a) metric classes computed from the PUBLISHED graph
statistics (exact reproduction — volume is a pure function of |V|,|E|;
reuse of AN_L/AN_R/avg-degree); (b) metrics measured with Eqs. 1-7 on our
synthetic recreations (scale=16).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core.taxonomy import (PAPER_GPU, classify_reuse,
                                 classify_volume_kb, profile_graph,
                                 reuse_from_an, volume_kb)
from repro.graph.datasets import PAPER_AN, PAPER_STATS, paper_graph

__all__ = ["run_table2"]


def run_table2(out_dir="results"):
    rows = []
    for name, stats in PAPER_STATS.items():
        v, e, maxd, avgd, volkb, reu, imb, vc, rc, ic = stats
        kb = volume_kb(v, e, PAPER_GPU)
        an_l, an_r = PAPER_AN[name]
        r = reuse_from_an(an_l, an_r, avgd)
        t0 = time.perf_counter()
        g = paper_graph(name, scale=16)
        prof = profile_graph(g, PAPER_GPU)
        dt = time.perf_counter() - t0
        rows.append({
            "graph": name,
            "published": dict(volume_kb=volkb, vol_class=vc, reuse=reu,
                              reuse_class=rc, imb=imb, imb_class=ic),
            "computed_from_published": dict(
                volume_kb=round(kb, 3),
                vol_class=classify_volume_kb(kb, PAPER_GPU),
                reuse=round(r, 4), reuse_class=classify_reuse(r, PAPER_GPU)),
            "measured_on_recreation": dict(
                n_nodes=g.n_nodes, n_edges=g.n_edges,
                volume_kb=round(prof.volume_kb, 3),
                vol_class=prof.volume_class,
                reuse=round(prof.reuse, 4), reuse_class=prof.reuse_class,
                imbalance=round(prof.imbalance, 4),
                imb_class=prof.imbalance_class),
            "profile_seconds": round(dt, 3),
        })
    Path(out_dir).mkdir(exist_ok=True, parents=True)
    Path(out_dir, "table2.json").write_text(json.dumps(rows, indent=2))
    return rows


if __name__ == "__main__":
    for row in run_table2():
        print(row["graph"], row["computed_from_published"],
              row["measured_on_recreation"])
