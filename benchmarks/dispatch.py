"""Engine dispatch benchmark: host vs fused us/iteration, tracked as
``results/BENCH_dispatch.json`` from this PR on.

The pinned workload is a Graph500-parameter R-MAT graph (fixed scale,
edge factor and seed) so the number is comparable across commits; every
cell of the full addressable design space (the paper's 12 static cells
plus the six dynamic ``D**`` cells — ``ALL_CONFIGS``) runs BFS under
both execution engines and reports seconds, iterations and
us/iteration (best of ``repeats``).  The host engine pays one jit dispatch plus a blocking
convergence read per iteration; the fused engine pays one dispatch per
*run* — the per-iteration delta is exactly the dispatch overhead the
device-resident ``lax.while_loop`` runner removes, which is what this
file makes machine-readable for CI to archive.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

from repro.algorithms import REGISTRY
from repro.core import ALL_CONFIGS, SystemConfig, run
from repro.graph import rmat_graph

__all__ = ["run_dispatch", "PINNED_WORKLOAD"]

#: The pinned workload — change it and the trajectory restarts.
PINNED_WORKLOAD = dict(scale=10, edge_factor=8, seed=7)
APP = "BFS"
ENGINES = ("host", "fused")
#: best-of-N per (config, engine): warm repeats are milliseconds (the
#: exec_fn cache skips recompilation), so generous repeats are cheap
#: insurance against scheduler noise in the tracked artifact.
REPEATS = 10


def run_dispatch(out_path: str = "results/BENCH_dispatch.json",
                 scale: int | None = None, repeats: int = REPEATS) -> dict:
    wl = dict(PINNED_WORKLOAD)
    if scale is not None:
        wl["scale"] = scale
    program = REGISTRY[APP]()
    g = rmat_graph(weighted=program.weighted, **wl)

    configs = {}
    for cfg in ALL_CONFIGS:
        cell = {}
        for engine in ENGINES:
            best = None
            for _ in range(repeats):
                r = run(program, g, SystemConfig.from_name(cfg.name),
                        engine=engine)
                if best is None or r.seconds < best.seconds:
                    best = r
            cell[engine] = {
                "seconds": best.seconds,
                "iterations": best.iterations,
                # from the same run as seconds/iterations (RunResult
                # carries its own dispatch count)
                "dispatches": best.dispatches,
                "us_per_iteration": best.seconds * 1e6
                / max(best.iterations, 1),
            }
        cell["fused_speedup"] = (cell["host"]["us_per_iteration"]
                                 / max(cell["fused"]["us_per_iteration"],
                                       1e-12))
        configs[cfg.name] = cell

    speedups = [c["fused_speedup"] for c in configs.values()]
    result = {
        "workload": {"generator": "rmat", **wl, "app": APP,
                     "n_nodes": g.n_nodes, "n_edges": g.n_edges},
        "repeats": repeats,
        "configs": configs,
        "summary": {
            "n_configs": len(configs),
            "fused_beats_host": sum(s > 1.0 for s in speedups),
            "geomean_fused_speedup": math.exp(
                sum(math.log(s) for s in speedups) / len(speedups)),
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    s = result["summary"]
    print(f"dispatch_bench,{len(configs)},"
          f"fused_beats_host={s['fused_beats_host']}/{s['n_configs']};"
          f"geomean_fused_speedup={s['geomean_fused_speedup']:.2f}x",
          flush=True)
    return result


if __name__ == "__main__":
    scale = int(sys.argv[1]) if len(sys.argv) > 1 else None
    run_dispatch(scale=scale)
