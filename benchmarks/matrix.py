"""The paper's headline 36-workload matrix: 6 apps x 6 inputs, every
cell swept over the design-space configs, tracked as
``results/BENCH_matrix.json`` from this PR on.  The sweep runs every
registered app, so the table is a strict superset of the paper's 36
workloads (the repo carries one more traversal app than the paper's
six).

Each workload (``input/app``) runs under every config in the sweep set
(the full 18-cell space by default, a reduced set under ``--smoke``)
on the fused engine, recording per-cell seconds (best of ``repeats``,
compile excluded), iterations, and — for dynamic cells — the
direction trace and sparse-gather residency.  Inputs come from
``dataset_graph``: the real SuiteSparse/SNAP edge list when fetched
locally, the degree-matched synthetic stand-in otherwise, with the
source and measured degree profile recorded per input.

The gate metric is each workload's ``specialization_gain``: reference
cell seconds (``TG0`` — the GPU-coherence/pull baseline every config
is normalized against in Fig. 5) divided by the best cell's seconds.
That is the paper's headline quantity — how much picking the right
coherence/consistency/direction buys over the one-size-fits-all
baseline — and, being a same-machine ratio, survives hardware changes
that absolute times would not.

``--smoke`` is the CI job: tiny stand-ins, three configs spanning the
axes (TG0 pull / SG1 push / DD1 dynamic), autotune off.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

import jax

from repro.algorithms import REGISTRY
from repro.core import ALL_CONFIGS, SystemConfig, run
from repro.graph.datasets import PAPER_GRAPHS, dataset_graph, degree_profile

__all__ = ["run_matrix", "REF_CONFIG", "SMOKE_CONFIGS", "SMOKE_SCALE",
           "FULL_SCALE"]

REF_CONFIG = "TG0"
SMOKE_CONFIGS = ("TG0", "SG1", "DD1")
FULL_SCALE = 32
SMOKE_SCALE = 256
FULL_BLOCK = 256
SMOKE_BLOCK = 64
REPEATS = 3
SMOKE_REPEATS = 2


def _geomean(xs):
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 1.0


def run_matrix(out_path: str = "results/BENCH_matrix.json",
               smoke: bool = False, scale: int | None = None,
               repeats: int | None = None, apps=None, graphs=None,
               configs=None, autotune=None) -> dict:
    """Sweep the 36-workload matrix; write and return the artifact."""
    scale = scale or (SMOKE_SCALE if smoke else FULL_SCALE)
    block_size = SMOKE_BLOCK if smoke else FULL_BLOCK
    repeats = repeats or (SMOKE_REPEATS if smoke else REPEATS)
    apps = list(apps or REGISTRY)
    graphs = list(graphs or PAPER_GRAPHS)
    config_names = list(configs or (SMOKE_CONFIGS if smoke
                                    else [c.name for c in ALL_CONFIGS]))
    if REF_CONFIG not in config_names:
        config_names.insert(0, REF_CONFIG)
    if autotune is None:
        autotune = "off" if smoke else "measure"

    inputs = {}
    cells = {}
    for gname in graphs:
        # one weighted + one unweighted materialization per input,
        # shared across apps (paper_graph lru-caches the synthetic path)
        gw, src_w = dataset_graph(gname, scale=scale, weighted=True,
                                  block_size=block_size)
        gu, _ = dataset_graph(gname, scale=scale, weighted=False,
                              block_size=block_size)
        prof = degree_profile(gu)
        inputs[gname] = {
            "source": src_w,
            "n_nodes": int(gu.n_nodes), "n_edges": int(gu.n_edges),
            "profile": prof["profile"], "signature": prof["signature"],
            "degree_skew": round(prof["degree_skew"], 3),
        }
        for app in apps:
            program = REGISTRY[app]()
            g = gw if program.weighted else gu
            key = jax.random.key(0) if program.randomized else None
            row = {}
            for cname in config_names:
                config = SystemConfig.from_name(cname)
                best = float("inf")
                res = None
                for _ in range(repeats):
                    r = run(program, g, config, key=key,
                            autotune=autotune)
                    if r.seconds < best:
                        best, res = r.seconds, r
                cell = {"seconds": best, "iterations": res.iterations,
                        "converged": res.converged}
                if cname.startswith("D") and res.direction_trace:
                    cell["directions"] = res.direction_trace
                    cell["n_sparse"] = res.sparse_iterations
                row[cname] = cell
            ref = row[REF_CONFIG]["seconds"]
            best_cfg = min(row, key=lambda c: row[c]["seconds"])
            gain = ref / max(row[best_cfg]["seconds"], 1e-12)
            cells[f"{gname}/{app}"] = {
                "configs": row, "best": best_cfg,
                "specialization_gain": gain,
            }
            print(f"matrix {gname}/{app}: best={best_cfg} "
                  f"gain={gain:.2f}x over {REF_CONFIG} "
                  + " ".join(f"{c}={row[c]['seconds']*1e3:.1f}ms"
                             for c in config_names), flush=True)

    hist: dict = {}
    for cell in cells.values():
        hist[cell["best"]] = hist.get(cell["best"], 0) + 1
    result = {
        "smoke": smoke,
        "workload": {"scale": scale, "block_size": block_size,
                     "repeats": repeats, "autotune": autotune,
                     "ref_config": REF_CONFIG,
                     "configs": config_names,
                     "apps": apps, "graphs": graphs},
        "inputs": inputs,
        "cells": cells,
        "summary": {
            "n_workloads": len(cells),
            "geomean_specialization_gain": _geomean(
                c["specialization_gain"] for c in cells.values()),
            "best_config_histogram": dict(sorted(hist.items())),
            # the paper's headline qualitative claim: no single config
            # wins every workload
            "n_distinct_best": len(hist),
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    s = result["summary"]
    print(f"matrix_summary,{s['n_workloads']},geomean_gain="
          f"{s['geomean_specialization_gain']:.2f}x;"
          f"distinct_best={s['n_distinct_best']}", flush=True)
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="results/BENCH_matrix.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny inputs, reduced config set (the CI job)")
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)
    run_matrix(out_path=args.out, smoke=args.smoke, scale=args.scale,
               repeats=args.repeats)


if __name__ == "__main__":
    main()
