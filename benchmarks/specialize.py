"""Train + validate the learned best-config specializer (paper Sec. IV,
the predictive half), tracked as ``results/BENCH_specialize.json``.

Consumes the measured matrix artifact (``results/BENCH_matrix.json`` —
run ``benchmarks.matrix`` first; CI orders the steps that way), fits
the pure-numpy decision tree of
:mod:`repro.core.specialize_learned` against each workload's
measured-best cell, refreshes the serving model file
(``results/specialize_model.json``), and evaluates every specialization
policy the repo carries against the same measured cells:

- **learned** — the serving model (admission-time features only),
- **trace_augmented** — the ablation model that also sees the Fig. 5
  direction/occupancy traces (an upper bound; serving can never use it
  because no trace exists at admission time),
- **static_full / static_partial** — the prose decision trees of
  ``core/model.py`` fed by the Sec. III taxonomy profile of each
  (re-materialized) input graph,
- **always-X** — every single config of the sweep applied to every
  workload (the paper's one-size-fits-all strawmen).

Two metric families, both computed on the matrix's measured seconds so
they are same-machine ratios like every other gated artifact:

- **accuracy**: fraction of workloads whose chosen cell is the
  measured-best one; the ``*_tol`` variant credits any cell within
  ``tol`` (default 10%) of best, since near-tied cells flip on timing
  noise.  Static-tree choices name cells a reduced (smoke) sweep never
  measured, so every choice is projected onto the measured config
  vocabulary first (:func:`repro.core.specialize_learned.
  project_config`).
- **e2e geomean us/graph**: geomean over workloads of the chosen
  cell's measured time; ``speedup_vs_best_always`` divides the best
  single-config policy's geomean by the learned policy's.

The gate (``benchmarks/compare.py``, kind ``specialize``) enforces the
two acceptance invariants — learned accuracy >= the static partial
tree's, and learned e2e >= 1.0x the best always-X baseline — as
1.0-vs-1e-6 metrics, plus the tolerant accuracy itself as a ratio.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

from repro.core import specialize_learned as sl
from repro.core.model import specialize, specialize_partial
from repro.core.properties import TABLE_III
from repro.core.taxonomy import profile_graph
from repro.graph.datasets import dataset_graph

__all__ = ["run_specialize", "DEFAULT_TOL"]

#: a cell within this fraction of the measured-best cell counts as a
#: correct pick for the ``*_tol`` accuracies
DEFAULT_TOL = 0.10


def _geomean(xs):
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 1.0


def _taxonomy_profiles(matrix: dict) -> dict:
    """Re-materialize each matrix input at its recorded scale and run
    the Sec. III taxonomy — the static trees' graph-side input, which
    the matrix artifact does not carry."""
    wl = matrix["workload"]
    profs = {}
    for name, rec in matrix["inputs"].items():
        g, source = dataset_graph(name, scale=wl["scale"],
                                  block_size=wl["block_size"])
        if source != rec.get("source", source):
            print(f"specialize: input {name} resolves to {source} graph "
                  f"but the matrix measured {rec['source']} — static-tree "
                  "accuracy is evaluated against a different graph",
                  flush=True)
        profs[name] = profile_graph(g)
    return profs


def run_specialize(out_path: str = "results/BENCH_specialize.json",
                   matrix_path: str = "results/BENCH_matrix.json",
                   model_out: str = "results/specialize_model.json",
                   smoke: bool = False, tol: float = DEFAULT_TOL,
                   max_depth: int = 6) -> dict:
    """Train the model, refresh ``model_out``, evaluate every policy;
    write and return the artifact."""
    mpath = Path(matrix_path)
    if not mpath.exists():
        raise SystemExit(
            f"specialize: no matrix artifact at {matrix_path} — run "
            "`python -m benchmarks.matrix" + (" --smoke" if smoke else "")
            + "` first (the specializer trains on its measured cells)")
    matrix = json.loads(mpath.read_text())
    if bool(matrix.get("smoke")) != bool(smoke):
        raise SystemExit(
            f"specialize: matrix at {matrix_path} has smoke="
            f"{matrix.get('smoke')} but this run asked smoke={smoke} — "
            "train on a matrix produced with the same flag")

    rows = sl.training_table(matrix)
    avail = sorted({c for r in rows for c in r.seconds})
    model = sl.fit_matrix(matrix, max_depth=max_depth)
    model_path = sl.save_model(model, model_out)
    trace_model = sl.fit_matrix(matrix, max_depth=max_depth,
                                trace_features=True)
    profs = _taxonomy_profiles(matrix)

    policies = {
        "learned": {r.workload: model.predict_name(r.features)
                    for r in rows},
        "trace_augmented": {
            r.workload: trace_model.predict_name({**r.features, **r.trace})
            for r in rows},
        "static_full": {
            r.workload: specialize(TABLE_III[r.app],
                                   profs[r.input_name]).name
            for r in rows},
        "static_partial": {
            r.workload: specialize_partial(TABLE_III[r.app],
                                           profs[r.input_name]).name
            for r in rows},
    }

    def seconds_of(r, name):
        return r.seconds[sl.project_config(name, avail)]

    def accuracy(choice, tolerance):
        ok = sum(seconds_of(r, choice[r.workload])
                 <= r.seconds[r.label] * (1.0 + tolerance) for r in rows)
        return ok / len(rows)

    def geomean_us(choice_fn):
        return _geomean(seconds_of(r, choice_fn(r)) * 1e6 for r in rows)

    acc = {}
    for pname, choice in policies.items():
        acc[pname] = accuracy(choice, 0.0)
        acc[f"{pname}_tol"] = accuracy(choice, tol)
    geo = {p: geomean_us(lambda r, c=c: c[r.workload])
           for p, c in policies.items()}
    geo["oracle"] = geomean_us(lambda r: r.label)
    always = {c: geomean_us(lambda r, c=c: c) for c in avail}
    best_always = min(always, key=always.get)
    speedup = always[best_always] / geo["learned"]

    per_workload = {
        r.workload: {
            "best": r.label,
            **{p: sl.project_config(c[r.workload], avail)
               for p, c in policies.items()},
        } for r in rows}

    result = {
        "smoke": bool(smoke),
        "workload": {
            "matrix": matrix["workload"], "tol": tol,
            "max_depth": max_depth, "features": list(sl.FEATURES),
            "n_workloads": len(rows), "configs": avail,
        },
        "model": {
            "path": model_path,
            "version": sl.MODEL_VERSION,
            "classes": list(model.classes),
            "depth": model.to_json()["depth"],
            "n_leaves": model.to_json()["n_leaves"],
            "label_histogram": model.meta["label_histogram"],
        },
        "accuracy": acc,
        "e2e": {
            "geomean_us": {**geo, "always": always},
            "best_always": {"config": best_always,
                            "geomean_us": always[best_always]},
            "speedup_vs_best_always": speedup,
        },
        "per_workload": per_workload,
        "gate": {
            "accuracy_ge_partial": acc["learned_tol"]
            >= acc["static_partial_tol"],
            "e2e_ge_best_always": speedup >= 1.0,
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    print(f"specialize: model -> {model_path} "
          f"(depth={result['model']['depth']}, "
          f"leaves={result['model']['n_leaves']})", flush=True)
    for pname in policies:
        print(f"specialize {pname}: accuracy={acc[pname]:.3f} "
              f"(tol {tol:.0%}: {acc[pname + '_tol']:.3f}) "
              f"geomean={geo[pname]:.1f}us", flush=True)
    print(f"specialize_summary,{len(rows)},learned_acc="
          f"{acc['learned_tol']:.3f};partial_acc="
          f"{acc['static_partial_tol']:.3f};"
          f"speedup_vs_always_{best_always}={speedup:.2f}x", flush=True)
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="results/BENCH_specialize.json")
    ap.add_argument("--matrix", default="results/BENCH_matrix.json",
                    help="matrix artifact to train/evaluate on")
    ap.add_argument("--model-out", default="results/specialize_model.json")
    ap.add_argument("--smoke", action="store_true",
                    help="expect a --smoke matrix (the CI job)")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL)
    args = ap.parse_args(argv)
    run_specialize(out_path=args.out, matrix_path=args.matrix,
                   model_out=args.model_out, smoke=args.smoke,
                   tol=args.tol)


if __name__ == "__main__":
    main()
