"""Roofline analysis (EXPERIMENTS.md §Roofline) from dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds per step:

    compute    = FLOPs / (chips x 197e12)           [bf16 peak / chip]
    memory     = HBM bytes / (chips x 819e9)
    collective = collective bytes / (chips x 50e9)  [ICI link BW]

FLOPs/bytes come from two sources, both reported:
- ``hlo_*``: ``compiled.cost_analysis()`` — NOTE XLA counts while-loop
  (scan) bodies ONCE, so scanned models are undercounted by ~n_layers x.
- ``analytic_*``: closed-form per family (the standard MFU convention);
  used for the roofline terms.  MODEL_FLOPS = 6*N*D (dense) or
  6*N_active*D (MoE); the ratio MODEL_FLOPS/analytic total shows how much
  compiled compute is "useful".

Collective bytes are parsed from post-SPMD HLO (per-device shapes); the
same while-body caveat applies and is listed per cell.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.registry import get_arch

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

__all__ = ["analyze", "analytic_cell"]


# ---------------------------------------------------------------------------
# analytic FLOP / byte models
# ---------------------------------------------------------------------------
def _lm_terms(cfg, shape, moe=False):
    L, d, hq, hkv, dh = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                         cfg.n_kv_heads, cfg.d_head)
    v = cfg.vocab
    n_total = cfg.n_params
    n_active = cfg.n_active_params if moe else n_total
    p_bytes = 2 * n_total                      # bf16
    opt_bytes = 8 * n_total                    # 2 x fp32 moments
    if shape == "train_4k":
        b, s = 256, 4096
        t = b * s
        flops = 6 * n_active * t
        win = cfg.window or s
        flops += 12 * L * b * hq * dh * s * min(s, win) * 0.5  # causal attn
        bytes_ = 3 * p_bytes + 2 * opt_bytes \
            + 12 * L * t * d                   # act r/w + remat reread, bf16
        return flops, bytes_, t
    if shape == "prefill_32k":
        b, s = 32, 32768
        t = b * s
        flops = 2 * n_active * t
        win = cfg.window or s
        flops += 4 * L * b * hq * dh * s * min(s, win) * 0.5
        bytes_ = p_bytes + 6 * L * t * d
        return flops, bytes_, t
    # decode shapes: one token per sequence
    b, s = (128, 32768) if shape == "decode_32k" else (1, 524288)
    win = cfg.window or s
    kv = min(s, win)
    flops = 2 * n_active * b + 4 * L * b * hq * dh * kv
    cache_bytes = 2 * L * b * hkv * s * dh * 2      # k+v bf16 (allocated)
    read_cache = 2 * L * b * hkv * kv * dh * 2      # bytes actually read
    bytes_ = p_bytes + read_cache
    return flops, bytes_, b


def _gnn_terms(name, dims):
    n, e = dims["n_nodes"], dims["n_edges"]
    if name == "meshgraphnet":
        h, L = 128, 15
        fl = 3 * L * (8 * e * h * h + 6 * n * h * h)
        by = 3 * L * (e * h * 4 * 3 + n * h * 4 * 3)
    elif name == "schnet":
        h, L, rbf = 64, 3, 300
        fl = 3 * L * (2 * e * (rbf * h + h * h) + 6 * n * h * h)
        by = 3 * L * (e * (rbf + h) * 4 + n * h * 4 * 3)
    elif name == "pna":
        h, L = 75, 4
        fl = 3 * L * (4 * e * h * h + 26 * n * h * h)
        by = 3 * L * (e * h * 4 * 2 + n * 13 * h * 4)
    else:  # equiformer-v2 (estimate; SH+Wigner+SO2+node linear)
        c, L, k = 128, 12, 49
        per_edge = 940 * c + 120 * c * c + 64 * k * 12   # rot+conv+SH
        per_node = 2 * k * c * c + 8 * c * c
        fl = 3 * L * (e * per_edge + n * per_node)
        by = 3 * L * (e * k * c * 4 + n * k * c * 4) // 4
    return fl, by, n


def _dlrm_terms(cfg, shape):
    d = cfg.embed_dim
    bot = [(13, 512), (512, 256), (256, 128)]
    nf = cfg.n_sparse + 1
    n_int = nf * (nf - 1) // 2 + d
    top = [(n_int, 1024), (1024, 1024), (1024, 512), (512, 256), (256, 1)]
    mlp_flops = 2 * (sum(a * b for a, b in bot) + sum(a * b for a, b in top))
    inter = 2 * nf * nf * d
    if shape == "train_batch":
        b = 65536
        fl = 3 * b * (mlp_flops + inter)
        by = b * cfg.n_sparse * d * 4 * 3 + b * (13 + n_int) * 4 * 3
        return fl, by, b
    if shape == "serve_p99":
        b = 512
    elif shape == "serve_bulk":
        b = 262144
    else:  # retrieval_cand
        nc = 1000448
        fl = 2 * nc * d + mlp_flops
        by = nc * d * 4
        return fl, by, 1
    fl = b * (mlp_flops + inter)
    by = b * cfg.n_sparse * d * 4 + b * (13 + n_int) * 4
    return fl, by, b


def analytic_cell(arch_name, shape):
    arch = get_arch(arch_name)
    if arch.family in ("lm", "moe"):
        fl, by, unit = _lm_terms(arch.cfg, shape, moe=arch.family == "moe")
        n = arch.cfg.n_params
        n_act = getattr(arch.cfg, "n_active_params", n)
        tokens = unit
        model_flops = 6 * n_act * tokens if shape.startswith("train") \
            else 2 * n_act * tokens
        return fl, by, model_flops
    if arch.family == "gnn":
        from repro.configs.base import GNN_SHAPES
        fl, by, _ = _gnn_terms(arch_name, GNN_SHAPES[shape])
        return fl, by, fl
    fl, by, _ = _dlrm_terms(arch.cfg, shape)
    return fl, by, fl


# ---------------------------------------------------------------------------
def analyze(dryrun_dir="results/dryrun", out="results/roofline.json",
            mesh="single"):
    rows = []
    for path in sorted(Path(dryrun_dir).glob(f"*__{mesh}.json")):
        d = json.loads(path.read_text())
        if not d.get("ok"):
            continue
        chips = d["n_devices"]
        arch, shape = d["arch"], d["shape"]
        fl, by, model_fl = analytic_cell(arch, shape)
        coll = d.get("collectives", {})
        coll_bytes = sum(v.get("bytes", 0) for v in coll.values()
                         if isinstance(v, dict))
        t_comp = fl / (chips * PEAK_FLOPS)
        t_mem = by / (chips * HBM_BW)
        t_coll = coll_bytes / ICI_BW          # already per-device bytes
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        hlo_fl = d.get("cost", {}).get("flops", -1)
        rows.append({
            "arch": arch, "shape": shape, "mesh": d["mesh"],
            "chips": chips,
            "analytic_flops": fl,
            "hlo_flops_per_dev_raw": hlo_fl,
            "model_flops": model_fl,
            "useful_ratio": round(model_fl / fl, 3) if fl else None,
            "analytic_bytes": by,
            "collective_bytes_per_dev": coll_bytes,
            "collectives": coll,
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "roofline_bound_s": bound,
            "roofline_fraction": round(t_comp / bound, 4) if bound else None,
            "memory_per_dev_bytes": d.get("memory", {}).get("peak_bytes"),
        })
    Path(out).write_text(json.dumps(rows, indent=2))
    return rows


def to_markdown(rows):
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | "
           "dominant | peak GB/dev | useful |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    fmt = lambda s: f"{s*1e3:.2f}ms" if s >= 1e-3 else f"{s*1e6:.0f}us"
    for r in rows:
        mem = r["memory_per_dev_bytes"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | "
            f"**{r['dominant']}** | "
            f"{mem/1e9:.2f} | {r['useful_ratio']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = analyze()
    print(to_markdown(rows))
