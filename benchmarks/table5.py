"""Table V reproduction: the specialization model's predictions.

(a) *Paper-faithful*: predictions from the published Table II classes —
    must equal Table V exactly (36/36; also enforced by tests/test_model).
(b) *Deployed*: predictions from classes measured on our recreations vs.
    the empirical best from the Fig.-5 sweep (results/fig5.json) on THIS
    backend — reports prediction quality the way the paper's Sec. VI does
    (exact hits + performance gap of mispredictions).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import TABLE_III, GraphProfile, specialize
from repro.core.taxonomy import profile_graph
from repro.graph.datasets import PAPER_STATS, paper_graph

__all__ = ["run_table5"]

TABLE_V = {
    "AMZ": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR", CC="DD1"),
    "DCT": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR", CC="DD1"),
    "EML": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR", CC="DD1"),
    "OLS": dict(PR="SDR", SSSP="SDR", MIS="TG0", CLR="TG0", BC="SDR", CC="DD1"),
    "RAJ": dict(PR="SDR", SSSP="SDR", MIS="SDR", CLR="SDR", BC="SDR", CC="DD1"),
    "WNG": dict(PR="SGR", SSSP="SGR", MIS="SGR", CLR="SGR", BC="SGR", CC="DD1"),
}


def run_table5(out_dir="results", fig5_path="results/fig5.json", scale=32):
    # (a) paper-faithful
    exact = 0
    preds = {}
    for gname, stats in PAPER_STATS.items():
        prof = GraphProfile.from_classes(*stats[7:10])
        preds[gname] = {}
        for app in TABLE_V[gname]:
            p = specialize(TABLE_III[app], prof).name
            preds[gname][app] = p
            exact += p == TABLE_V[gname][app]
    paper_faithful = {"predictions": preds, "match_table_v": f"{exact}/36"}

    # (b) deployed (measured classes + measured best)
    deployed = {}
    fig5 = {}
    if Path(fig5_path).exists():
        fig5 = json.loads(Path(fig5_path).read_text())
    hits, within = 0, []
    for gname in TABLE_V:
        prof = profile_graph(paper_graph(gname, scale=scale))
        for app in TABLE_V[gname]:
            pred = specialize(TABLE_III[app], prof).name
            key = f"{gname}/{app}"
            entry = {"predicted": pred,
                     "measured_classes": [prof.volume_class,
                                          prof.reuse_class,
                                          prof.imbalance_class]}
            if key in fig5:
                row = fig5[key]["configs"]
                best = fig5[key]["best"]
                entry["empirical_best"] = best
                entry["hit"] = pred == best
                if pred in row:
                    gap = row[pred]["seconds"] / row[best]["seconds"] - 1
                    entry["gap_vs_best"] = round(gap, 4)
                    within.append(gap)
                hits += entry.get("hit", False)
            deployed[key] = entry
    out = {
        "paper_faithful": paper_faithful,
        "deployed": deployed,
        "deployed_exact_hits": hits,
        "deployed_mean_gap": (sum(within) / len(within)) if within else None,
    }
    Path(out_dir).mkdir(exist_ok=True, parents=True)
    Path(out_dir, "table5.json").write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    res = run_table5()
    print("paper-faithful:", res["paper_faithful"]["match_table_v"])
    print("deployed exact hits:", res["deployed_exact_hits"],
          "mean gap:", res["deployed_mean_gap"])
