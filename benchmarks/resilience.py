"""Resilience benchmark: checkpointing overhead and recovery payoff,
tracked as ``results/BENCH_resilience.json``.

Two questions, both against the pinned dispatch workload so the
trajectory is comparable across commits:

1. **What does checkpointing cost when nothing goes wrong?**  PR (the
   longest-converging pinned app — see ``APP``) runs every cell of the
   18-config design space under the plain fused engine and under
   ``checkpoint_every=DEFAULT_CHECKPOINT_EVERY`` with the full
   sentinel battery; ``efficiency = fused_us / ckpt_us`` (1.0 = free)
   and the two final states must be **bit-identical** — segmenting the
   while_loop never changes the math, it only bounds how much a fault
   can destroy.

2. **What does a checkpoint buy when something does go wrong?**  A NaN
   is injected late into a PR run (the app with the longest pinned
   convergence) and recovery is timed with a warm checkpoint ring
   (rolls back one short segment) vs ``ring_capacity=1`` (only the
   pinned initial snapshot survives — cold-restart semantics).
   ``recovery_speedup = cold_seconds / ckpt_seconds``.

The CI gate (benchmarks/compare.py) tracks both, capped below their
noise floors like the serve metrics: healthy runs saturate the caps
and read exactly 1.0 run-to-run, so the gate only trips when
checkpointing genuinely stops being cheap (or recovery stops beating
a cold restart) — or when any config loses bit-identity, which the
``identical`` metric turns into an unmissable regression.
"""
from __future__ import annotations

import json
import math
import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

import jax
import numpy as np

from benchmarks.dispatch import PINNED_WORKLOAD
from repro.algorithms import REGISTRY
from repro.core import ALL_CONFIGS, SystemConfig, run
from repro.core.resilience import (DEFAULT_CHECKPOINT_EVERY,
                                   DEFAULT_RING_CAPACITY, RetryPolicy)
from repro.graph import rmat_graph
from repro.testing.faults import NaNFault

__all__ = ["run_resilience_bench"]

#: PR, not BFS: the overhead question is only meaningful against a run
#: long enough to amortize a segment boundary (PR's pinned convergence
#: is ~20 iterations; BFS converges in 4, where the one boundary
#: snapshot reads as a huge relative "overhead" of a degenerate run).
APP = "PR"
RECOVERY_APP = "PR"
REPEATS = 10
SMOKE_SCALE = 9
#: recovery segment length: short relative to PR's pinned convergence
#: (~24 iterations) so the warm ring resumes close to the fault while
#: the cold restart replays the whole prefix.
RECOVERY_K = 4


def _states_equal(a, b) -> bool:
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    return True


def _best(fn, repeats):
    best = None
    for _ in range(repeats):
        r = fn()
        if best is None or r.seconds < best.seconds:
            best = r
    return best


def run_resilience_bench(out_path: str = "results/BENCH_resilience.json",
                         smoke: bool = False,
                         repeats: int | None = None) -> dict:
    repeats = repeats if repeats is not None else (5 if smoke else REPEATS)
    wl = dict(PINNED_WORKLOAD)
    if smoke:
        wl["scale"] = SMOKE_SCALE
    program = REGISTRY[APP]()
    g = rmat_graph(weighted=program.weighted, **wl)
    K = DEFAULT_CHECKPOINT_EVERY

    configs = {}
    for cfg in ALL_CONFIGS:
        config = SystemConfig.from_name(cfg.name)
        plain = _best(lambda: run(program, g, config), repeats)
        ckpt = _best(lambda: run(program, g, config, checkpoint_every=K),
                     repeats)
        plain_us = plain.seconds * 1e6 / max(plain.iterations, 1)
        ckpt_us = ckpt.seconds * 1e6 / max(ckpt.iterations, 1)
        configs[cfg.name] = {
            "fused_us_per_iteration": plain_us,
            "ckpt_us_per_iteration": ckpt_us,
            "iterations": ckpt.iterations,
            "efficiency": plain_us / max(ckpt_us, 1e-12),
            "bit_identical": _states_equal(plain.state, ckpt.state),
        }

    # recovery: fault late in the longest-running pinned app, recover
    # from a warm ring vs from only the pinned initial snapshot
    rprog = REGISTRY[RECOVERY_APP]()
    rcfg = SystemConfig.from_name("DG1")
    clean = run(rprog, g, rcfg)
    at = max(2 * RECOVERY_K, clean.iterations - RECOVERY_K)
    retry = RetryPolicy(max_attempts=3)

    def recover(capacity):
        def once():
            t0 = time.perf_counter()
            r = run(rprog, g, rcfg, checkpoint_every=RECOVERY_K,
                    retry=retry, ring_capacity=capacity,
                    fault_injector=NaNFault(at_iteration=at))
            assert r.converged and r.fault["recovered"], r.outcome
            r.seconds = time.perf_counter() - t0
            return r
        return _best(once, repeats)

    warm = recover(DEFAULT_RING_CAPACITY)
    cold = recover(1)
    recovery = {
        "app": RECOVERY_APP, "fault": "nan", "at_iteration": int(at),
        "checkpoint_every": RECOVERY_K,
        "clean_iterations": clean.iterations,
        "ckpt_seconds": warm.seconds,
        "cold_restart_seconds": cold.seconds,
        "recovery_speedup": cold.seconds / max(warm.seconds, 1e-12),
    }

    effs = [c["efficiency"] for c in configs.values()]
    geomean_eff = math.exp(sum(math.log(max(e, 1e-12)) for e in effs)
                           / len(effs))
    result = {
        "workload": {"generator": "rmat", **wl, "app": APP,
                     "n_nodes": g.n_nodes, "n_edges": g.n_edges},
        "smoke": bool(smoke),
        "checkpoint_every": K,
        "repeats": repeats,
        "configs": configs,
        "recovery": recovery,
        "summary": {
            "n_configs": len(configs),
            "n_bit_identical": sum(c["bit_identical"]
                                   for c in configs.values()),
            "geomean_efficiency": geomean_eff,
            "geomean_overhead_pct": (1.0 / geomean_eff - 1.0) * 100.0,
            "recovery_speedup": recovery["recovery_speedup"],
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    s = result["summary"]
    print(f"resilience_bench,{len(configs)},"
          f"bit_identical={s['n_bit_identical']}/{s['n_configs']};"
          f"ckpt_overhead={s['geomean_overhead_pct']:.1f}%;"
          f"recovery_speedup={s['recovery_speedup']:.2f}x", flush=True)
    return result


if __name__ == "__main__":
    run_resilience_bench(smoke="--smoke" in sys.argv[1:])
