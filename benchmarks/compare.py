"""CI perf-regression gate over the tracked benchmark artifacts.

Diffs the current
``results/BENCH_{dispatch,autotune,batch,matrix,serve,resilience,chaos,
specialize}.json`` against
committed baselines under ``results/baselines/`` and **fails** (exit 1)
when an artifact's geomean regression exceeds the threshold
(default 20%).  docs/BENCHMARKS.md documents every artifact, its gate
metrics and the refresh workflow.

What is compared: the **within-run speedup ratios** each artifact
records — fused-vs-host per config (dispatch), tuned-vs-default per
workload x config (autotune), batched-vs-sequential per config x batch
size (batch), best-config-vs-TG0 per workload (matrix),
gateway-vs-serial-server throughput and p99 ratios per arrival mode
(serve), plain-vs-checkpointed efficiency plus cold-vs-warm recovery
speedup and per-config bit-identity (resilience), crash-recovery
bit-identity / lost-work containment / overload containment as
1.0-vs-1e-6 invariants (chaos), learned-specializer accuracy and
e2e-vs-always-X invariants (specialize) — *not* absolute
microseconds.  Ratios are measured
against a same-machine denominator, so a baseline recorded on one
machine remains meaningful on a differently-provisioned CI runner;
absolute-time gates would only measure the hardware.  A "regression"
is therefore a drop in what the subsystem *buys* (e.g. the fused
engine's advantage shrinking because per-iteration overhead crept
back), which is exactly the property these artifacts exist to track.

Per metric the regression ratio is ``baseline_speedup /
current_speedup`` (> 1 means worse); the gate fails an artifact when
the **geomean** of its ratios exceeds ``1 + threshold`` — single-cell
noise averages out, systematic slowdowns do not.

Baselines must be *compatible*: same pinned workload parameters and the
same smoke flag (a smoke run is a different workload, not a noisy
full run).  Incompatible or missing baselines exit 2 — refresh them
(see README "Refreshing perf baselines"): run the benchmarks, eyeball
the numbers, then ``python -m benchmarks.compare --update-baselines``
and commit the copies under ``results/baselines/``.
"""
from __future__ import annotations

import argparse
import json
import math
import shutil
import sys
from pathlib import Path

__all__ = ["extract_metrics", "fingerprint", "compare_artifact",
           "compare_dirs", "ARTIFACTS", "DEFAULT_THRESHOLD"]

#: artifact kind -> tracked file name.
ARTIFACTS = {
    "dispatch": "BENCH_dispatch.json",
    "autotune": "BENCH_autotune.json",
    "batch": "BENCH_batch.json",
    "matrix": "BENCH_matrix.json",
    "serve": "BENCH_serve.json",
    "resilience": "BENCH_resilience.json",
    "chaos": "BENCH_chaos.json",
    "specialize": "BENCH_specialize.json",
}
DEFAULT_THRESHOLD = 0.20

#: serve metrics are clamped at caps *below* their run-to-run noise
#: floor (closed-loop speedup swings ~1.7-4.2x with thread scheduling;
#: open-loop p99_gain 5-10x): healthy runs saturate every cap, so the
#: gate reads exactly 1.0 between runs and trips only when the gateway
#: genuinely stops paying for itself (throughput advantage lost, or
#: tail latency no longer better than the serial server's).
SERVE_CAPS = {
    ("closed", "throughput_speedup"): 1.5,
    ("closed", "p99_gain"): 1.5,
    ("open", "throughput_speedup"): 1.15,
    ("open", "p99_gain"): 1.5,
}

#: same cap idiom for the resilience artifact: checkpointing efficiency
#: (fused_us / ckpt_us) sits ~0.95-1.0 with a few-% noise band, so the
#: gate clamps at 0.90 — it trips only when checkpoint boundaries cost
#: real time again; recovery_speedup (cold restart / warm ring) swings
#: with how late the injected fault lands relative to convergence, so
#: it clamps just above break-even.  Bit-identity is uncapped on
#: purpose: any config losing it drives its ratio through the roof.
RESILIENCE_EFFICIENCY_CAP = 0.90
RESILIENCE_RECOVERY_CAP = 1.1

#: the learned specializer's e2e advantage over the best single-config
#: policy is clamped at break-even + margin: the >= 1.0x acceptance
#: bound is enforced by the ``e2e_ge_best_always`` invariant, and
#: headroom above it varies with which cells the fresh matrix measured
#: fastest — not something to hold future runs to
SPECIALIZE_CAP = 1.05


def extract_metrics(kind: str, data: dict) -> dict:
    """The artifact's tracked speedup metrics as ``{name: ratio}``."""
    out = {}
    if kind == "dispatch":
        for cfg, cell in data.get("configs", {}).items():
            out[f"dispatch/{cfg}/fused_speedup"] = cell["fused_speedup"]
    elif kind == "autotune":
        for wl, w in data.get("workloads", {}).items():
            for cfg, cell in w.get("configs", {}).items():
                out[f"autotune/{wl}/{cfg}/speedup"] = cell["speedup"]
    elif kind == "batch":
        for cfg, per_b in data.get("configs", {}).items():
            for b, cell in per_b.items():
                out[f"batch/{cfg}/B{b}/speedup"] = cell["speedup"]
    elif kind == "matrix":
        for wl, cell in data.get("cells", {}).items():
            out[f"matrix/{wl}/specialization_gain"] = (
                cell["specialization_gain"])
    elif kind == "serve":
        for mode, cell in data.get("modes", {}).items():
            for metric in ("throughput_speedup", "p99_gain"):
                cap = SERVE_CAPS.get((mode, metric), 1.5)
                out[f"serve/{mode}/{metric}"] = min(cell[metric], cap)
    elif kind == "resilience":
        for cfg, cell in data.get("configs", {}).items():
            out[f"resilience/{cfg}/efficiency"] = min(
                cell["efficiency"], RESILIENCE_EFFICIENCY_CAP)
            # 1e-6, not 0: a config that loses bit-identity against a
            # clean baseline blows its ratio up to 1e6 (the gate can't
            # miss it), while two matching runs still read exactly 1.0
            out[f"resilience/{cfg}/identical"] = (
                1.0 if cell["bit_identical"] else 1e-6)
        rec = data.get("recovery", {})
        if rec:
            out["resilience/recovery/speedup"] = min(
                rec["recovery_speedup"], RESILIENCE_RECOVERY_CAP)
    elif kind == "chaos":
        # every chaos metric is a 1.0-vs-1e-6 invariant: recovery
        # wall-clock is noise, but losing bit-identity, replaying the
        # whole run (lost_work_ratio >= 1 means durable checkpoints
        # bought nothing over cold restart), or overload breaking an
        # admitted request must blow the gate up unmissably
        core = data.get("core", {})
        if core:
            out["chaos/core/identical"] = (
                1.0 if core.get("bit_identical") else 1e-6)
            out["chaos/core/lost_work_contained"] = (
                1.0 if core.get("lost_work_ratio", 1.0) < 1.0 else 1e-6)
        gw = data.get("gateway", {})
        for app, cell in gw.get("apps", {}).items():
            out[f"chaos/gateway/{app}/identical"] = (
                1.0 if cell.get("bit_identical") else 1e-6)
        if gw:
            out["chaos/gateway/lost_work_contained"] = (
                1.0 if gw.get("lost_work_ratio", 1.0) < 1.0 else 1e-6)
        ov = data.get("overload", {})
        if ov:
            out["chaos/overload/contained"] = (
                1.0 if ov.get("contained") else 1e-6)
    elif kind == "specialize":
        # the two acceptance invariants as 1.0-vs-1e-6 metrics (the
        # chaos idiom): the learned model must pick at least as well as
        # the static partial tree, and its e2e geomean must beat every
        # always-one-config policy
        acc = data.get("accuracy", {})
        gate = data.get("gate", {})
        if gate:
            out["specialize/accuracy_ge_partial"] = (
                1.0 if gate.get("accuracy_ge_partial") else 1e-6)
            out["specialize/e2e_ge_best_always"] = (
                1.0 if gate.get("e2e_ge_best_always") else 1e-6)
        # the tolerant accuracy itself, as a ratio: labels come from
        # the same run's measurements, so this is stable within the
        # normal threshold and trips only on a real model regression
        if "learned_tol" in acc:
            out["specialize/accuracy_learned_tol"] = max(
                acc["learned_tol"], 1e-6)
        spd = data.get("e2e", {}).get("speedup_vs_best_always")
        if spd is not None:
            # capped at the invariant's break-even, like the serve
            # caps: extra headroom above 1.0x is workload luck, not a
            # property the gate should hold future runs to
            out["specialize/speedup_vs_best_always"] = min(spd,
                                                           SPECIALIZE_CAP)
    else:
        raise ValueError(f"unknown artifact kind {kind!r}")
    return out


def fingerprint(kind: str, data: dict) -> dict:
    """What must match between baseline and current for the diff to be
    meaningful: the pinned workload identity and the smoke flag."""
    if kind == "dispatch":
        return {"workload": data.get("workload")}
    if kind == "autotune":
        return {"smoke": data.get("smoke"),
                "workloads": {n: {"generator": w.get("generator"),
                                  "params": w.get("params")}
                              for n, w in data.get("workloads", {}).items()}}
    if kind == "batch":
        return {"smoke": data.get("smoke"),
                "workload": data.get("workload")}
    if kind == "matrix":
        # input sources matter: a run against real fetched graphs is a
        # different workload than one against the synthetic stand-ins
        return {"smoke": data.get("smoke"),
                "workload": data.get("workload"),
                "sources": {n: i.get("source")
                            for n, i in data.get("inputs", {}).items()}}
    if kind == "serve":
        return {"smoke": data.get("smoke"),
                "workload": data.get("workload")}
    if kind == "resilience":
        return {"smoke": data.get("smoke"),
                "workload": data.get("workload"),
                "checkpoint_every": data.get("checkpoint_every")}
    if kind == "chaos":
        return {"smoke": data.get("smoke"),
                "workload": data.get("workload")}
    if kind == "specialize":
        # carries the training matrix's pinned workload: a model
        # trained on a different sweep is a different experiment
        return {"smoke": data.get("smoke"),
                "workload": data.get("workload")}
    raise ValueError(f"unknown artifact kind {kind!r}")


def compare_artifact(kind: str, baseline: dict, current: dict,
                     threshold: float = DEFAULT_THRESHOLD) -> dict:
    """Diff one artifact; returns ``{status, geomean_ratio, ratios,
    worst, n_metrics}`` with status in {"ok", "regression",
    "incompatible", "empty"}."""
    if fingerprint(kind, baseline) != fingerprint(kind, current):
        return {"status": "incompatible", "n_metrics": 0,
                "geomean_ratio": None, "ratios": {}, "worst": [],
                "baseline": {}, "current": {}}
    base = extract_metrics(kind, baseline)
    cur = extract_metrics(kind, current)
    shared = sorted(set(base) & set(cur))
    ratios = {m: base[m] / max(cur[m], 1e-12) for m in shared}
    if not ratios:
        return {"status": "empty", "n_metrics": 0, "geomean_ratio": None,
                "ratios": {}, "worst": [], "baseline": {}, "current": {}}
    geomean = math.exp(sum(math.log(max(r, 1e-12))
                           for r in ratios.values()) / len(ratios))
    worst = sorted(ratios.items(), key=lambda kv: -kv[1])[:5]
    return {
        "status": "regression" if geomean > 1.0 + threshold else "ok",
        "n_metrics": len(ratios),
        "geomean_ratio": geomean,
        "ratios": ratios,
        "worst": worst,
        "baseline": base,
        "current": cur,
    }


def compare_dirs(baseline_dir: str, current_dir: str,
                 artifacts=None, threshold: float = DEFAULT_THRESHOLD,
                 allow_missing: bool = False) -> int:
    """Diff every requested artifact; prints a report, returns the exit
    code (0 pass, 1 regression, 2 missing/incompatible baseline)."""
    artifacts = artifacts or list(ARTIFACTS)
    base_dir, cur_dir = Path(baseline_dir), Path(current_dir)
    exit_code = 0
    for kind in artifacts:
        fname = ARTIFACTS[kind]
        bpath, cpath = base_dir / fname, cur_dir / fname
        if not cpath.exists():
            # a requested artifact the benchmarks did not produce would
            # silently un-gate itself if this were a pass — fail loudly
            # (CI runs every benchmark before the gate, so this only
            # fires when an output path drifted)
            if allow_missing:
                print(f"perf-gate {kind}: SKIP (no current {cpath})")
                continue
            print(f"perf-gate {kind}: MISSING current {cpath} — did the "
                  f"benchmark step run (or its --out path drift)?")
            exit_code = max(exit_code, 2)
            continue
        if not bpath.exists():
            if allow_missing:
                print(f"perf-gate {kind}: SKIP (no baseline {bpath})")
                continue
            print(f"perf-gate {kind}: MISSING baseline {bpath} — run the "
                  f"benchmarks and `--update-baselines` (see README)")
            exit_code = max(exit_code, 2)
            continue
        # a corrupt/truncated artifact must gate as loudly as a missing
        # one — an unhandled JSONDecodeError here would read as a CI
        # infrastructure flake instead of "your baseline is broken"
        try:
            baseline = json.loads(bpath.read_text())
        except (ValueError, OSError) as exc:
            print(f"perf-gate {kind}: UNREADABLE baseline {bpath} "
                  f"({exc}) — re-run the benchmarks and "
                  f"`python -m benchmarks.compare --update-baselines` "
                  f"(see README), then commit the refreshed copy")
            exit_code = max(exit_code, 2)
            continue
        try:
            current = json.loads(cpath.read_text())
        except (ValueError, OSError) as exc:
            print(f"perf-gate {kind}: UNREADABLE current {cpath} "
                  f"({exc}) — the benchmark step emitted a corrupt "
                  f"artifact; re-run it before gating")
            exit_code = max(exit_code, 2)
            continue
        rep = compare_artifact(kind, baseline, current, threshold)
        if rep["status"] == "incompatible":
            print(f"perf-gate {kind}: INCOMPATIBLE baseline (pinned "
                  f"workload or smoke flag changed) — refresh "
                  f"results/baselines/{fname}")
            exit_code = max(exit_code, 2)
            continue
        if rep["status"] == "empty":
            print(f"perf-gate {kind}: SKIP (no shared metrics)")
            continue
        gm = rep["geomean_ratio"]
        line = (f"perf-gate {kind}: geomean_regression="
                f"{(gm - 1) * 100:+.1f}% over {rep['n_metrics']} metrics "
                f"(threshold +{threshold * 100:.0f}%)")
        if rep["status"] == "regression":
            print(line + " — FAIL")
            # name each offender with what was measured vs what the
            # committed baseline recorded, so the CI log alone says
            # which artifact/metric regressed and by how much
            for name, r in rep["worst"]:
                print(f"  worst [{kind}]: {name} — measured "
                      f"{rep['current'][name]:.4g} vs baseline "
                      f"{rep['baseline'][name]:.4g} "
                      f"({(r - 1) * 100:+.1f}% regression)")
            exit_code = max(exit_code, 1)
        else:
            print(line + " — ok")
    return exit_code


def update_baselines(baseline_dir: str, current_dir: str,
                     artifacts=None) -> None:
    artifacts = artifacts or list(ARTIFACTS)
    base_dir = Path(baseline_dir)
    base_dir.mkdir(parents=True, exist_ok=True)
    for kind in artifacts:
        src = Path(current_dir) / ARTIFACTS[kind]
        if src.exists():
            shutil.copyfile(src, base_dir / ARTIFACTS[kind])
            print(f"baseline updated: {base_dir / ARTIFACTS[kind]}")
        else:
            print(f"baseline NOT updated ({src} missing)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default="results/baselines")
    ap.add_argument("--current-dir", default="results")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative geomean regression that fails the "
                         "gate (default 0.20 = 20%%)")
    ap.add_argument("--artifacts", default=",".join(ARTIFACTS),
                    help="comma-separated subset of "
                         + "/".join(ARTIFACTS))
    ap.add_argument("--allow-missing", action="store_true",
                    help="skip artifacts without a committed baseline "
                         "instead of failing")
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy the current artifacts over the baselines "
                         "instead of diffing")
    args = ap.parse_args(argv)
    artifacts = [a for a in args.artifacts.split(",") if a]
    unknown = [a for a in artifacts if a not in ARTIFACTS]
    if unknown:
        ap.error(f"unknown artifacts: {unknown}")
    if args.update_baselines:
        update_baselines(args.baseline_dir, args.current_dir, artifacts)
        return 0
    return compare_dirs(args.baseline_dir, args.current_dir, artifacts,
                        threshold=args.threshold,
                        allow_missing=args.allow_missing)


if __name__ == "__main__":
    sys.exit(main())
