"""Fig. 5 reproduction: apps x inputs x design-space configs, measured
execution time (converged runs, compile excluded) on the TPU-analogue
design space.  Static apps: TG0 + push {SG1, SGR, SD1, SDR} (the paper's
five shown bars); CC: DG1, DGR, DD1, DDR; the frontier traversal apps
(BFS, SSSP, BC) additionally run the dynamic cells, whose rows report the
per-iteration direction trace ("S"=push, "T"=pull) the frontier heuristic
chose — the axis that makes D* cells distinct behaviors, not relabels —
plus the sparse-gather residency: how many push iterations ran the
O(m_f) frontier-gathered path (``n_sparse``) and at what mean occupancy
of the static gather capacity (``mean_sparse_occupancy``).  A dynamic
cell whose sparse iterations show low occupancy is doing a small
fraction of the dense path's edge work — the speedup the D configs
exist for.

CPU wall-times stand in for the paper's simulated-GPU cycle counts: the
reproduction claim is qualitative (config rankings vary per workload; no
single winner), the exact ratios are hardware-specific by design.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from repro.algorithms import REGISTRY
from repro.core import SystemConfig, run
from repro.graph.datasets import PAPER_GRAPHS, paper_graph

__all__ = ["run_fig5", "STATIC_SHOWN", "DYNAMIC_SHOWN", "TRAVERSAL_APPS"]

STATIC_SHOWN = ("TG0", "SG1", "SGR", "SD1", "SDR")
DYNAMIC_SHOWN = ("DG1", "DGR", "DD1", "DDR")
#: frontier-protocol traversal apps (kept for harness consumers); since
#: the PR/CC/CLR/MIS port every registered app speaks the protocol and
#: runs the dynamic cells with a populated direction trace.
TRAVERSAL_APPS = ("BFS", "SSSP", "BC")
SCALE = 32
REPEATS = 3


def _configs_for(app: str):
    if app == "CC":
        # CC's hooking direction is inherently per-round (alternating):
        # the paper shows it on the dynamic cells only
        return DYNAMIC_SHOWN
    return STATIC_SHOWN + ("DG1", "DD1")


def run_fig5(out_dir="results", scale=SCALE, apps=None, graphs=None,
             engine="fused"):
    """Sweep apps x inputs x configs under one execution engine.

    ``engine="fused"`` (default) times pure device work — one
    ``lax.while_loop`` dispatch per run, so per-cell differences are
    kernel differences, not host round-trips.  Repeats and the 12-cell
    sweep itself amortize construction through the executor's plan
    cache: each graph's chunked edge orders and reducer tiling plans are
    built at most once per (order, n_chunks), not per cell.
    """
    apps = apps or list(REGISTRY)
    graphs = graphs or list(PAPER_GRAPHS)
    results = {}
    for gname in graphs:
        for app in apps:
            program = REGISTRY[app]()
            g = paper_graph(gname, scale=scale, weighted=program.weighted)
            configs = _configs_for(app)
            row = {}
            for cname in configs:
                cfg = SystemConfig.from_name(cname)
                best = float("inf")
                res = None
                for rep in range(REPEATS):
                    r = run(program, g, cfg, key=jax.random.key(0),
                            engine=engine)
                    best = min(best, r.seconds)
                    res = r
                row[cname] = {"seconds": best,
                              "iterations": res.iterations}
                if cname.startswith("D") and res.direction_trace is not None:
                    trace = res.direction_trace
                    row[cname]["directions"] = trace
                    row[cname]["n_push"] = trace.count("S")
                    row[cname]["n_pull"] = trace.count("T")
                    if res.occupancy_trace is not None:
                        row[cname]["n_sparse"] = res.sparse_iterations
                        row[cname]["n_dense"] = (res.iterations
                                                 - res.sparse_iterations)
                        occ = res.mean_sparse_occupancy
                        row[cname]["mean_sparse_occupancy"] = (
                            round(occ, 4) if occ is not None else None)
            base = row[configs[0]]["seconds"]
            for cname in configs:
                row[cname]["normalized"] = row[cname]["seconds"] / base
            best_cfg = min(row, key=lambda c: row[c]["seconds"])
            results[f"{gname}/{app}"] = {"configs": row, "best": best_cfg}
            dyn = " ".join(f"{c}:{row[c]['directions']}"
                           for c in configs
                           if "directions" in row[c])
            occ = " ".join(
                f"{c}:{row[c]['n_sparse']}/{row[c]['iterations']}"
                f"@{row[c]['mean_sparse_occupancy']}"
                for c in configs
                if row[c].get("n_sparse"))  # 0 sparse iters: nothing to show
            print(f"{gname}/{app}: best={best_cfg} "
                  + " ".join(f"{c}={row[c]['seconds']*1e3:.1f}ms"
                             for c in configs)
                  + (f" dirs[{dyn}]" if dyn else "")
                  + (f" sparse[{occ}]" if occ else ""), flush=True)
    Path(out_dir).mkdir(exist_ok=True, parents=True)
    Path(out_dir, "fig5.json").write_text(json.dumps(results, indent=2))
    return results


if __name__ == "__main__":
    run_fig5()
