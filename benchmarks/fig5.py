"""Fig. 5 reproduction: 6 apps x 6 inputs x design-space configs, measured
execution time (converged runs, compile excluded) on the TPU-analogue
design space.  Static apps: TG0 + push {SG1, SGR, SD1, SDR} (the paper's
five shown bars); CC: DG1, DGR, DD1, DDR.

CPU wall-times stand in for the paper's simulated-GPU cycle counts: the
reproduction claim is qualitative (config rankings vary per workload; no
single winner), the exact ratios are hardware-specific by design.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from repro.algorithms import REGISTRY
from repro.core import SystemConfig, run
from repro.graph.datasets import PAPER_GRAPHS, paper_graph

__all__ = ["run_fig5", "STATIC_SHOWN", "DYNAMIC_SHOWN"]

STATIC_SHOWN = ("TG0", "SG1", "SGR", "SD1", "SDR")
DYNAMIC_SHOWN = ("DG1", "DGR", "DD1", "DDR")
SCALE = 32
REPEATS = 3


def run_fig5(out_dir="results", scale=SCALE, apps=None, graphs=None):
    apps = apps or list(REGISTRY)
    graphs = graphs or list(PAPER_GRAPHS)
    results = {}
    for gname in graphs:
        for app in apps:
            program = REGISTRY[app]()
            g = paper_graph(gname, scale=scale, weighted=program.weighted)
            configs = DYNAMIC_SHOWN if app == "CC" else STATIC_SHOWN
            row = {}
            for cname in configs:
                cfg = SystemConfig.from_name(cname)
                best = float("inf")
                iters = 0
                for rep in range(REPEATS):
                    r = run(program, g, cfg, key=jax.random.key(0))
                    best = min(best, r.seconds)
                    iters = r.iterations
                row[cname] = {"seconds": best, "iterations": iters}
            base = row[configs[0]]["seconds"]
            for cname in configs:
                row[cname]["normalized"] = row[cname]["seconds"] / base
            best_cfg = min(row, key=lambda c: row[c]["seconds"])
            results[f"{gname}/{app}"] = {"configs": row, "best": best_cfg}
            print(f"{gname}/{app}: best={best_cfg} "
                  + " ".join(f"{c}={row[c]['seconds']*1e3:.1f}ms"
                             for c in configs), flush=True)
    Path(out_dir).mkdir(exist_ok=True, parents=True)
    Path(out_dir, "fig5.json").write_text(json.dumps(results, indent=2))
    return results


if __name__ == "__main__":
    run_fig5()
