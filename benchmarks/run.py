"""Benchmark harness entry point — one section per paper artifact.

Prints ``name,us_per_call,derived`` CSV rows; detailed JSON lands in
results/.  Fast subsets by default so `python -m benchmarks.run` finishes
on one CPU; pass --full for the complete Fig. 5 grid.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.fig5 import run_fig5
from benchmarks.fig6 import run_fig6
from benchmarks.table2 import run_table2
from benchmarks.table5 import run_table5


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 6x6 Fig.5 grid (slow); default is a "
                         "representative subset")
    ap.add_argument("--scale", type=int, default=32)
    ap.add_argument("--json", action="store_true",
                    help="additionally run the host-vs-fused engine "
                         "benchmark and write machine-readable "
                         "results/BENCH_dispatch.json (per-engine "
                         "us/iteration for the pinned RMAT workload "
                         "across the design-space configs)")
    ap.add_argument("--dispatch-only", action="store_true",
                    help="with --json: skip the paper-artifact sections "
                         "and only write BENCH_dispatch.json (CI uses "
                         "this to track the perf trajectory cheaply)")
    ap.add_argument("--autotune-only", action="store_true",
                    help="only run the reducer-autotuner benchmark and "
                         "write results/BENCH_autotune.json (tuned-vs-"
                         "default us/iteration across the 18 configs on "
                         "three degree profiles)")
    ap.add_argument("--autotune-smoke", action="store_true",
                    help="with --autotune-only: tiny graphs + 2-candidate "
                         "grid (the CI smoke job)")
    ap.add_argument("--batch-only", action="store_true",
                    help="only run the batched-serving benchmark and "
                         "write results/BENCH_batch.json (batched vs "
                         "sequential us/graph across batch sizes and the "
                         "18 configs)")
    ap.add_argument("--batch-smoke", action="store_true",
                    help="with --batch-only: tiny graphs, B<=4 (the CI "
                         "smoke job)")
    ap.add_argument("--serve-only", action="store_true",
                    help="only run the streaming-gateway load benchmark "
                         "and write results/BENCH_serve.json (continuous "
                         "batching vs serve-one-at-a-time throughput and "
                         "latency under closed- and open-loop arrivals)")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="with --serve-only: tiny pool, 64 requests (the "
                         "CI smoke job)")
    ap.add_argument("--resilience-only", action="store_true",
                    help="only run the checkpoint-overhead / fault-"
                         "recovery benchmark and write results/"
                         "BENCH_resilience.json (checkpointed-vs-plain "
                         "fused us/iteration across the 18 configs, "
                         "bit-identity, and warm-ring vs cold-restart "
                         "recovery from an injected NaN)")
    ap.add_argument("--resilience-smoke", action="store_true",
                    help="with --resilience-only: tiny graph, 3 repeats "
                         "(the CI smoke job)")
    ap.add_argument("--chaos-only", action="store_true",
                    help="only run the kill-and-restart chaos benchmark "
                         "and write results/BENCH_chaos.json (crash "
                         "recovery from durable checkpoints and the "
                         "gateway write-ahead journal: recovery seconds, "
                         "lost-work ratio, overload shed rate, end-state "
                         "bit-identity)")
    ap.add_argument("--chaos-smoke", action="store_true",
                    help="with --chaos-only: tiny graphs (the CI smoke "
                         "job)")
    ap.add_argument("--matrix-only", action="store_true",
                    help="only run the 6-app x 6-input workload matrix "
                         "and write results/BENCH_matrix.json (per-cell "
                         "seconds across the design-space configs plus "
                         "each workload's specialization gain over TG0)")
    ap.add_argument("--matrix-smoke", action="store_true",
                    help="with --matrix-only: tiny stand-ins, reduced "
                         "config set (the CI smoke job)")
    ap.add_argument("--specialize-only", action="store_true",
                    help="only train + evaluate the learned best-config "
                         "specializer on results/BENCH_matrix.json "
                         "(run --matrix-only first), refreshing results/"
                         "specialize_model.json and writing results/"
                         "BENCH_specialize.json (accuracy vs measured "
                         "best and e2e geomean vs always-X baselines)")
    ap.add_argument("--specialize-smoke", action="store_true",
                    help="with --specialize-only: expect a --smoke "
                         "matrix artifact (the CI smoke job)")
    args = ap.parse_args()

    print("name,us_per_call,derived")

    if args.matrix_only:
        from benchmarks.matrix import run_matrix
        run_matrix(smoke=args.matrix_smoke)
        return

    if args.specialize_only:
        from benchmarks.specialize import run_specialize
        run_specialize(smoke=args.specialize_smoke)
        return

    if args.autotune_only:
        from benchmarks.autotune import run_autotune
        run_autotune(smoke=args.autotune_smoke,
                     repeats=2 if args.autotune_smoke else 5)
        return

    if args.batch_only:
        from benchmarks.batch import run_batch_bench
        run_batch_bench(smoke=args.batch_smoke)
        return

    if args.serve_only:
        from benchmarks.serve import run_serve_bench
        run_serve_bench(smoke=args.serve_smoke)
        return

    if args.resilience_only:
        from benchmarks.resilience import run_resilience_bench
        run_resilience_bench(smoke=args.resilience_smoke)
        return

    if args.chaos_only:
        from benchmarks.chaos import run_chaos_bench
        run_chaos_bench(smoke=args.chaos_smoke)
        return

    if args.json or args.dispatch_only:  # --dispatch-only implies --json
        from benchmarks.dispatch import run_dispatch
        run_dispatch()
        if args.dispatch_only:
            return

    t0 = time.perf_counter()
    rows = run_table2()
    dt = (time.perf_counter() - t0) / max(len(rows), 1)
    n_class_ok = sum(
        r["computed_from_published"]["vol_class"]
        == r["published"]["vol_class"] for r in rows)
    print(f"table2_profile,{dt*1e6:.0f},vol_class_match={n_class_ok}/6")

    graphs = None if args.full else ["DCT", "RAJ", "OLS", "WNG"]
    apps = None if args.full else ["PR", "SSSP", "BFS", "MIS", "CLR", "CC"]
    t0 = time.perf_counter()
    fig5 = run_fig5(scale=args.scale, graphs=graphs, apps=apps)
    n_cells = len(fig5)
    dt = (time.perf_counter() - t0) / max(n_cells, 1)
    n_best_not_ref = sum(1 for v in fig5.values()
                         if v["best"] not in ("TG0", "DG1"))
    # dynamic cells whose frontier heuristic used BOTH directions in one
    # run — the per-iteration switching the D configs exist for
    n_mixed = sum(
        1 for v in fig5.values() for c, d in v["configs"].items()
        if c.startswith("D") and "S" in d.get("directions", "")
        and "T" in d.get("directions", ""))
    # dynamic cells where >=1 push iteration ran the O(m_f) sparse-
    # gathered path instead of the dense O(E) masked scan
    n_sparse_cells = sum(
        1 for v in fig5.values() for c, d in v["configs"].items()
        if c.startswith("D") and d.get("n_sparse", 0))
    print(f"fig5_sweep,{dt*1e6:.0f},cells={n_cells};"
          f"best_differs_from_ref={n_best_not_ref};"
          f"dyn_mixed_direction_cells={n_mixed};"
          f"dyn_sparse_gather_cells={n_sparse_cells}")

    t0 = time.perf_counter()
    t5 = run_table5(scale=args.scale)
    dt = time.perf_counter() - t0
    print(f"table5_model,{dt*1e6:.0f},"
          f"paper_faithful={t5['paper_faithful']['match_table_v']};"
          f"deployed_hits={t5['deployed_exact_hits']}")

    t0 = time.perf_counter()
    f6 = run_fig6()
    dt = time.perf_counter() - t0
    print(f"fig6_flexibility,{dt*1e6:.0f},cases={f6['n_cases']};"
          f"avg_reduction={f6['avg_reduction_pct']}%")

    # roofline (requires dry-run artifacts; skipped gracefully otherwise)
    try:
        from benchmarks.roofline import analyze
        src = "results/dryrun_opt" if Path("results/dryrun_opt").exists() \
            else "results/dryrun"
        rows = analyze(dryrun_dir=src)
        if rows:
            worst = min(rows, key=lambda r: r["roofline_fraction"] or 1)
            print(f"roofline,{len(rows)},cells={len(rows)};"
                  f"worst_fraction={worst['roofline_fraction']}"
                  f"@{worst['arch']}/{worst['shape']}")
        else:
            print("roofline,0,no_dryrun_artifacts")
    except Exception as exc:  # pragma: no cover
        print(f"roofline,0,error={exc}")


if __name__ == "__main__":
    main()
