"""Fig. 6 reproduction: workloads where SGR is NOT optimal — execution
time of the best (and predicted) config relative to SGR."""
from __future__ import annotations

import json
from pathlib import Path

__all__ = ["run_fig6"]


def run_fig6(out_dir="results", fig5_path="results/fig5.json"):
    fig5 = json.loads(Path(fig5_path).read_text())
    rows = {}
    reductions = []
    for key, entry in fig5.items():
        cfgs = entry["configs"]
        ref = "SGR" if "SGR" in cfgs else "DGR"
        best = entry["best"]
        if best == ref:
            continue
        red = 1.0 - cfgs[best]["seconds"] / cfgs[ref]["seconds"]
        rows[key] = {
            "ref": ref,
            "best": best,
            "best_over_ref": round(cfgs[best]["seconds"]
                                   / cfgs[ref]["seconds"], 4),
            "reduction_pct": round(100 * red, 1),
        }
        reductions.append(red)
    out = {
        "cases": rows,
        "n_cases": len(rows),
        "avg_reduction_pct": round(100 * sum(reductions)
                                   / max(len(reductions), 1), 1),
        "max_reduction_pct": round(100 * max(reductions, default=0.0), 1),
    }
    Path(out_dir, "fig6.json").write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    res = run_fig6()
    print(f"{res['n_cases']} workloads where the reference config is "
          f"not optimal; avg reduction {res['avg_reduction_pct']}%, "
          f"max {res['max_reduction_pct']}%")
