"""Autotuner benchmark: tuned-vs-default us/iteration, tracked as
``results/BENCH_autotune.json`` from this PR on.

Three pinned degree profiles — the Graph500 R-MAT workload the dispatch
benchmark also uses, a high-skew power-law graph and a near-regular
graph — each run BFS across **all 18 addressable configs**
(``ALL_CONFIGS``) under the fused engine with ``use_pallas=True``,
once with the static default reducer tiling (``autotune="off"``) and
once with empirically tuned plans (``autotune="measure"``).  Per cell
the file records both us/iteration figures and their ratio; per
workload it records the kernel-level tuning sweeps themselves
(candidate grid, measured seconds, winner) so the end-to-end ratios are
reproducible from first principles.

Cells whose tuned context resolves the *same* plans as the default one
(e.g. the ``S*G`` cells, which use no blocked reducer at all) execute
the identical compiled program, so the default measurement is reused
and their ratio is exactly 1.0 — re-timing an identical executable
would only add noise.

``--smoke`` is the CI job: a tiny graph per profile and a 2-candidate
grid, exercising the whole tune → cache → run pipeline in seconds.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

from repro.algorithms import REGISTRY
from repro.core import ALL_CONFIGS, SystemConfig, run
from repro.core.executor import EdgeContext
from repro.graph import powerlaw_graph, regular_graph, rmat_graph
from repro.kernels.autotune import (ORDERS, autotune_plan, degree_features,
                                    degree_signature, persist_tune_result,
                                    tune)

__all__ = ["run_autotune", "PINNED_WORKLOADS", "SMOKE_WORKLOADS"]

#: The pinned degree profiles — change them and the trajectory restarts.
PINNED_WORKLOADS = {
    "rmat": (rmat_graph, dict(scale=10, edge_factor=8, seed=7)),
    "skew": (powerlaw_graph,
             dict(n=2048, n_edges=24576, alpha=1.6, seed=5)),
    "regular": (regular_graph, dict(n=2048, degree=8, seed=5)),
}
#: CI smoke profiles: same shapes, tiny sizes.
SMOKE_WORKLOADS = {
    "rmat": (rmat_graph, dict(scale=7, edge_factor=8, seed=7)),
    "skew": (powerlaw_graph, dict(n=384, n_edges=4096, alpha=1.6, seed=5)),
    "regular": (regular_graph, dict(n=384, degree=6, seed=5)),
}
APP = "BFS"
REPEATS = 5


def _best_run(program, g, cfg, repeats, **kw):
    best = None
    for _ in range(repeats):
        r = run(program, g, cfg, use_pallas=True, **kw)
        if best is None or r.seconds < best.seconds:
            best = r
    return best


def _cell(result):
    return {
        "seconds": result.seconds,
        "iterations": result.iterations,
        "us_per_iteration": result.seconds * 1e6
        / max(result.iterations, 1),
    }


def run_autotune(out_path: str = "results/BENCH_autotune.json",
                 smoke: bool = False, repeats: int = REPEATS) -> dict:
    workloads = SMOKE_WORKLOADS if smoke else PINNED_WORKLOADS
    max_candidates = 2 if smoke else 6
    program = REGISTRY[APP]()
    out_workloads = {}
    for name, (gen, params) in workloads.items():
        g = gen(weighted=program.weighted, **params)
        feats = degree_features(g)

        # Kernel-level sweeps, recorded verbatim for reproducibility.
        # The winner is >= the default by construction (the default is
        # always one candidate).  The sweep's result seeds the disk
        # cache (overwriting any stale entry for this signature) so
        # autotune_plan — and through it every autotune="measure"
        # context below — recalls exactly this sweep instead of paying
        # an identical second one; the *resolved* plan the config runs
        # execute is recorded alongside as ground truth.
        tuning = {}
        for order in ORDERS:
            cap = (EdgeContext.default_sparse_capacity(g)
                   if order == "gathered" else None)
            res = tune(g, order=order, repeats=repeats,
                       max_candidates=max_candidates, cap_e=cap)
            tuning[order] = {
                "plan": dict(zip(("tile_e", "block_mult", "block_div",
                                  "gather_splits"), res.plan.astuple())),
                "kernel_speedup_vs_default": res.speedup_vs_default,
                "candidates": [
                    {"tile_e": p.tile_e, "block_mult": p.block_mult,
                     "block_div": p.block_div,
                     "gather_splits": p.gather_splits,
                     "us": s * 1e6} for p, s in res.measurements],
            }
            persist_tune_result(res, cap_e=cap)
            resolved = autotune_plan(g, order=order, mode="measure",
                                     repeats=repeats,
                                     max_candidates=max_candidates,
                                     cap_e=cap)
            tuning[order]["resolved_plan"] = dict(zip(
                ("tile_e", "block_mult", "block_div", "gather_splits"),
                resolved.astuple()))
            tuning[order]["resolved_source"] = resolved.source

        configs = {}
        for cfg in ALL_CONFIGS:
            config = SystemConfig.from_name(cfg.name)
            ctx_def = EdgeContext.create(g, config, use_pallas=True)
            ctx_tuned = EdgeContext.create(g, config, use_pallas=True,
                                           autotune="measure")
            default = _best_run(program, g, config, repeats)
            plans_differ = ctx_tuned.plan_signature != ctx_def.plan_signature
            if plans_differ:
                tuned = _best_run(program, g, config, repeats,
                                  autotune="measure")
                if tuned.seconds > default.seconds * 0.95:
                    # near-tie: best-of a second interleaved round for
                    # both modes so scheduler noise, not tiling, can't
                    # decide the reported ratio
                    d2 = _best_run(program, g, config, repeats)
                    t2 = _best_run(program, g, config, repeats,
                                   autotune="measure")
                    default = min(default, d2, key=lambda r: r.seconds)
                    tuned = min(tuned, t2, key=lambda r: r.seconds)
            else:
                # identical resolved plans => identical executable;
                # reuse the measurement instead of re-timing it
                tuned = default
            cell = {"default": _cell(default), "tuned": _cell(tuned),
                    "plans_differ": plans_differ}
            cell["speedup"] = (cell["default"]["us_per_iteration"]
                               / max(cell["tuned"]["us_per_iteration"],
                                     1e-12))
            configs[cfg.name] = cell

        speedups = [c["speedup"] for c in configs.values()]
        out_workloads[name] = {
            "generator": gen.__name__,
            "params": params,
            "n_nodes": g.n_nodes,
            "n_edges": g.n_edges,
            "degree_signature": degree_signature(feats),
            "features": feats,
            "tuning": tuning,
            "configs": configs,
            "summary": {
                "n_configs": len(configs),
                "regressions": sum(s < 1.0 for s in speedups),
                "tuned_cells": sum(c["plans_differ"]
                                   for c in configs.values()),
                "geomean_speedup": math.exp(
                    sum(math.log(s) for s in speedups) / len(speedups)),
                "max_speedup": max(speedups),
            },
        }

    geomeans = {n: w["summary"]["geomean_speedup"]
                for n, w in out_workloads.items()}
    result = {
        "app": APP,
        "repeats": repeats,
        "smoke": smoke,
        "workloads": out_workloads,
        "summary": {
            "total_regressions": sum(w["summary"]["regressions"]
                                     for w in out_workloads.values()),
            "geomean_by_workload": geomeans,
            "best_workload_geomean": max(geomeans.values()),
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    s = result["summary"]
    per_wl = ";".join(f"{n}={v:.2f}x" for n, v in geomeans.items())
    print(f"autotune_bench,{len(out_workloads) * len(ALL_CONFIGS)},"
          f"regressions={s['total_regressions']};{per_wl}", flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graphs + 2-candidate grid (the CI job)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--out", default="results/BENCH_autotune.json")
    args = ap.parse_args()
    repeats = args.repeats if args.repeats is not None else \
        (2 if args.smoke else REPEATS)
    run_autotune(out_path=args.out, smoke=args.smoke, repeats=repeats)
