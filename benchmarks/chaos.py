"""Chaos benchmark: kill-and-restart durability, tracked as
``results/BENCH_chaos.json``.

PR 8's resilience benchmark measures *in-process* recovery (a fault
healed inside one surviving process).  This harness measures the
crash-durability layer: the process itself dies — via
:class:`~repro.testing.faults.SimulatedProcessDeath`, a
``BaseException`` that no in-process retry net can catch — and a fresh
"process" must resume from what reached disk.  Three scenarios, all
seeded and deterministic:

1. **Core kill → resume** (``checkpoint_dir``): a long PR run is
   killed at the worst moment (``point="after_segment"``: a segment
   executed but its boundary checkpoint never persisted), then resumed
   from the on-disk :class:`~repro.core.durability.CheckpointStore`.
   Measured: recovery seconds, the **lost-work ratio** (iterations
   replayed / total — the killed segment must be replayed, everything
   older must not), and bit-identity of the resumed final state
   against an uninterrupted run.

2. **Gateway kill → journal recovery**: a journaled gateway serving a
   mixed stream (BFS / SSSP / CC — exact MIN-monoid apps, so
   bit-identity holds across arbitrary cohort changes) is killed
   mid-stream; a fresh scheduler replays the write-ahead journal
   (:meth:`~repro.launch.serve.ContinuousScheduler.recover`),
   re-admits every unfinished ticket from its newest persisted slice
   boundary and drives them to convergence.  Measured: recovery
   seconds, lost-work ratio across the recovered ticket set, and
   per-app end-state bit-identity against the uninterrupted gateway.

3. **Overload shedding at 2× capacity**: after a warm-up wave teaches
   the gateway its service time, a burst of deadline-carrying
   requests at twice the roster capacity hits ``submit``.  The
   projection must shed the requests whose deadline is already
   hopeless (structured ``OverloadError``) while every *admitted*
   request still completes — overload degrades admission, never
   correctness.

The CI gate (benchmarks/compare.py) tracks bit-identity (1.0 vs 1e-6 —
any loss is unmissable), lost-work containment (< 1.0: warm
checkpoints beat cold restart) and overload containment; recovery
seconds are recorded for trend-watching but not gated (wall-clock
noise).
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from tempfile import TemporaryDirectory

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

import numpy as np

from benchmarks.dispatch import PINNED_WORKLOAD
from repro.algorithms import REGISTRY
from repro.core import SystemConfig, run
from repro.core.durability import CheckpointStore
from repro.graph import rmat_batch, rmat_graph
from repro.launch.serve import ContinuousScheduler, OverloadError
from repro.testing.faults import (GatewayKillFault, ProcessKillFault,
                                  SimulatedProcessDeath)

__all__ = ["run_chaos_bench"]

CORE_APP = "PR"          # longest pinned convergence: the kill lands
                         # deep enough that cold restart is expensive
CORE_K = 4
GATEWAY_APPS = ("BFS", "SSSP", "CC")   # exact MIN-monoid: bit-identity
                                       # holds across cohort changes
SMOKE_SCALE = 9
GATEWAY_SCALE = 6
GATEWAY_POOL = 3
GATEWAY_REQUESTS = 6
KILL_AFTER_SLICES = 2


def _states_equal(a, b) -> bool:
    keys = sorted(a) if isinstance(a, dict) else None
    for k in (keys or []):
        if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
            return False
    return True


# ----------------------------------------------------------------------
def _core_chaos(smoke: bool) -> dict:
    wl = dict(PINNED_WORKLOAD)
    if smoke:
        wl["scale"] = SMOKE_SCALE
    program = REGISTRY[CORE_APP]()
    g = rmat_graph(weighted=program.weighted, **wl)
    config = SystemConfig.from_name("DG1")

    clean = run(program, g, config, checkpoint_every=CORE_K)
    total = clean.iterations
    kill_at = max(CORE_K, total - CORE_K)

    with TemporaryDirectory() as d:
        killed_it = 0
        try:
            run(program, g, config, checkpoint_every=CORE_K,
                checkpoint_dir=d,
                fault_injector=ProcessKillFault(at_iteration=kill_at,
                                                point="after_segment"))
            raise RuntimeError("kill injector never fired")
        except SimulatedProcessDeath:
            pass
        # what the dead process knew vs what reached disk: the killed
        # segment's end iteration minus the newest persisted boundary
        # is exactly the work that must be replayed
        cp, _ = CheckpointStore(d).load_latest()
        resume_it = cp.it if cp is not None else 0
        killed_it = min(resume_it + CORE_K, total)
        t0 = time.perf_counter()
        resumed = run(program, g, config, checkpoint_every=CORE_K,
                      checkpoint_dir=d)
        recovery_seconds = time.perf_counter() - t0

    replayed = killed_it - resume_it
    return {
        "app": CORE_APP, "checkpoint_every": CORE_K,
        "total_iterations": int(total), "kill_at": int(killed_it),
        "resume_iteration": int(resume_it),
        "replayed_iterations": int(replayed),
        "lost_work_ratio": replayed / max(total, 1),
        "cold_restart_ratio": killed_it / max(total, 1),
        "recovery_seconds": recovery_seconds,
        "bit_identical": _states_equal(clean.state, resumed.state),
        "converged": bool(resumed.converged),
    }


# ----------------------------------------------------------------------
def _gateway_chaos(smoke: bool) -> dict:
    scale = GATEWAY_SCALE if smoke else GATEWAY_SCALE + 2
    pool = rmat_batch(GATEWAY_POOL, scale, seed=7)
    apps = {}
    total_replayed = 0
    total_killed = 0
    total_iters = 0
    recovery_seconds = 0.0
    for app in GATEWAY_APPS:
        program = REGISTRY[app]()
        config = SystemConfig.from_name("DG1")

        ref = ContinuousScheduler(max_batch=4, slice_len=2)
        ref_tickets = [ref.submit(program, pool[i % GATEWAY_POOL], config)
                       for i in range(GATEWAY_REQUESTS)]
        ref.run_until_idle()
        ref_results = [t.result(0) for t in ref_tickets]

        with TemporaryDirectory() as d:
            sched = ContinuousScheduler(
                max_batch=4, slice_len=2, journal_dir=d,
                fault_injector=GatewayKillFault(
                    after_slices=KILL_AFTER_SLICES))
            tickets = [sched.submit(program, pool[i % GATEWAY_POOL],
                                    config)
                       for i in range(GATEWAY_REQUESTS)]
            try:
                sched.run_until_idle()
                raise RuntimeError("gateway kill never fired")
            except SimulatedProcessDeath:
                pass
            # progress the dead gateway had made (committed boundaries)
            killed_it = {}
            for lane in sched._lanes.values():
                for i, t in enumerate(lane.tickets):
                    if t is not None:
                        killed_it[t.jid] = lane.it_b[i]
                for t in lane.queue:
                    killed_it[t.jid] = 0

            t0 = time.perf_counter()
            fresh = ContinuousScheduler(max_batch=4, slice_len=2)
            recovered = fresh.recover(d)
            resume_it = {t.jid: (t._restore[1] if t._restore else 0)
                         for t in recovered}
            fresh.run_until_idle()
            recovery_seconds += time.perf_counter() - t0

        by_jid = {t.jid: t.result(0) for t in tickets if t.done()}
        by_jid.update({t.jid: t.result(0) for t in recovered})
        ordered = [by_jid[t.jid] for t in tickets]
        identical = all(
            _states_equal(r.state, c.state)
            for r, c in zip(ref_results, ordered))
        replayed = sum(killed_it[j] - resume_it[j] for j in resume_it)
        total_replayed += replayed
        total_killed += sum(killed_it.values())
        total_iters += sum(r.iterations for r in ordered)
        apps[app] = {
            "requests": GATEWAY_REQUESTS,
            "recovered": len(recovered),
            "replayed_iterations": int(replayed),
            "bit_identical": bool(identical),
            "all_converged": all(r.converged for r in ordered),
        }
    return {
        "apps": apps, "pool": GATEWAY_POOL, "scale": scale,
        "kill_after_slices": KILL_AFTER_SLICES,
        "recovery_seconds": recovery_seconds,
        "replayed_iterations": int(total_replayed),
        "total_iterations": int(total_iters),
        "lost_work_ratio": total_replayed / max(total_iters, 1),
        "cold_restart_ratio": total_killed / max(total_iters, 1),
        "n_bit_identical": sum(a["bit_identical"] for a in apps.values()),
    }


# ----------------------------------------------------------------------
def _overload_chaos(smoke: bool) -> dict:
    program = REGISTRY["BFS"]()
    config = SystemConfig.from_name("DG1")
    g = rmat_graph(scale=GATEWAY_SCALE, edge_factor=8, seed=3,
                   weighted=False)
    sched = ContinuousScheduler(max_batch=2, slice_len=2)

    # warm-up wave: teach the gateway its service time
    warm = [sched.submit(program, g, config) for _ in range(4)]
    sched.run_until_idle()
    for t in warm:
        t.result(0)
    mean_latency = float(np.mean(sched.stats.latencies_s))

    # 2x-capacity burst with deadlines one wave of service can meet but
    # a growing queue cannot: the projection must shed the hopeless tail
    offered = 4 * sched.max_batch
    deadline = 1.5 * mean_latency
    admitted, shed = [], 0
    for _ in range(offered):
        try:
            admitted.append(sched.submit(program, g, config,
                                         deadline_s=deadline))
        except OverloadError:
            shed += 1
    sched.run_until_idle()
    finished = [t for t in admitted if t.done()]
    completed = sum(1 for t in finished
                    if t.result(0) is not None)
    return {
        "offered": offered, "admitted": len(admitted), "shed": shed,
        "shed_rate": shed / max(offered, 1),
        "deadline_s": deadline, "mean_warm_latency_s": mean_latency,
        "completed": completed,
        "contained": bool(shed > 0 and completed == len(admitted)),
    }


# ----------------------------------------------------------------------
def run_chaos_bench(out_path: str = "results/BENCH_chaos.json",
                    smoke: bool = False) -> dict:
    core = _core_chaos(smoke)
    gateway = _gateway_chaos(smoke)
    overload = _overload_chaos(smoke)
    result = {
        "smoke": bool(smoke),
        "workload": {"core_app": CORE_APP, "core_k": CORE_K,
                     "gateway_apps": list(GATEWAY_APPS),
                     "gateway_pool": GATEWAY_POOL,
                     "gateway_requests": GATEWAY_REQUESTS},
        "core": core,
        "gateway": gateway,
        "overload": overload,
        "summary": {
            "core_lost_work_ratio": core["lost_work_ratio"],
            "gateway_lost_work_ratio": gateway["lost_work_ratio"],
            "recovery_seconds": (core["recovery_seconds"]
                                 + gateway["recovery_seconds"]),
            "n_bit_identical": (int(core["bit_identical"])
                                + gateway["n_bit_identical"]),
            "n_identity_checks": 1 + len(gateway["apps"]),
            "shed_rate": overload["shed_rate"],
            "overload_contained": overload["contained"],
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    s = result["summary"]
    print(f"chaos_bench,"
          f"bit_identical={s['n_bit_identical']}/{s['n_identity_checks']};"
          f"core_lost_work={s['core_lost_work_ratio']:.3f};"
          f"gateway_lost_work={s['gateway_lost_work_ratio']:.3f};"
          f"shed_rate={s['shed_rate']:.2f};"
          f"recovery={s['recovery_seconds']:.2f}s", flush=True)
    return result


if __name__ == "__main__":
    run_chaos_bench(smoke="--smoke" in sys.argv[1:])
