"""Batched-serving benchmark: batched vs sequential us/graph, tracked as
``results/BENCH_batch.json`` from this PR on.

The serving scenario the batched executor exists for: many small
R-MAT graphs (distinct seeds, one padding bucket) answered under every
addressable config.  For each batch size B in ``SIZES`` and each of the
18 configs, the file records

- ``seq_us_per_graph`` — per-graph sequential cost: best-of-``repeats``
  fused ``run()`` seconds per distinct graph, averaged.  Graphs beyond
  ``--seq-sample`` reuse the sample mean (measuring 64 distinct
  compiled runners adds minutes of compile time for no information —
  the per-graph cost is i.i.d. across seeds); each entry records
  whether its sequential basis was ``measured`` or ``extrapolated``.
- ``batch_us_per_graph`` — best-of-``repeats`` ``run_batch()`` wall
  seconds over the whole batch, divided by B (one fused dispatch for
  the batch; warmup compilation excluded on both sides).
- their ratio ``speedup`` — the dispatch amortization the batched
  executor buys.

``--smoke`` is the CI job: B=4 over tiny graphs, exercising pack →
batch-context → fused batch dispatch → unbatch in seconds.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

from repro.algorithms import REGISTRY
from repro.core import ALL_CONFIGS, SystemConfig, run, run_batch
from repro.graph import rmat_batch

__all__ = ["run_batch_bench", "PINNED_WORKLOAD", "SMOKE_WORKLOAD",
           "SIZES", "SMOKE_SIZES"]

#: The pinned workload — change it and the trajectory restarts.
PINNED_WORKLOAD = dict(scale=6, edge_factor=8, seed=7)
SMOKE_WORKLOAD = dict(scale=5, edge_factor=8, seed=7)
APP = "BFS"
SIZES = (1, 4, 16, 64)
SMOKE_SIZES = (1, 4)
REPEATS = 5
#: How many distinct graphs get their own sequential measurement;
#: beyond this the sequential basis is the sample mean (extrapolated).
SEQ_SAMPLE = 16


def _geomean(xs):
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 1.0


def run_batch_bench(out_path: str = "results/BENCH_batch.json",
                    smoke: bool = False, repeats: int | None = None,
                    sizes=None, seq_sample: int = SEQ_SAMPLE) -> dict:
    wl = dict(SMOKE_WORKLOAD if smoke else PINNED_WORKLOAD)
    sizes = tuple(sizes) if sizes else (SMOKE_SIZES if smoke else SIZES)
    repeats = repeats or (2 if smoke else REPEATS)
    program = REGISTRY[APP]()
    n_graphs = max(sizes)
    graphs = rmat_batch(n_graphs, weighted=program.weighted, **wl)
    n_meas = min(n_graphs, seq_sample)

    configs = {}
    for cfg in ALL_CONFIGS:
        config = SystemConfig.from_name(cfg.name)
        seq_best = []
        for g in graphs[:n_meas]:
            best = min(run(program, g, config).seconds
                       for _ in range(repeats))
            seq_best.append(best)
        mean_seq = sum(seq_best) / len(seq_best)

        per_b = {}
        for b in sizes:
            gs = graphs[:b]
            if b <= n_meas:
                seq_total, basis = sum(seq_best[:b]), "measured"
            else:
                seq_total = sum(seq_best) + mean_seq * (b - n_meas)
                basis = "extrapolated"
            best_bat = None
            iters = 0
            for _ in range(repeats):
                rs = run_batch(program, gs, config)
                tot = sum(r.seconds for r in rs)
                if best_bat is None or tot < best_bat:
                    best_bat = tot
                    iters = max(r.iterations for r in rs)
            seq_us = seq_total * 1e6 / b
            bat_us = best_bat * 1e6 / b
            per_b[str(b)] = {
                "seq_us_per_graph": seq_us,
                "batch_us_per_graph": bat_us,
                "speedup": seq_us / max(bat_us, 1e-12),
                "batch_iterations": iters,
                "sequential_basis": basis,
            }
        configs[cfg.name] = per_b

    geomean_by_b = {
        str(b): _geomean(c[str(b)]["speedup"] for c in configs.values())
        for b in sizes
    }
    headline_b = str(16 if 16 in sizes else max(sizes))
    result = {
        "workload": {"generator": "rmat_batch", **wl, "app": APP,
                     "n_nodes": graphs[0].n_nodes,
                     "n_edges": graphs[0].n_edges},
        "app": APP,
        "smoke": smoke,
        "repeats": repeats,
        "sizes": list(sizes),
        "seq_sample": n_meas,
        "configs": configs,
        "summary": {
            "n_configs": len(configs),
            "geomean_speedup_by_batch_size": geomean_by_b,
            "headline_batch_size": int(headline_b),
            "headline_geomean_speedup": geomean_by_b[headline_b],
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    per_b_str = ";".join(f"B{b}={v:.2f}x" for b, v in geomean_by_b.items())
    print(f"batch_bench,{len(configs) * len(sizes)},"
          f"headline_B{headline_b}="
          f"{result['summary']['headline_geomean_speedup']:.2f}x;"
          f"{per_b_str}", flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graphs, B<=4 (the CI job)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--sizes", default=None,
                    help="comma-separated batch sizes (default 1,4,16,64; "
                         "smoke 1,4)")
    ap.add_argument("--seq-sample", type=int, default=SEQ_SAMPLE)
    ap.add_argument("--out", default="results/BENCH_batch.json")
    args = ap.parse_args()
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else None)
    run_batch_bench(out_path=args.out, smoke=args.smoke,
                    repeats=args.repeats, sizes=sizes,
                    seq_sample=args.seq_sample)
