"""Serving-gateway load benchmark: continuous batching vs
serve-one-at-a-time, tracked as ``results/BENCH_serve.json``.

Two arrival modes over one pinned request stream (``--requests``
queries cycling through a pool of same-bucket R-MAT graphs, one app,
one config):

- **closed-loop** — ``--clients`` concurrent clients, each submitting
  its next request the moment the previous one completes: the
  saturation throughput test.  The gateway serves the stream through
  :class:`repro.launch.serve.GraphGateway`; the serve-one-at-a-time
  baseline replays the *same* stream against a single serial ``run()``
  server (really measured per-graph service times, deterministic FIFO
  queue simulation for the closed-loop waiting).
- **open-loop** — Poisson arrivals (seeded, rate ``--lambda-x`` times
  the solo server's measured capacity): the latency-under-load test.
  Gateway arrivals are real timed submissions; the solo baseline runs
  the same arrival schedule through the serial-queue model.

Per mode the artifact records gateway and solo ``{throughput_rps,
p50_ms, p99_ms}`` plus the two hardware-portable ratios the CI gate
diffs: ``throughput_speedup`` (gateway/solo completed-requests rate)
and ``p99_gain`` (solo p99 / gateway p99; >= 1 means the gateway's
throughput does not come at a tail-latency cost).  Both sides are
compile-warm before timing — the gateway pre-grows its roster with one
warmup wave, the solo server warms each distinct graph's runner.

``--smoke`` is the CI job: a 4-graph scale-5 pool, 64 requests,
finishing in seconds.  Each mode's measured window is best-of-
``--repeats`` (max throughput) so the gated ratios are stable.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # `benchmarks` package
sys.path.insert(0, str(_ROOT / "src"))  # `repro` package

import numpy as np

from repro.algorithms import REGISTRY
from repro.core import SystemConfig, run
from repro.graph import rmat_batch
from repro.launch.serve import GraphGateway

__all__ = ["run_serve_bench", "PINNED_WORKLOAD", "SMOKE_WORKLOAD"]

#: The pinned stream — change it and the trajectory restarts.
PINNED_WORKLOAD = dict(scale=6, edge_factor=8, seed=7, pool=8,
                       requests=96, clients=16)
SMOKE_WORKLOAD = dict(scale=5, edge_factor=8, seed=7, pool=4,
                      requests=64, clients=8)
APP = "BFS"
CONFIG = "DG1"
MAX_BATCH = 8
SLICE_LEN = 8
#: open-loop arrival rate as a multiple of solo capacity (> 1: the
#: serial server falls behind, the gateway should not)
LAMBDA_X = 1.2


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def _measure_solo(program, pool, config, repeats: int):
    """Warm per-graph serve-one-at-a-time service seconds.

    Times the **full request path** a serial server pays per query —
    state init, context/plan lookups, the fused dispatch, unbatching
    and trace decode (``RunResult.seconds`` alone times only the
    dispatch) — best-of-``repeats`` after a compile warmup.
    """
    service = []
    for g in pool:
        run(program, g, config)  # compile warmup
        best = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            run(program, g, config)
            dt = time.perf_counter() - t0
            best = dt if best is None or dt < best else best
        service.append(best)
    return service


def _solo_closed(service_by_req, clients: int):
    """Closed-loop FIFO replay against one serial server: client k
    resubmits the instant its previous request completes."""
    n = len(service_by_req)
    next_submit = [0.0] * clients
    server_free = 0.0
    latencies = []
    for i in range(n):
        arr = next_submit[i % clients]
        done = max(server_free, arr) + service_by_req[i]
        server_free = done
        latencies.append(done - arr)
        next_submit[i % clients] = done
    return latencies, n / server_free


def _solo_open(service_by_req, arrivals):
    """Open-loop FIFO replay: fixed arrival schedule, serial server."""
    server_free = 0.0
    latencies = []
    for arr, s in zip(arrivals, service_by_req):
        done = max(server_free, arr) + s
        server_free = done
        latencies.append(done - arr)
    return latencies, len(arrivals) / server_free


def _warmup(gw, program, pool, config, max_batch):
    """Grow the roster to steady state (+ compile) then reset stats so
    the measured window starts cache- and compile-warm."""
    warm = [gw.submit(program, pool[i % len(pool)], config)
            for i in range(max(max_batch, len(pool)))]
    for t in warm:
        t.result(timeout=600)
    gw.reset_stats()


def _gateway_closed(program, pool, config, n_requests, clients,
                    max_batch, slice_len):
    """Really serve the closed-loop stream through the gateway."""
    with GraphGateway(max_batch=max_batch, slice_len=slice_len) as gw:
        _warmup(gw, program, pool, config, max_batch)
        latencies = [None] * n_requests
        def client(k):
            for i in range(k, n_requests, clients):
                t = gw.submit(program, pool[i % len(pool)], config)
                latencies[i] = t.result(timeout=600).seconds
        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(clients)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wall = time.perf_counter() - t0
        snap = gw.stats()
    return latencies, n_requests / wall, snap


def _gateway_open(program, pool, config, n_requests, interarrivals,
                  max_batch, slice_len):
    """Timed Poisson submissions against the running gateway."""
    with GraphGateway(max_batch=max_batch, slice_len=slice_len,
                      max_queue=4 * n_requests) as gw:
        _warmup(gw, program, pool, config, max_batch)
        tickets = []
        t0 = time.perf_counter()
        due = 0.0
        for i in range(n_requests):
            due += interarrivals[i]
            lag = due - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            tickets.append(gw.submit(program, pool[i % len(pool)], config))
        results = [t.result(timeout=600) for t in tickets]
        wall = time.perf_counter() - t0
        snap = gw.stats()
    return [r.seconds for r in results], n_requests / wall, snap


def _mode_entry(gw_lat, gw_rps, snap, solo_lat, solo_rps, gw_p99=None):
    gw_p99 = _pct(gw_lat, 99) if gw_p99 is None else gw_p99
    solo_p99 = _pct(solo_lat, 99)
    return {
        "gateway": {
            "throughput_rps": gw_rps,
            "p50_ms": _pct(gw_lat, 50) * 1e3,
            "p99_ms": gw_p99 * 1e3,
            "mean_occupancy": snap["mean_occupancy"],
            "slices": snap["slices"],
            "roster_rebuilds": snap["roster_rebuilds"],
        },
        "solo": {
            "throughput_rps": solo_rps,
            "p50_ms": _pct(solo_lat, 50) * 1e3,
            "p99_ms": solo_p99 * 1e3,
        },
        "throughput_speedup": gw_rps / solo_rps,
        "p99_gain": solo_p99 / max(gw_p99, 1e-12),
    }


def run_serve_bench(out_path: str = "results/BENCH_serve.json",
                    smoke: bool = False, repeats: int | None = None) -> dict:
    wl = dict(SMOKE_WORKLOAD if smoke else PINNED_WORKLOAD)
    repeats = repeats or (3 if smoke else 5)
    program = REGISTRY[APP]()
    config = SystemConfig.from_name(CONFIG)
    pool = rmat_batch(wl["pool"], wl["scale"],
                      edge_factor=wl["edge_factor"], seed=wl["seed"],
                      weighted=program.weighted)
    n, clients = wl["requests"], wl["clients"]
    service = _measure_solo(program, pool, config, repeats)
    service_by_req = [service[i % len(pool)] for i in range(n)]

    def best_of(measure):
        # best-of-`repeats` measured windows, per metric: throughput
        # from the fastest window, p99 from the lowest-tail window —
        # the same best-of-N noise policy the timing benchmarks use,
        # so one scheduler hiccup in one window doesn't set the
        # artifact's tail number
        runs = [measure() for _ in range(repeats)]
        lat, rps, snap = max(runs, key=lambda r: r[1])
        return lat, rps, snap, min(_pct(r[0], 99) for r in runs)

    # closed loop -------------------------------------------------------
    solo_lat_c, solo_rps_c = _solo_closed(service_by_req, clients)
    gw_lat_c, gw_rps_c, snap_c, gw_p99_c = best_of(
        lambda: _gateway_closed(program, pool, config, n, clients,
                                MAX_BATCH, SLICE_LEN))
    closed = _mode_entry(gw_lat_c, gw_rps_c, snap_c, solo_lat_c,
                         solo_rps_c, gw_p99=gw_p99_c)

    # open loop (Poisson, seeded) --------------------------------------
    rng = np.random.default_rng(wl["seed"])
    lam = LAMBDA_X / (sum(service) / len(service))
    inter = rng.exponential(1.0 / lam, size=n)
    arrivals = np.cumsum(inter)
    solo_lat_o, solo_rps_o = _solo_open(service_by_req, arrivals)
    gw_lat_o, gw_rps_o, snap_o, gw_p99_o = best_of(
        lambda: _gateway_open(program, pool, config, n, list(inter),
                              MAX_BATCH, SLICE_LEN))
    opened = _mode_entry(gw_lat_o, gw_rps_o, snap_o, solo_lat_o,
                         solo_rps_o, gw_p99=gw_p99_o)

    result = {
        "workload": {"generator": "rmat_batch", "app": APP,
                     "config": CONFIG, **wl,
                     "n_nodes": pool[0].n_nodes,
                     "n_edges": pool[0].n_edges,
                     "max_batch": MAX_BATCH, "slice_len": SLICE_LEN,
                     "lambda_x": LAMBDA_X},
        "smoke": smoke,
        "repeats": repeats,
        "modes": {"closed": closed, "open": opened},
        "summary": {
            "headline_mode": "closed",
            "headline_throughput_speedup": closed["throughput_speedup"],
            "headline_p99_gain": closed["p99_gain"],
        },
    }
    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    print(f"serve_bench,{n},"
          f"closed={closed['throughput_speedup']:.2f}x"
          f"@p99_gain={closed['p99_gain']:.2f};"
          f"open={opened['throughput_speedup']:.2f}x"
          f"@p99_gain={opened['p99_gain']:.2f};"
          f"occupancy={closed['gateway']['mean_occupancy']:.2f}",
          flush=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pool, 64 requests (the CI job)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--out", default="results/BENCH_serve.json")
    args = ap.parse_args()
    run_serve_bench(out_path=args.out, smoke=args.smoke,
                    repeats=args.repeats)
