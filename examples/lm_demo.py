"""Train a small starcoder2-family LM for a few hundred steps, then serve
it: prefill + iterative decode with the KV cache — both entry points the
production dry-run lowers, on a CPU-sized config.

    PYTHONPATH=src python examples/lm_demo.py --steps 100 --d-model 256
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.synthetic import lm_batch
from repro.models.transformer import decode_step, init_lm, prefill, \
    train_forward
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train.trainer import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    base = get_arch("starcoder2-7b").reduced_cfg
    cfg = dataclasses.replace(
        base, n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 32, n_kv_heads=max(1, args.d_model // 64),
        d_head=32, d_ff=args.d_model * 4, vocab=2048, window=None)
    params = init_lm(jax.random.key(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=1e-3)

    def step(p, o, batch):
        loss, grads = jax.value_and_grad(
            lambda pp: train_forward(cfg, pp, batch))(p)
        p2, o2, gnorm = adamw_update(grads, o, p, opt_cfg)
        return p2, o2, {"loss": loss}

    def make_batch(s):
        return jax.tree.map(jnp.asarray, lm_batch(s, 8, args.seq, cfg.vocab))

    t0 = time.perf_counter()
    params, _, hist = train_loop(
        step, params, make_batch,
        TrainLoopConfig(total_steps=args.steps, log_every=20,
                        checkpoint_dir=None),
        log_fn=lambda r: print(f"step {r['step']:>4} loss {r['loss']:.4f}"))
    print(f"train: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"in {time.perf_counter()-t0:.1f}s")

    # --- serve: prefill a prompt, decode 16 tokens -----------------------
    prompt = jnp.asarray(lm_batch(999, 1, 32, cfg.vocab)["tokens"])
    logits, cache = jax.jit(lambda p, t: prefill(cfg, p, t))(params, prompt)
    smax = 64
    kc = jnp.zeros((cfg.n_layers, 1, cfg.n_kv_heads, smax, cfg.d_head),
                   jnp.bfloat16).at[:, :, :, :32].set(
        cache[0].astype(jnp.bfloat16))
    vc = jnp.zeros_like(kc).at[:, :, :, :32].set(
        cache[1].astype(jnp.bfloat16))
    decode = jax.jit(lambda p, t, c, n: decode_step(cfg, p, t, c, n))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    t0 = time.perf_counter()
    for i in range(16):
        lg, (kc, vc) = decode(params, tok, (kc, vc), jnp.int32(32 + i))
        tok = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    dt = (time.perf_counter() - t0) / 16
    print(f"serve: decoded {out} ({dt*1e3:.1f} ms/token)")
    assert np.isfinite(float(hist[-1]["loss"]))


if __name__ == "__main__":
    main()
