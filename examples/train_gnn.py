"""Train a PNA node classifier end to end with the full substrate:
sharded data pipeline, AdamW, async checkpointing, preemption guard,
straggler tracking — a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_gnn.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.synthetic import gnn_batch
from repro.graph import powerlaw_graph
from repro.models.gnn.pna import pna_loss
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train.trainer import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_gnn_ckpt")
    args = ap.parse_args()

    arch = get_arch("pna")
    cfg = arch.reduced_cfg
    graph = powerlaw_graph(512, 4000, alpha=1.0, seed=0, block_size=64)
    params = arch.init_params(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: pna_loss(cfg, p, batch))(params)
        p, o, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        return p, o, {"loss": loss, "grad_norm": gnorm}

    # fixed labels -> the model must actually fit something
    fixed = gnn_batch(0, graph, cfg.d_in, cfg.n_classes)

    def make_batch(s):
        b = dict(fixed)
        return jax.tree.map(jnp.asarray, b)

    loop_cfg = TrainLoopConfig(total_steps=args.steps, checkpoint_every=50,
                               log_every=20, checkpoint_dir=args.ckpt)
    params, opt, history = train_loop(
        step, params, make_batch, loop_cfg,
        log_fn=lambda r: print(f"step {r['step']:>4} "
                               f"loss {r['loss']:.4f} "
                               f"({r['seconds']*1e3:.0f} ms)"))
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} over {len(history)} steps "
          f"(checkpoints in {args.ckpt})")
    assert last < first


if __name__ == "__main__":
    main()
