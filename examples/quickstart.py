"""Quickstart: profile a graph, let the paper's specialization model pick
the system configuration, run PageRank under it, verify vs. the oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.algorithms import pagerank
from repro.algorithms.reference import pagerank_np
from repro.core import run, specialize
from repro.core.taxonomy import profile_graph
from repro.graph import powerlaw_graph

# 1. an input graph (synthetic power-law, ~8k vertices)
graph = powerlaw_graph(8192, 60000, alpha=1.2, max_degree=800,
                       locality=0.3, seed=0)

# 2. taxonomy: Volume (Eq.1), Reuse (Eq.6), Imbalance (Eq.7)
profile = profile_graph(graph)
print(f"profile: volume={profile.volume_kb:.1f}KB({profile.volume_class}) "
      f"reuse={profile.reuse:.3f}({profile.reuse_class}) "
      f"imbalance={profile.imbalance:.3f}({profile.imbalance_class})")

# 3. the decision tree (paper Fig. 4) picks update-prop/coherence/consistency
program = pagerank()
config = specialize(program.properties, profile)
print(f"specialized config: {config.name}  "
      f"({config.prop.name} / {config.coherence.name} / "
      f"{config.consistency.name})")

# 4. execute under that configuration
result = run(program, graph, config)
print(f"pagerank converged={result.converged} in {result.iterations} "
      f"iterations, {result.seconds*1e3:.1f} ms")

# 5. verify against the numpy oracle
err = np.abs(np.asarray(result.state["rank"]) - pagerank_np(graph)).max()
print(f"max |err| vs oracle: {err:.2e}")
assert err < 1e-4
