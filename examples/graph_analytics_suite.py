"""End-to-end driver: the full paper pipeline over the six recreated
inputs x six applications — profile, specialize, execute, validate — the
graph-analytics analogue of "train a model end to end".

    PYTHONPATH=src python examples/graph_analytics_suite.py [--scale 48]
"""
import argparse
import time

import jax
import numpy as np

from repro.algorithms import REGISTRY
from repro.algorithms.reference import (bfs_np, cc_np,
                                        is_maximal_independent_set,
                                        is_proper_coloring, pagerank_np,
                                        sssp_np)
from repro.core import run, specialize
from repro.core.taxonomy import profile_graph
from repro.graph.datasets import PAPER_GRAPHS, paper_graph


def validate(app, g, res):
    if app == "PR":
        return np.abs(np.asarray(res.state["rank"])
                      - pagerank_np(g)).max() < 1e-4
    if app == "SSSP":
        ref = sssp_np(g)
        got = np.asarray(res.state["dist"])
        m = np.isfinite(ref)
        return np.allclose(got[m], ref[m], atol=1e-3)
    if app == "CC":
        return np.array_equal(np.asarray(res.state["label"]), cc_np(g))
    if app == "MIS":
        return is_maximal_independent_set(
            g, np.asarray(res.state["status"]) == 1)
    if app == "CLR":
        return is_proper_coloring(g, np.asarray(res.state["color"]))
    if app == "BFS":
        return np.array_equal(np.asarray(res.state["depth"]), bfs_np(g))
    return True  # BC checked in tests (O(V*E) oracle too slow here)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=48)
    ap.add_argument("--graphs", nargs="*", default=list(PAPER_GRAPHS))
    args = ap.parse_args()

    total_t0 = time.perf_counter()
    n_ok = 0
    for gname in args.graphs:
        for app, factory in REGISTRY.items():
            program = factory()
            g = paper_graph(gname, scale=args.scale,
                            weighted=program.weighted)
            profile = profile_graph(g)
            config = specialize(program.properties, profile)
            res = run(program, g, config, key=jax.random.key(0))
            ok = validate(app, g, res)
            n_ok += ok
            dirs = f" dirs={res.direction_trace}" \
                if config.name.startswith("D") and res.direction_trace else ""
            print(f"{gname:>4}/{app:<4} -> {config.name}  "
                  f"iters={res.iterations:<4} {res.seconds*1e3:7.1f}ms  "
                  f"converged={res.converged} valid={ok}{dirs}")
    dt = time.perf_counter() - total_t0
    print(f"\nsuite done: {n_ok} validated, {dt:.1f}s total")


if __name__ == "__main__":
    main()
