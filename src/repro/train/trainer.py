"""Generic fault-tolerant training loop.

Wires together: arch registry step functions, AdamW, the sharded data
pipeline, async checkpointing, preemption handling, bounded step retry and
the straggler tracker.  Works on 1 CPU device (smoke/examples) and on the
production mesh unchanged — the step function is the same object the
dry-run compiles.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.data.pipeline import ShardedPipeline
from repro.optim.adamw import adamw_init
from repro.train.checkpoint import AsyncCheckpointer, restore_checkpoint
from repro.train.fault_tolerance import (PreemptionGuard, StragglerPolicy,
                                         run_step_with_retry)

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: Optional[str] = None
    resume: bool = True
    max_step_retries: int = 3


def train_loop(step_fn: Callable, params: Any, make_batch: Callable[[int], Any],
               cfg: TrainLoopConfig, opt_state: Any = None,
               log_fn: Callable[[dict], None] = None) -> tuple[Any, Any, list]:
    """step_fn(params, opt_state, batch) -> (params, opt_state, metrics)."""
    opt_state = opt_state if opt_state is not None else adamw_init(params)
    start_step = 0
    ckpt = AsyncCheckpointer(cfg.checkpoint_dir) if cfg.checkpoint_dir else None
    if ckpt and cfg.resume:
        try:
            (params, opt_state), start_step, _ = restore_checkpoint(
                cfg.checkpoint_dir, (params, opt_state))
            start_step += 1
        except FileNotFoundError:
            pass

    guard = PreemptionGuard()
    straggler = StragglerPolicy()
    pipeline = ShardedPipeline(make_batch, start_step=start_step)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    history = []
    try:
        for step, batch in pipeline:
            if step >= cfg.total_steps:
                break
            t0 = time.perf_counter()
            params, opt_state, metrics = run_step_with_retry(
                jit_step, params, opt_state, batch,
                max_retries=cfg.max_step_retries)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            verdict = straggler.observe(dt)
            row = {"step": step, "seconds": dt,
                   **{k: float(v) for k, v in metrics.items()},
                   "straggler": verdict["slow"]}
            history.append(row)
            if log_fn and step % cfg.log_every == 0:
                log_fn(row)
            if ckpt and (step + 1) % cfg.checkpoint_every == 0:
                ckpt.save(step, (params, opt_state))
            if guard.preempted:
                if ckpt:
                    ckpt.save(step, (params, opt_state))
                break
    finally:
        pipeline.close()
        if ckpt:
            ckpt.wait()
        guard.restore()
    return params, opt_state, history
