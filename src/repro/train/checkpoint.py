"""Sharded, atomic, resharding-on-restore checkpointing (no orbax dep).

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step, mesh
            shard_<i>.npz       flat leaf arrays (host-local shard or full)

Writes are crash-safe: a temp directory is populated, fsync'd, then
atomically renamed; a ``latest`` symlink flips last.  ``AsyncCheckpointer``
overlaps serialization with training (one in-flight save, back-pressure on
the next).  Restore accepts a different device count/mesh than the save
(elastic restarts): arrays are saved fully-replicated from host RAM and
re-sharded on load by ``jax.device_put`` with the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _flatten_with_names(tree):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any,
                    extra: Optional[dict] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, paths, _ = _flatten_with_names(tree)
    arrays = {}
    meta = []
    for i, (leaf, path) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"leaf_{i}"] = arr
        meta.append({"path": path, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
    np.savez(tmp / "shard_0.npz", **arrays)
    manifest = {
        "step": int(step),
        "n_leaves": len(leaves),
        "leaves": meta,
        "format": 1,
        "extra": extra or {},
    }
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=2))
    # fsync the manifest for crash safety, then atomic publish
    with open(tmp / _MANIFEST, "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest = directory / "latest"
    tmp_link = directory / ".latest_tmp"
    if tmp_link.exists() or tmp_link.is_symlink():
        tmp_link.unlink()
    tmp_link.symlink_to(final.name)
    os.replace(tmp_link, latest)
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    steps = sorted(int(p.name.split("_")[1])
                   for p in directory.glob("step_*") if p.is_dir())
    return steps[-1] if steps else None


def restore_checkpoint(directory: str | os.PathLike, tree_like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``tree_like``; ``shardings`` (optional
    pytree of NamedSharding, may target a different mesh than the save)
    re-shards on load — the elastic-restart path."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    with np.load(d / "shard_0.npz") as z:
        arrays = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    leaves, treedef = jax.tree.flatten(tree_like)
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, expected {len(leaves)}")
    if shardings is not None:
        shard_leaves = jax.tree.flatten(shardings)[0] \
            if not isinstance(shardings, jax.sharding.Sharding) \
            else [shardings] * len(arrays)
        arrays = [jax.device_put(a.astype(l.dtype), s)
                  for a, l, s in zip(arrays, leaves, shard_leaves)]
    else:
        arrays = [jax.numpy.asarray(a.astype(l.dtype))
                  for a, l in zip(arrays, leaves)]
    return treedef.unflatten(arrays), step, manifest.get("extra", {})


class AsyncCheckpointer:
    """One background writer thread; ``save`` returns immediately, the next
    save (or ``wait``) blocks until the previous one lands."""

    def __init__(self, directory: str | os.PathLike):
        self.directory = Path(directory)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
            except BaseException as exc:  # surfaced on next wait()
                self._error = exc

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
