"""Fault-tolerance machinery for 1000+-node posture.

- :class:`PreemptionGuard` — SIGTERM/SIGINT → "checkpoint now, exit clean".
- :func:`run_step_with_retry` — bounded retry around a train step for
  transient executor failures; re-raises on persistent ones.
- :class:`ElasticMesh` — rebuild a (data, model) mesh after losing hosts
  and recompute shardings; restore path reshards checkpoints (see
  checkpoint.restore_checkpoint).
- :class:`StragglerPolicy` — step-time tracker: flags outlier steps and
  recommends data re-dispatch (deterministic batch reassignment) when a
  host is persistently slow.  On-device timing comes from the caller.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Any, Callable, Optional

import jax

__all__ = ["PreemptionGuard", "run_step_with_retry", "ElasticMesh",
           "StragglerPolicy"]


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a flag the train loop polls each step."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._previous = {}
        for s in signals:
            try:
                self._previous[s] = signal.signal(s, self._handler)
            except ValueError:  # not main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def restore(self):
        for s, h in self._previous.items():
            signal.signal(s, h)


def run_step_with_retry(step_fn: Callable[..., Any], *args,
                        max_retries: int = 3, backoff_s: float = 0.5,
                        on_retry: Optional[Callable[[int, Exception], None]]
                        = None, **kwargs):
    """Retry transient step failures (link flap, DMA timeout class).

    jax surfaces these as XlaRuntimeError; deterministic program errors
    (shape/type) also raise XlaRuntimeError at dispatch, so retries are
    bounded and the last error always re-raises.
    """
    attempt = 0
    while True:
        try:
            return step_fn(*args, **kwargs)
        except jax.errors.JaxRuntimeError as exc:
            attempt += 1
            if attempt > max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            time.sleep(backoff_s * (2 ** (attempt - 1)))


class ElasticMesh:
    """Rebuilds the largest usable (data, model) mesh from live devices.

    Keeps the model axis fixed (TP degree is baked into weight shapes) and
    shrinks the data axis to the largest multiple that fits — the elastic
    scaling contract: lose a pod, halve DP, reshard, continue.
    """

    def __init__(self, model_parallel: int):
        self.model_parallel = model_parallel

    def build(self, devices=None):
        devices = devices if devices is not None else jax.devices()
        n = len(devices)
        mp = self.model_parallel
        dp = max(1, n // mp)
        usable = dp * mp
        import numpy as np
        from jax.sharding import Mesh
        arr = np.asarray(devices[:usable]).reshape(dp, mp)
        return Mesh(arr, ("data", "model"))

    def reshard(self, tree, mesh, spec_tree):
        from jax.sharding import NamedSharding
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        if spec_tree is None:
            sharding = NamedSharding(mesh, P())
            return jax.tree.map(lambda x: jax.device_put(x, sharding), tree)
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                                 is_leaf=lambda x: isinstance(
                                     x, jax.sharding.PartitionSpec))
        return jax.tree.map(jax.device_put, tree, shardings)


class StragglerPolicy:
    """Flags steps slower than ``threshold`` x rolling median; after
    ``patience`` consecutive flags, recommends re-dispatch."""

    def __init__(self, window: int = 32, threshold: float = 2.0,
                 patience: int = 3):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self._times: list[float] = []
        self._consecutive = 0

    def observe(self, step_seconds: float) -> dict:
        self._times.append(step_seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = statistics.median(self._times)
        slow = len(self._times) >= 8 and step_seconds > self.threshold * med
        self._consecutive = self._consecutive + 1 if slow else 0
        return {
            "median_s": med,
            "slow": slow,
            "redispatch": self._consecutive >= self.patience,
        }
