"""Token-choice top-k MoE (qwen3-moe-235b-a22b, grok-1-314b).

Sort-based dispatch (MegaBlocks-style, XLA-native): tokens are argsorted by
assigned expert, ranked within their expert via a vectorised searchsorted
(no [T,E] one-hot cumsum), scattered into per-expert capacity buffers,
transformed by a grouped GEMM (einsum over the expert axis -> shardable
over the EP/'model' mesh axis), and combined back with gate weights.

The expert dispatch/combine is a push-style scatter over a ragged
token->expert graph — it reuses the paper's machinery in spirit: dispatch
is "push with atomics analogue" (scatter into owned expert buffers),
combine is the reverse gather (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import LMConfig

__all__ = ["MoEConfig", "init_moe_layer", "moe_apply", "init_moe_lm",
           "moe_train_forward", "moe_decode_step", "abstract_moe_params"]


@dataclasses.dataclass(frozen=True)
class MoEConfig(LMConfig):
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_mode: str = "ep"   # 'ep': experts sharded over tp; 'tp': d_ff over tp
    #: dispatch groups (== data-parallel degree): routing/sort/scatter all
    #: happen within a group so token tensors never cross dp shards except
    #: through the single EP all-to-all of the capacity buffers (§Perf B1)
    dispatch_groups: int = 1

    @property
    def n_params(self) -> int:
        d, f, v, h = self.d_model, self.d_ff, self.vocab, self.d_head
        attn = d * h * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * h * d
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        moe = self.n_experts * glu * d * f + d * self.n_experts
        return self.n_layers * (attn + moe) + v * d

    @property
    def n_active_params(self) -> int:
        d, f, v, h = self.d_model, self.d_ff, self.vocab, self.d_head
        attn = d * h * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * h * d
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        act = self.top_k * glu * d * f + d * self.n_experts
        return self.n_layers * (attn + act) + v * d


def init_moe_layer(key, cfg: MoEConfig):
    ks = jax.random.split(key, 4)
    dt = cfg.dtype
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32)
                   * scale).astype(jnp.float32),  # router stays fp32
        "up": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
               * scale).astype(dt),
        "down": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
                 * f ** -0.5).astype(dt),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32)
                     * scale).astype(dt)
    return p


def moe_apply(p, x: jnp.ndarray, cfg: MoEConfig):
    """x [T, d] -> ([T, d], aux_loss).

    Grouped sort-based dispatch: tokens are split into ``dispatch_groups``
    (aligned with the data-parallel shards), routed and capacity-packed
    *within* each group, and exchanged with the expert shards through ONE
    [G, E, cap, d] buffer — the EP all-to-all.  An ungrouped dispatch
    (G=1) makes XLA gather the whole global batch to sort it (measured:
    696 GB/device on qwen3 train_4k, §Perf B0); grouped dispatch keeps
    every token-indexed tensor dp-local by construction.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = cfg.dispatch_groups if t % max(cfg.dispatch_groups, 1) == 0 else 1
    tl = t // g                                     # tokens per group
    tk = tl * k
    xg = x.reshape(g, tl, d)
    if cfg.tp_axis is not None and g > 1:
        from jax.sharding import PartitionSpec as P
        xg = jax.lax.with_sharding_constraint(
            xg, P(tuple(cfg.dp_axes) or None, None, None))

    gates = jax.nn.softmax(
        jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"]),
        axis=-1)                                    # [G, Tl, E]
    gate_vals, expert_idx = jax.lax.top_k(gates, k)
    gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)

    # flatten and sort assignments by expert, per group
    e_flat = expert_idx.reshape(g, tk)
    t_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tl), k)[None], (g, tk))
    g_flat = gate_vals.reshape(g, tk)
    order = jnp.argsort(e_flat, axis=-1)
    take = lambda a: jnp.take_along_axis(a, order, axis=-1)
    e_sorted, t_sorted, g_sorted = take(e_flat), take(t_flat), take(g_flat)
    # rank within expert: position - first-position-of-this-expert
    first = jax.vmap(
        lambda es: jnp.searchsorted(es, es, side="left"))(e_sorted)
    rank = jnp.arange(tk)[None] - first
    cap = int(max(8, -(-tk // e) * cfg.capacity_factor)) \
        if tk >= e else max(8, tk)
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, e * cap)  # overflow row

    # dispatch: per-group scatter into [G, E*cap(+1), d] capacity buffers.
    # GSPMD refuses to partition even batched scatters on the group dim
    # (B1, B2 measured); shard_map over the dp axes makes group-locality
    # STRUCTURAL: each dp shard scatters only its own groups (§Perf B3).
    rows = e * cap + 1

    def _dispatch(xg_, t_sorted_, keep_, slot_):
        gl = xg_.shape[0]
        gathered = jnp.take_along_axis(xg_, t_sorted_[..., None], axis=1) \
            * keep_[..., None].astype(x.dtype)               # [gl, Tk, d]
        b = jnp.zeros((gl, rows, d), x.dtype) \
            .at[jnp.arange(gl)[:, None], slot_].set(gathered)
        return b[:, :e * cap].reshape(gl, e, cap, d)

    def _combine(out_ext_, slot_, t_sorted_, w_):
        gl = out_ext_.shape[0]
        picked = jnp.take_along_axis(out_ext_, slot_[..., None], axis=1) \
            * w_[..., None].astype(x.dtype)                  # [gl, Tk, d]
        return jnp.zeros((gl, tl, d), x.dtype) \
            .at[jnp.arange(gl)[:, None], t_sorted_].add(picked)

    shard_ctx = None
    if cfg.tp_axis is not None and g > 1:
        from repro.models.mesh_compat import active_abstract_mesh
        shard_ctx = active_abstract_mesh()
    from jax.sharding import PartitionSpec as P
    dp = tuple(cfg.dp_axes) or None
    if shard_ctx is not None:
        buf = jax.shard_map(
            _dispatch, mesh=shard_ctx,
            in_specs=(P(dp, None, None), P(dp, None), P(dp, None),
                      P(dp, None)),
            out_specs=P(dp, None, None, None))(xg, t_sorted, keep, slot)
    else:
        buf = _dispatch(xg, t_sorted, keep, slot)
    if cfg.tp_axis is not None:
        if cfg.moe_mode == "ep":
            # EP all-to-all: group dim dp-sharded, expert dim tp-sharded
            buf = jax.lax.with_sharding_constraint(
                buf, P(dp, cfg.tp_axis, None, None))
        else:
            buf = jax.lax.with_sharding_constraint(
                buf, P(dp, None, None, None))

    # grouped GEMM over experts (EP/TP-shardable einsum)
    up = jnp.einsum("gecd,edf->gecf", buf, p["up"])
    if "gate" in p:
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["gate"])) * up
    else:
        h = jax.nn.gelu(up)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["down"])

    # combine: gather back + weighted per-token scatter-add (same
    # locality contract as dispatch)
    out_ext = jnp.concatenate(
        [out_buf.reshape(g, e * cap, d),
         jnp.zeros((g, 1, d), x.dtype)], axis=1)             # [G, rows, d]
    w = g_sorted * keep
    if shard_ctx is not None:
        out_ext = jax.lax.with_sharding_constraint(
            out_ext, P(dp, None, None))  # reverse all-to-all happens here
        y = jax.shard_map(
            _combine, mesh=shard_ctx,
            in_specs=(P(dp, None, None), P(dp, None), P(dp, None),
                      P(dp, None)),
            out_specs=P(dp, None, None))(out_ext, slot, t_sorted, w)
    else:
        y = _combine(out_ext, slot, t_sorted, w)

    # load-balance aux loss (Switch-style), averaged over groups
    me = gates.mean(axis=(0, 1))                              # [E]
    ce = jnp.zeros((e,), jnp.float32).at[e_flat.reshape(-1)].add(1.0) \
        / (t * k)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)
    return y.reshape(t, d), aux


# ---------------------------------------------------------------------------
# full MoE LM: reuse the dense transformer skeleton, swap the FFN
# ---------------------------------------------------------------------------
from repro.models import transformer as T  # noqa: E402


def _init_moe_block(key, cfg: MoEConfig):
    kb, km = jax.random.split(key)
    p = T._init_block(kb, cfg)
    del p["mlp"]
    p["moe"] = init_moe_layer(km, cfg)
    return p


def init_moe_lm(key, cfg: MoEConfig):
    k_embed, k_blocks = jax.random.split(key)
    blocks = jax.vmap(lambda k: _init_moe_block(k, cfg))(
        jax.random.split(k_blocks, cfg.n_layers))
    return {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(cfg.dtype),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg.d_model, cfg.dtype),
    }


def abstract_moe_params(cfg: MoEConfig):
    return jax.eval_shape(lambda: init_moe_lm(jax.random.key(0), cfg))


def _moe_block(cfg: MoEConfig, p, x, positions, kv=None, kv_len=None):
    h = T._norm(cfg, p["ln1"], x)
    a, kv_out = T._attention(cfg, p["attn"], h, positions, kv=kv,
                             kv_len=kv_len)
    mid = x + a
    h2 = T._norm(cfg, p["ln2"], mid)
    b, s, d = h2.shape
    y, aux = moe_apply(p["moe"], h2.reshape(b * s, d), cfg)
    return mid + y.reshape(b, s, d), aux, kv_out


def moe_train_forward(cfg: MoEConfig, params, batch):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)

    def block(p, x):
        y, aux, _ = _moe_block(cfg, p, x, positions)
        return y, aux

    blk = jax.checkpoint(block,
                         policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else block

    def body(carry, layer_p):
        x, aux_sum = carry
        x = T._constrain_act(cfg, x)
        y, aux = blk(layer_p, x)
        return (y, aux_sum + aux), None

    (x, aux_total), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                     params["blocks"])
    loss = T.chunked_ce(cfg, params, x, labels)
    return loss + aux_total / cfg.n_layers


def moe_prefill(cfg: MoEConfig, params, tokens):
    """Causal forward through the MoE stack; returns (last-token logits
    [B,V], cache (k,v) [L,B,Hkv,S,dh])."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(carry, layer_p):
        y, _, (k, v) = _moe_block(cfg, layer_p, carry, positions)
        return y, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    return T._logits(cfg, params, x[:, -1:, :])[:, 0], (ks, vs)


def moe_decode_step(cfg: MoEConfig, params, token, cache, kv_len):
    b = token.shape[0]
    positions = jnp.broadcast_to(kv_len, (b, 1)).astype(jnp.int32)
    x = jnp.take(params["embed"], token, axis=0)

    def body(carry, xs):
        layer_p, kc, vc = xs
        y, _, (kc, vc) = _moe_block(cfg, layer_p, carry, positions,
                                    kv=(kc, vc), kv_len=kv_len)
        return y, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], *cache))
    return T._logits(cfg, params, x), (ks, vs)
