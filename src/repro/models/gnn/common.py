"""GNN substrate: message aggregation routed through the paper's design
space + shared MLP helpers.

``aggregate`` is the single scatter primitive every GNN model uses; the
bound :class:`SystemConfig` picks:
- coherence: LLC-analogue direct scatter vs owned-analogue sort-by-target-
  block + reduce (paying "ownership registration" for block locality —
  in-graph ``argsort`` since GNN edge sets are runtime inputs),
- consistency: DRF0 monolithic / DRF1 ordered chunks / DRFrlx independent
  partial reductions (see core.consistency).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.coherence import segment_reduce
from repro.core.config_space import (Coherence, Consistency, SystemConfig,
                                     UpdateProp)
from repro.core.consistency import scheduled_reduce
from repro.core.vertex_program import MAX, MIN, SUM, Monoid
from repro.models import layers as L

__all__ = ["aggregate", "segment_softmax", "init_mlp_stack", "mlp_stack",
           "DEFAULT_GNN_CONFIG"]

#: push + GPU-coherence + DRFrlx — the paper's majority-optimal config is
#: the default; models accept any SystemConfig.
DEFAULT_GNN_CONFIG = SystemConfig(UpdateProp.PUSH, Coherence.GPU,
                                  Consistency.DRFRLX)

_MONOIDS = {"sum": SUM, "min": MIN, "max": MAX}


def constrain_flat(x):
    """Shard dim0 (nodes/edges) over every mesh axis when a mesh context
    is active (dry-run / production); no-op on a single device.  Without
    this, GSPMD replicates the [N, ...] node state per device —
    catastrophic at ogb_products scale (§Perf C1)."""
    from repro.models.mesh_compat import active_abstract_mesh
    am = active_abstract_mesh()
    if am is None or not am.axis_names:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(am.axis_names), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def aggregate(values: jnp.ndarray, dst: jnp.ndarray, n_nodes: int,
              kind: str = "sum",
              config: SystemConfig = DEFAULT_GNN_CONFIG,
              block_size: int = 1024) -> jnp.ndarray:
    """values [E, ...], dst [E] -> [n_nodes, ...] reduced by ``kind``."""
    monoid = _MONOIDS[kind]
    if config.coherence is Coherence.DENOVO:
        order = jnp.argsort(dst // block_size)   # ownership registration
        values = jnp.take(values, order, axis=0)
        dst = jnp.take(dst, order, axis=0)
    e = dst.shape[0]
    n_chunks = 1 if config.consistency is Consistency.DRF0 \
        else min(config.n_chunks, max(1, e // 1024))
    ec = -(-e // n_chunks)
    pad = n_chunks * ec - e
    if pad:
        values = jnp.concatenate(
            [values, jnp.zeros((pad,) + values.shape[1:], values.dtype)])
        dst = jnp.concatenate([dst, jnp.full((pad,), n_nodes, dst.dtype)])
    values = values.reshape((n_chunks, ec) + values.shape[1:])
    dst = dst.reshape(n_chunks, ec)
    ident = monoid.identity(values.dtype)

    def chunk_reduce(i):
        v = jax.lax.dynamic_index_in_dim(values, i, keepdims=False)
        d = jax.lax.dynamic_index_in_dim(dst, i, keepdims=False)
        if kind != "sum":  # padding must contribute the identity
            v = jnp.where((d < n_nodes)[(...,) + (None,) * (v.ndim - 1)],
                          v, ident)
        return segment_reduce(v, d, n_nodes + 1, monoid)

    out = scheduled_reduce(chunk_reduce, n_chunks, config.consistency,
                           monoid)
    return constrain_flat(out[:n_nodes])


def segment_softmax(logits: jnp.ndarray, dst: jnp.ndarray, n_nodes: int,
                    config: SystemConfig = DEFAULT_GNN_CONFIG) -> jnp.ndarray:
    """Edge softmax normalised over incoming edges of each target."""
    mx = aggregate(logits, dst, n_nodes, "max", config)
    ex = jnp.exp(logits - jnp.take(mx, dst, axis=0))
    den = aggregate(ex, dst, n_nodes, "sum", config)
    return ex / jnp.maximum(jnp.take(den, dst, axis=0), 1e-30)


# ---------------------------------------------------------------------------
# MLP stacks (MeshGraphNet/SchNet/PNA style)
# ---------------------------------------------------------------------------
def init_mlp_stack(key, dims: tuple[int, ...], dtype=jnp.float32,
                   layer_norm: bool = False):
    ks = jax.random.split(key, len(dims) - 1)
    p = {"layers": [L.init_dense(k, dims[i], dims[i + 1], use_bias=True,
                                 dtype=dtype)
                    for i, k in enumerate(ks)]}
    if layer_norm:
        p["ln"] = L.init_norm(dims[-1], dtype)
    return p


def mlp_stack(p, x, act=jax.nn.relu, final_act: bool = False):
    n = len(p["layers"])
    for i, lp in enumerate(p["layers"]):
        x = L.dense(lp, x)
        if i < n - 1 or final_act:
            x = act(x.astype(jnp.float32)).astype(x.dtype)
    if "ln" in p:
        x = L.layer_norm(p["ln"], x)
    return x
