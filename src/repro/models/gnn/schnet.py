"""SchNet [arXiv:1706.08566]: continuous-filter convolutions for molecules.

3 interaction blocks, hidden 64, 300 Gaussian RBFs, 10 A cutoff.  The
triplet-free cfconv regime: per-edge distance -> RBF -> filter MLP ->
elementwise with gathered source features -> scatter-sum (the paper's push
path).  Per-graph energy readout via a second segment reduction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.config_space import SystemConfig
from repro.models import layers as L
from repro.models.gnn.common import (DEFAULT_GNN_CONFIG, aggregate,
                                     init_mlp_stack, mlp_stack)

__all__ = ["SchNetConfig", "init_schnet", "schnet_forward", "schnet_loss"]


def shifted_softplus(x):
    return jax.nn.softplus(x.astype(jnp.float32)) - jnp.log(2.0)


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    n_graphs: int = 128   # graphs per batch (static for the jitted readout)
    sys: SystemConfig = DEFAULT_GNN_CONFIG


def init_schnet(key, cfg: SchNetConfig):
    ks = jax.random.split(key, 4)
    h = cfg.d_hidden

    def block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "filter": init_mlp_stack(k1, (cfg.n_rbf, h, h)),
            "in": L.init_dense(k2, h, h, use_bias=False, dtype=jnp.float32),
            "out1": L.init_dense(k3, h, h, use_bias=True, dtype=jnp.float32),
            "out2": L.init_dense(k4, h, h, use_bias=True, dtype=jnp.float32),
        }

    return {
        "embed": (jax.random.normal(ks[0], (cfg.n_species, h)) * 0.3)
        .astype(jnp.float32),
        "blocks": jax.vmap(block)(
            jax.random.split(ks[1], cfg.n_interactions)),
        "readout": init_mlp_stack(ks[2], (h, h // 2, 1)),
    }


def _rbf(cfg: SchNetConfig, dist):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 10.0
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def schnet_forward(cfg: SchNetConfig, params, inputs):
    """inputs: species [N] int32, positions [N,3], src/dst [E],
    graph_ids [N] int32 (cfg.n_graphs graphs per batch)."""
    n = inputs["species"].shape[0]
    src, dst = inputs["src"], inputs["dst"]
    x = jnp.take(params["embed"], inputs["species"], axis=0)
    d = jnp.linalg.norm(
        jnp.take(inputs["positions"], src, axis=0)
        - jnp.take(inputs["positions"], dst, axis=0) + 1e-12, axis=-1)
    rbf = _rbf(cfg, d)
    # cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cfg.cutoff, 0, 1)) + 1.0)

    def body(x, bp):
        w = mlp_stack(bp["filter"], rbf, act=shifted_softplus,
                      final_act=True) * env[:, None]
        msg = jnp.take(L.dense(bp["in"], x), src, axis=0) * w
        agg = aggregate(msg, dst, n, "sum", cfg.sys)
        v = shifted_softplus(L.dense(bp["out1"], agg))
        return x + L.dense(bp["out2"], v), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    atom_e = mlp_stack(params["readout"], x, act=shifted_softplus)  # [N,1]
    energy = aggregate(atom_e[:, 0], inputs["graph_ids"],
                       cfg.n_graphs, "sum", cfg.sys)
    return energy


def schnet_loss(cfg: SchNetConfig, params, batch):
    pred = schnet_forward(cfg, params, batch)
    return jnp.mean((pred - batch["energy"]) ** 2)
