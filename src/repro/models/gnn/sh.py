"""Real spherical harmonics + Wigner rotation blocks for eSCN-style models.

``real_sph_harm`` evaluates real SH up to ``l_max`` via the associated-
Legendre recurrence (fully vectorised jnp; differentiable).

``wigner_blocks`` builds the per-degree rotation matrices D_l(R) with the
sample-projection identity  Y_l(R r) = D_l Y_l(r):  for a fixed, well-
conditioned set of sample directions S (host-side constant),
D_l = Y_l(R S) @ pinv(Y_l(S)).  This avoids the Ivanic-Ruedenberg
recursion entirely while staying exact (the system is overdetermined:
|S| >> 2l+1) and jit/vmap-friendly.  pinv(Y_l(S)) is precomputed in numpy.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["real_sph_harm", "align_z_rotation", "wigner_blocks",
           "n_coeffs", "kept_rows"]


def n_coeffs(l_max: int) -> int:
    return (l_max + 1) ** 2


def kept_rows(l_max: int, m_max: int) -> np.ndarray:
    """Indices of coefficients with |m| <= m_max (the eSCN O(L^3) cut)."""
    rows = []
    off = 0
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            if abs(m) <= m_max:
                rows.append(off + m + l)
        off += 2 * l + 1
    return np.asarray(rows, np.int32)


def real_sph_harm(dirs: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """dirs [..., 3] (unit vectors) -> [..., (l_max+1)^2] real SH values,
    ordered l-major, m from -l..l."""
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    ct = jnp.clip(z, -1.0, 1.0)                      # cos(theta)
    st = jnp.sqrt(jnp.maximum(1.0 - ct * ct, 1e-12))
    phi = jnp.arctan2(y, x)

    # associated Legendre P_l^m(ct) for 0 <= m <= l <= l_max
    p = {}
    p[(0, 0)] = jnp.ones_like(ct)
    for m in range(1, l_max + 1):
        p[(m, m)] = -(2 * m - 1) * st * p[(m - 1, m - 1)]
    for m in range(0, l_max):
        p[(m + 1, m)] = (2 * m + 1) * ct * p[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            p[(l, m)] = ((2 * l - 1) * ct * p[(l - 1, m)]
                         - (l + m - 1) * p[(l - 2, m)]) / (l - m)

    import math
    fact = [float(math.factorial(i)) for i in range(2 * l_max + 1)]
    out = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            k = np.sqrt((2 * l + 1) / (4 * np.pi)
                        * fact[l - am] / fact[l + am])
            if m == 0:
                out.append(k * p[(l, 0)])
            elif m > 0:
                out.append(np.sqrt(2.0) * k * jnp.cos(m * phi) * p[(l, m)])
            else:
                out.append(np.sqrt(2.0) * k * jnp.sin(am * phi) * p[(l, am)])
    return jnp.stack(out, axis=-1)


def align_z_rotation(e: jnp.ndarray) -> jnp.ndarray:
    """Rotation matrix R with R @ e = z_hat (Rodrigues; e [..., 3] unit)."""
    z = jnp.zeros_like(e).at[..., 2].set(1.0)
    v = jnp.cross(e, z)                     # rotation axis * sin
    c = e[..., 2]                           # cos angle
    s2 = jnp.sum(v * v, axis=-1)
    # skew(v)
    zero = jnp.zeros_like(c)
    k = jnp.stack([
        jnp.stack([zero, -v[..., 2], v[..., 1]], -1),
        jnp.stack([v[..., 2], zero, -v[..., 0]], -1),
        jnp.stack([-v[..., 1], v[..., 0], zero], -1),
    ], -2)
    eye = jnp.broadcast_to(jnp.eye(3), k.shape)
    coef = jnp.where(s2 > 1e-12, (1.0 - c) / jnp.maximum(s2, 1e-12), 0.5)
    r = eye + k + coef[..., None, None] * (k @ k)
    # antipodal case e = -z: rotate pi about x
    flip = jnp.broadcast_to(
        jnp.asarray([[1.0, 0, 0], [0, -1.0, 0], [0, 0, -1.0]]), k.shape)
    return jnp.where((c < -1.0 + 1e-9)[..., None, None], flip, r)


@lru_cache(maxsize=None)
def _sample_dirs(n_pts: int = 64, seed: int = 7):
    """Fibonacci-sphere sample directions + per-l pinv of their SH matrix."""
    i = np.arange(n_pts, dtype=np.float64) + 0.5
    phi = np.arccos(1 - 2 * i / n_pts)
    theta = np.pi * (1 + 5 ** 0.5) * i
    dirs = np.stack([np.sin(phi) * np.cos(theta),
                     np.sin(phi) * np.sin(theta),
                     np.cos(phi)], axis=-1)
    return dirs


@lru_cache(maxsize=None)
def _pinv_blocks(l_max: int, n_pts: int = 64):
    dirs = _sample_dirs(n_pts)
    # May be reached during an outer trace (first call inside a jitted
    # forward); force eager evaluation of this host-side constant.
    with jax.ensure_compile_time_eval():
        y = np.asarray(real_sph_harm(jnp.asarray(dirs), l_max),
                       np.float64)            # [n_pts, (L+1)^2]
    pinvs = []
    off = 0
    for l in range(l_max + 1):
        a = y[:, off:off + 2 * l + 1]         # [n_pts, 2l+1]
        pinvs.append(np.linalg.pinv(a.T))     # [n_pts, 2l+1]
        off += 2 * l + 1
    return dirs, pinvs


def wigner_blocks(rot: jnp.ndarray, l_max: int, n_pts: int = 64,
                  m_max: int | None = None):
    """rot [..., 3, 3] -> list of D_l blocks, l = 0..l_max.

    With ``m_max`` set, only the rows with |m| <= m_max are built
    ([..., n_kept_l, 2l+1]) — the eSCN cut applied at construction, which
    also skips ~40% of the projection compute at l_max=6, m_max=2."""
    dirs_np, pinvs = _pinv_blocks(l_max, n_pts)
    dirs = jnp.asarray(dirs_np, rot.dtype)                    # [P, 3]
    rdirs = jnp.einsum("...ij,pj->...pi", rot, dirs)          # [..., P, 3]
    y_rot = real_sph_harm(rdirs, l_max)                       # [..., P, K]
    blocks = []
    off = 0
    for l in range(l_max + 1):
        b = y_rot[..., off:off + 2 * l + 1]                   # [..., P, 2l+1]
        if m_max is not None and l > m_max:
            # rows m = -m_max..m_max live at indices l+m
            keep = np.arange(l - m_max, l + m_max + 1)
            b = b[..., keep]
        # D = Y(RS)^T @ pinv(Y(S))^T  (so that Y(R r) = D Y(r))
        d = jnp.einsum("...pm,pn->...mn",
                       b, jnp.asarray(pinvs[l], rot.dtype))
        blocks.append(d)
        off += 2 * l + 1
    return blocks
