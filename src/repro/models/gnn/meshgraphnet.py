"""MeshGraphNet [arXiv:2010.03409]: encode-process-decode over a mesh.

15 message-passing blocks; edge update MLP(e, h_src, h_dst) and node update
MLP(h, sum of incoming edge features); residuals + LayerNorm; 2-layer MLPs
of width 128.  Aggregation goes through ``common.aggregate`` so the paper's
coherence/consistency config applies per input graph.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.config_space import SystemConfig
from repro.models.gnn.common import (DEFAULT_GNN_CONFIG, aggregate,
                                     init_mlp_stack, mlp_stack)

__all__ = ["MGNConfig", "init_mgn", "mgn_forward", "mgn_loss"]


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 12
    d_edge_in: int = 4
    d_out: int = 3
    sys: SystemConfig = DEFAULT_GNN_CONFIG


def _mlp_dims(cfg, d_in):
    return (d_in,) + (cfg.d_hidden,) * cfg.mlp_layers


def init_mgn(key, cfg: MGNConfig):
    ks = jax.random.split(key, 4)
    h = cfg.d_hidden

    def block(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge": init_mlp_stack(k1, _mlp_dims(cfg, 3 * h), layer_norm=True),
            "node": init_mlp_stack(k2, _mlp_dims(cfg, 2 * h), layer_norm=True),
        }

    return {
        "node_enc": init_mlp_stack(ks[0], _mlp_dims(cfg, cfg.d_node_in),
                                   layer_norm=True),
        "edge_enc": init_mlp_stack(ks[1], _mlp_dims(cfg, cfg.d_edge_in),
                                   layer_norm=True),
        "blocks": jax.vmap(block)(jax.random.split(ks[2], cfg.n_layers)),
        "decoder": init_mlp_stack(ks[3], (h, h, cfg.d_out)),
    }


def mgn_forward(cfg: MGNConfig, params, inputs):
    """inputs: node_feat [N,Fn], edge_feat [E,Fe], src [E], dst [E]."""
    n = inputs["node_feat"].shape[0]
    h = mlp_stack(params["node_enc"], inputs["node_feat"])
    e = mlp_stack(params["edge_enc"], inputs["edge_feat"])
    src, dst = inputs["src"], inputs["dst"]

    def body(carry, bp):
        h, e = carry
        he = jnp.concatenate(
            [e, jnp.take(h, src, axis=0), jnp.take(h, dst, axis=0)], axis=-1)
        e = e + mlp_stack(bp["edge"], he)
        agg = aggregate(e, dst, n, "sum", cfg.sys)
        h = h + mlp_stack(bp["node"], jnp.concatenate([h, agg], axis=-1))
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["blocks"])
    return mlp_stack(params["decoder"], h)


def mgn_loss(cfg: MGNConfig, params, batch):
    pred = mgn_forward(cfg, params, batch)
    return jnp.mean((pred - batch["target"]) ** 2)
