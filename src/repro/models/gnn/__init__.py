from repro.models.gnn.common import aggregate, segment_softmax
from repro.models.gnn.equiformer_v2 import (EquiformerV2Config,
                                            equiformer_forward,
                                            equiformer_loss, init_equiformer)
from repro.models.gnn.meshgraphnet import (MGNConfig, init_mgn, mgn_forward,
                                           mgn_loss)
from repro.models.gnn.pna import PNAConfig, init_pna, pna_forward, pna_loss
from repro.models.gnn.schnet import (SchNetConfig, init_schnet,
                                     schnet_forward, schnet_loss)
