"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

4 layers, hidden 75, aggregators {mean, max, min, std} x scalers
{identity, amplification, attenuation} -> 12 aggregated views, concatenated
and mixed by a linear tower.  The multi-aggregator step is 4 parallel
segment reductions — the densest consumer of the paper's design space in
this suite (each reduction goes through ``common.aggregate``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.config_space import SystemConfig
from repro.models import layers as L
from repro.models.gnn.common import (DEFAULT_GNN_CONFIG, aggregate,
                                     init_mlp_stack, mlp_stack)

__all__ = ["PNAConfig", "init_pna", "pna_forward", "pna_loss"]


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 1433
    n_classes: int = 7
    delta: float = 2.5   # mean log-degree of the training graphs
    sys: SystemConfig = DEFAULT_GNN_CONFIG


def init_pna(key, cfg: PNAConfig):
    ks = jax.random.split(key, 3)
    h = cfg.d_hidden

    def block(k):
        k1, k2 = jax.random.split(k)
        return {
            "pre": init_mlp_stack(k1, (2 * h, h)),      # msg MLP(h_src,h_dst)
            "post": init_mlp_stack(k2, (12 * h + h, h), layer_norm=True),
        }

    return {
        "enc": init_mlp_stack(ks[0], (cfg.d_in, h)),
        "blocks": jax.vmap(block)(jax.random.split(ks[1], cfg.n_layers)),
        "head": init_mlp_stack(ks[2], (h, h, cfg.n_classes)),
    }


def pna_forward(cfg: PNAConfig, params, inputs):
    """inputs: node_feat [N,F], src/dst [E], in_degree [N]."""
    n = inputs["node_feat"].shape[0]
    src, dst = inputs["src"], inputs["dst"]
    deg = jnp.maximum(inputs["in_degree"].astype(jnp.float32), 1.0)
    log_deg = jnp.log(deg + 1.0)[:, None]
    s_amp = (log_deg / cfg.delta)
    s_att = (cfg.delta / log_deg)

    h = mlp_stack(params["enc"], inputs["node_feat"])

    def body(h, bp):
        msg = mlp_stack(bp["pre"], jnp.concatenate(
            [jnp.take(h, src, axis=0), jnp.take(h, dst, axis=0)], axis=-1))
        ssum = aggregate(msg, dst, n, "sum", cfg.sys)
        mean = ssum / deg[:, None]
        mx = aggregate(msg, dst, n, "max", cfg.sys)
        mn = aggregate(msg, dst, n, "min", cfg.sys)
        sq = aggregate(msg * msg, dst, n, "sum", cfg.sys) / deg[:, None]
        std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-5)
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
        mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
        agg = jnp.concatenate([mean, mx, mn, std], axis=-1)     # [N, 4h]
        agg = jnp.concatenate([agg, agg * s_amp, agg * s_att], axis=-1)
        h = h + mlp_stack(bp["post"], jnp.concatenate([h, agg], axis=-1))
        return h, None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    return mlp_stack(params["head"], h)


def pna_loss(cfg: PNAConfig, params, batch):
    logits = pna_forward(cfg, params, batch)
    return L.cross_entropy(logits, batch["labels"])
