"""EquiformerV2 [arXiv:2306.12059] — equivariant graph attention with
eSCN-style SO(2) convolutions (l_max=6, m_max=2, 8 heads, 12 layers).

TPU adaptation of the eSCN trick (the paper's O(L^6) -> O(L^3) reduction):
per edge, node features (real-SH irreps, [N, (L+1)^2, C]) are rotated into
the edge-aligned frame by Wigner blocks built via sample-projection
(sh.wigner_blocks — exact, recursion-free, vmap-friendly).  In that frame
the convolution is block-diagonal in m; components with |m| > m_max are
dropped (the cut), and each m-block mixes (cos, sin) pairs through an
(L-mix x C-mix) factorised SO(2) linear map modulated by radial basis
weights.  Messages are weighted by invariant multi-head attention
(segment-softmax over incoming edges — the paper's pull-style reduction)
and rotated back before a scatter-sum node update (push-style).

Both reductions run through ``common.aggregate``/``segment_softmax`` so
the coherence/consistency configuration applies.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config_space import SystemConfig
from repro.models import layers as L
from repro.models.gnn import sh
from repro.models.gnn.common import (DEFAULT_GNN_CONFIG, aggregate,
                                     init_mlp_stack, mlp_stack,
                                     segment_softmax)

__all__ = ["EquiformerV2Config", "init_equiformer", "equiformer_forward",
           "equiformer_loss"]


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 64
    cutoff: float = 10.0
    n_species: int = 100
    n_graphs: int = 128
    sys: SystemConfig = DEFAULT_GNN_CONFIG

    @property
    def n_coeff(self) -> int:
        return (self.l_max + 1) ** 2

    @property
    def m_blocks(self):
        """Per |m| block: list of (m, l-count) for m = 0..m_max."""
        return [(m, self.l_max + 1 - m) for m in range(self.m_max + 1)]


def _coeff_index(l_max):
    """(l, m) -> flat index in the l-major SH layout."""
    idx = {}
    off = 0
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            idx[(l, m)] = off + m + l
        off += 2 * l + 1
    return idx


def _compact_index(l_max, m_max):
    """(l, m) -> index in the COMPACT (|m| <= m_max) l-major layout used
    for edge messages (§Perf C2: 29 of 49 rows at l_max=6, m_max=2)."""
    idx = {}
    n = 0
    for l in range(l_max + 1):
        mm = min(l, m_max)
        for m in range(-mm, mm + 1):
            idx[(l, m)] = n
            n += 1
    return idx, n


def init_equiformer(key, cfg: EquiformerV2Config):
    ks = jax.random.split(key, 6)
    c, h = cfg.d_hidden, cfg.n_heads

    def so2_block(k):
        kk = jax.random.split(k, 2 * (cfg.m_max + 1) + 2)
        p = {"c_mix": (jax.random.normal(kk[0], (c, c)) * c ** -0.5)}
        for m, nl in cfg.m_blocks:
            p[f"l_mix_{m}"] = (jax.random.normal(kk[2 * m + 1], (nl, nl))
                               * nl ** -0.5)
            if m > 0:
                p[f"l_mix_{m}_im"] = (jax.random.normal(
                    kk[2 * m + 2], (nl, nl)) * nl ** -0.5)
        return p

    def block(k):
        kk = jax.random.split(k, 6)
        return {
            "so2": so2_block(kk[0]),
            "radial": init_mlp_stack(kk[1], (cfg.n_rbf, c, cfg.m_max + 1)),
            "attn": init_mlp_stack(kk[2], (2 * c + cfg.n_rbf, c, h)),
            "lin_out": (jax.random.normal(kk[3], (cfg.l_max + 1, c, c))
                        * c ** -0.5),
            "gate": init_mlp_stack(kk[4], (c, c * cfg.l_max)),
            "ffn0": init_mlp_stack(kk[5], (c, 2 * c, c)),
        }

    return {
        "embed": (jax.random.normal(ks[0], (cfg.n_species, c)) * 0.3),
        "blocks": jax.vmap(block)(jax.random.split(ks[1], cfg.n_layers)),
        "head": init_mlp_stack(ks[2], (c, c, 1)),
    }


def _so2_conv(cfg: EquiformerV2Config, p, z, radial):
    """SO(2) conv in the edge frame, COMPACT layout: z [E, n_kept, C]
    (only |m| <= m_max rows exist); radial [E, m_max+1] per-m modulation."""
    cidx, _ = _compact_index(cfg.l_max, cfg.m_max)
    cm = p["c_mix"].astype(z.dtype)
    out = jnp.zeros_like(z)
    for m, nl in cfg.m_blocks:
        ls = list(range(m, cfg.l_max + 1))
        rows_p = np.asarray([cidx[(l, m)] for l in ls], np.int32)
        lr = p[f"l_mix_{m}"].astype(z.dtype)
        if m == 0:
            x0 = z[:, rows_p, :]                       # [E, nl, C]
            y0 = jnp.einsum("enc,nm,cd->emd", x0, lr, cm)
            y0 = y0 * radial[:, m, None, None]
            out = out.at[:, rows_p, :].set(y0.astype(out.dtype))
        else:
            rows_n = np.asarray([cidx[(l, -m)] for l in ls], np.int32)
            li = p[f"l_mix_{m}_im"].astype(z.dtype)
            xp = z[:, rows_p, :]
            xn = z[:, rows_n, :]
            yp = jnp.einsum("enc,nm,cd->emd", xp, lr, cm) \
                - jnp.einsum("enc,nm,cd->emd", xn, li, cm)
            yn = jnp.einsum("enc,nm,cd->emd", xn, lr, cm) \
                + jnp.einsum("enc,nm,cd->emd", xp, li, cm)
            yp = yp * radial[:, m, None, None]
            yn = yn * radial[:, m, None, None]
            out = out.at[:, rows_p, :].set(yp.astype(out.dtype))
            out = out.at[:, rows_n, :].set(yn.astype(out.dtype))
    return out


def _rotate_in(blocks, x):
    """Full layout -> compact edge frame: z_l = D_kept_l @ x_l.
    blocks[l]: [E, n_kept_l, 2l+1]; x [E, (L+1)^2, C] -> [E, n_kept, C]."""
    outs = []
    off = 0
    for l, d in enumerate(blocks):
        xl = x[:, off:off + 2 * l + 1, :]
        outs.append(jnp.einsum("emk,ekc->emc", d.astype(x.dtype), xl))
        off += 2 * l + 1
    return jnp.concatenate(outs, axis=1)


def _rotate_out(blocks, z):
    """Compact edge frame -> full layout: out_l = D_kept_l^T @ z_l
    (orthogonal D: the transpose restricted to kept rows)."""
    outs = []
    off = 0
    for l, d in enumerate(blocks):
        nk = d.shape[-2]
        zl = z[:, off:off + nk, :]
        outs.append(jnp.einsum("emk,emc->ekc", d.astype(z.dtype), zl))
        off += nk
    return jnp.concatenate(outs, axis=1)


def _rbf(cfg, dist):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    width = cfg.cutoff / cfg.n_rbf
    return jnp.exp(-((dist[:, None] - centers[None, :]) / width) ** 2)


def equiformer_forward(cfg: EquiformerV2Config, params, inputs):
    """inputs: species [N], positions [N,3], src/dst [E], graph_ids [N]."""
    n = inputs["species"].shape[0]
    src, dst = inputs["src"], inputs["dst"]
    pos = inputs["positions"]
    vec = jnp.take(pos, src, axis=0) - jnp.take(pos, dst, axis=0)
    dist = jnp.linalg.norm(vec + 1e-9, axis=-1)
    unit = vec / jnp.maximum(dist, 1e-9)[:, None]
    rbf = _rbf(cfg, dist)
    # kept-row Wigner blocks only (§Perf C2: the eSCN |m|<=m_max cut is
    # applied at rotation-construction time — 29/49 rows at L=6, m=2)
    rots = sh.wigner_blocks(sh.align_z_rotation(unit), cfg.l_max,
                            m_max=cfg.m_max)

    k = cfg.n_coeff
    c = cfg.d_hidden
    x = jnp.zeros((n, k, c), jnp.float32)
    x = x.at[:, 0, :].set(jnp.take(params["embed"], inputs["species"],
                                   axis=0))
    from repro.models.gnn.common import constrain_flat

    def body(x, bp):
        x = constrain_flat(x)                                  # §Perf C1
        # --- invariant multi-head attention over incoming edges (pull) ---
        inv = x[:, 0, :]
        feat = jnp.concatenate([jnp.take(inv, src, axis=0),
                                jnp.take(inv, dst, axis=0), rbf], axis=-1)
        logits = mlp_stack(bp["attn"], feat)                   # [E, H]
        alpha = segment_softmax(logits, dst, n, cfg.sys)       # [E, H]
        # --- eSCN message: rotate -> SO(2) conv -> rotate back (push) ---
        # edge-resident tensors in bf16 (§Perf C3): message traffic and
        # aggregation collectives at half the bytes.  The bf16 cast happens
        # BEFORE the src gather so the cross-device x movement (the SpMM
        # gather — dominant on ogb_products) is half-width too.
        radial = mlp_stack(bp["radial"], rbf).astype(jnp.bfloat16)
        xb = x.astype(jnp.bfloat16)
        z = _rotate_in(rots, jnp.take(xb, src, axis=0))        # [E, nk, C]
        z = _so2_conv(cfg, bp["so2"], z, radial)
        # attention weighting: heads partition the channel dim
        aw = jnp.repeat(alpha, c // cfg.n_heads, axis=-1)      # [E, C]
        z = z * aw[:, None, :].astype(z.dtype)
        msg = _rotate_out(rots, z)                             # full layout
        agg = aggregate(msg, dst, n, "sum", cfg.sys) \
            .astype(jnp.float32)                               # [N, K, C]
        # --- node update: per-l linear + gated nonlinearity --------------
        upd = []
        off = 0
        for l in range(cfg.l_max + 1):
            upd.append(jnp.einsum("nmc,cd->nmd",
                                  agg[:, off:off + 2 * l + 1, :],
                                  bp["lin_out"][l]))
            off += 2 * l + 1
        upd = jnp.concatenate(upd, axis=1)
        x = x + upd
        # gate: scalars modulate each higher-l degree
        gates = jax.nn.sigmoid(mlp_stack(bp["gate"], x[:, 0, :]))  # [N, C*L]
        gates = gates.reshape(n, cfg.l_max, c)
        scale = jnp.concatenate(
            [jnp.ones((n, 1, c))] +
            [jnp.repeat(gates[:, l - 1:l, :], 2 * l + 1, axis=1)
             for l in range(1, cfg.l_max + 1)], axis=1)
        x = x * scale
        x = x.at[:, 0, :].add(mlp_stack(bp["ffn0"], x[:, 0, :]))
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    atom_e = mlp_stack(params["head"], x[:, 0, :])             # invariant
    return aggregate(atom_e[:, 0], inputs["graph_ids"], cfg.n_graphs,
                     "sum", cfg.sys)


def equiformer_loss(cfg: EquiformerV2Config, params, batch):
    pred = equiformer_forward(cfg, params, batch)
    return jnp.mean((pred - batch["energy"]) ** 2)
