"""Version-spanning access to the active abstract mesh.

``jax.sharding.get_abstract_mesh`` appeared in jax 0.5; earlier versions
carry the mesh context in ``thread_resources`` (set by ``with mesh:``).
Model code asks one question — "is a mesh context active, and which?" —
so expose exactly that and keep the version probing out of model files.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["active_abstract_mesh"]


def active_abstract_mesh() -> Optional["jax.sharding.AbstractMesh"]:
    """The active abstract mesh, or None when no mesh context is set."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        am = getter()
        return None if am is None or am.empty else am
    from jax._src import mesh as _mesh_lib  # pre-0.5 fallback
    phys = _mesh_lib.thread_resources.env.physical_mesh
    return None if phys.empty else phys.abstract_mesh
