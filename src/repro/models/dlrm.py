"""DLRM (MLPerf config, Criteo-1TB) [arXiv:1906.00091].

13 dense features -> bottom MLP 512-256-128; 26 categorical features ->
row-sharded embedding tables (dim 128) via embedding-bag (jnp.take +
segment reduction — JAX has no native EmbeddingBag; the Pallas kernel is
the TPU hot path); dot-product feature interaction over the 27 vectors;
top MLP 1024-1024-512-256-1; BCE loss.

``retrieval_score`` serves the retrieval_cand shape: one user against 1M
candidate embeddings as a single batched dot (no loops).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn.common import init_mlp_stack, mlp_stack

__all__ = ["DLRMConfig", "CRITEO_1TB_VOCABS", "init_dlrm", "dlrm_forward",
           "dlrm_loss", "retrieval_score"]

#: MLPerc DLRM (Criteo Terabyte) per-feature vocabulary sizes.
CRITEO_1TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771, 25641295,
    39664984, 585935, 12972, 108, 36,
)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    vocab_sizes: tuple[int, ...] = CRITEO_1TB_VOCABS
    embed_dim: int = 128
    bot_mlp: tuple[int, ...] = (512, 256, 128)
    top_mlp: tuple[int, ...] = (1024, 1024, 512, 256, 1)
    multi_hot: int = 1     # indices per feature (bag size)

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def padded_vocab_sizes(self) -> tuple[int, ...]:
        """Table allocation sizes: big (sharded) tables round up to the
        512-row multiple so row-sharding divides on any mesh; lookups use
        logical indices so padding rows are dead weight only."""
        return tuple(-(-v // 512) * 512 if v >= 4096 else v
                     for v in self.vocab_sizes)

    @property
    def n_embed_rows(self) -> int:
        return sum(self.vocab_sizes)

    @property
    def n_params(self) -> int:
        d = self.embed_dim
        n = self.n_embed_rows * d
        dims = (self.n_dense,) + self.bot_mlp
        n += sum(dims[i] * dims[i + 1] + dims[i + 1]
                 for i in range(len(dims) - 1))
        n_int = (self.n_sparse + 1) * self.n_sparse // 2 + d
        tdims = (n_int,) + self.top_mlp
        n += sum(tdims[i] * tdims[i + 1] + tdims[i + 1]
                 for i in range(len(tdims) - 1))
        return n


def init_dlrm(key, cfg: DLRMConfig):
    ks = jax.random.split(key, 3 + cfg.n_sparse)
    d = cfg.embed_dim
    tables = [
        (jax.random.normal(ks[3 + i], (v, d), jnp.float32)
         * (1.0 / jnp.sqrt(v))).astype(jnp.float32)
        for i, v in enumerate(cfg.padded_vocab_sizes)
    ]
    n_int = (cfg.n_sparse + 1) * cfg.n_sparse // 2 + d
    return {
        "tables": tables,
        "bot": init_mlp_stack(ks[0], (cfg.n_dense,) + cfg.bot_mlp),
        "top": init_mlp_stack(ks[1], (n_int,) + cfg.top_mlp),
    }


def _interact(bottom: jnp.ndarray, embs: jnp.ndarray) -> jnp.ndarray:
    """bottom [B,D]; embs [B,F,D] -> dot interaction + bottom passthrough."""
    z = jnp.concatenate([bottom[:, None, :], embs], axis=1)   # [B, F+1, D]
    gram = jnp.einsum("bfd,bgd->bfg", z, z,
                      preferred_element_type=jnp.float32)
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    pairs = gram[:, iu, ju]                                   # [B, F(F-1)/2]
    return jnp.concatenate([bottom, pairs], axis=-1)


def dlrm_forward(cfg: DLRMConfig, params, batch, impl: str = "xla"):
    """batch: dense [B, 13] f32; sparse [B, 26, multi_hot] int32."""
    bottom = mlp_stack(params["bot"], batch["dense"], final_act=True)
    from repro.kernels.embedding_bag.ops import embedding_bag
    embs = [
        embedding_bag(params["tables"][i], batch["sparse"][:, i, :],
                      mode="sum", impl=impl)
        for i in range(cfg.n_sparse)
    ]
    embs = jnp.stack(embs, axis=1)                            # [B, 26, D]
    x = _interact(bottom, embs)
    logit = mlp_stack(params["top"], x)[:, 0]
    return logit


def dlrm_loss(cfg: DLRMConfig, params, batch, impl: str = "xla"):
    logit = dlrm_forward(cfg, params, batch, impl=impl)
    y = batch["label"].astype(jnp.float32)
    z = logit.astype(jnp.float32)
    # numerically stable BCE-with-logits
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def retrieval_score(cfg: DLRMConfig, params, batch):
    """One query scored against n_candidates item embeddings.

    batch: dense [1, 13]; sparse [1, 26, multi_hot]; cand [N_c, D].
    Returns [N_c] scores = <user tower output, candidate embedding>."""
    bottom = mlp_stack(params["bot"], batch["dense"], final_act=True)  # [1,D]
    return jnp.einsum("nd,bd->n", batch["cand"], bottom)
