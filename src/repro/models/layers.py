"""Shared neural building blocks (functional: params are plain pytrees).

Everything here is shape-polymorphic and shard_map/pjit-friendly; matmuls
accumulate in fp32 (``preferred_element_type``) with bf16 params/activations
by default — the TPU-native mixed-precision contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["Dtypes", "DEFAULT_DTYPES", "dense", "init_dense", "rms_norm",
           "layer_norm", "init_norm", "rope", "blocked_attention_xla",
           "gqa_attention", "mlp", "init_mlp", "cross_entropy"]


@dataclasses.dataclass(frozen=True)
class Dtypes:
    param: jnp.dtype = jnp.bfloat16
    compute: jnp.dtype = jnp.bfloat16
    accum: jnp.dtype = jnp.float32


DEFAULT_DTYPES = Dtypes()


# ---------------------------------------------------------------------------
# linear / norm
# ---------------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, use_bias: bool = False,
               dtype=jnp.bfloat16, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)
    p = {"w": w.astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    # Output dtype == input dtype (bf16 in, bf16 out).  The TPU MXU always
    # accumulates fp32 internally; emitting bf16 keeps the BACKWARD
    # cotangents bf16 too — an fp32 output here makes every activation
    # cotangent fp32, doubling backward memory AND collective bytes
    # (measured: §Perf iteration A1).
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def init_norm(d: int, dtype=jnp.bfloat16, with_bias: bool = False):
    p = {"scale": jnp.ones((d,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x [..., S, D] (D even), positions [..., S] -> rotated x."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., ::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (blocked-XLA path; the Pallas kernel is the TPU fast path)
# ---------------------------------------------------------------------------
def blocked_attention_xla(q, k, v, *, causal: bool = True,
                          window: Optional[int] = None,
                          q_chunk: int = 1024, k_chunk: int = 1024):
    """Memory-efficient (online-softmax) attention in pure XLA.

    q [B,H,Sq,D], k/v [B,H,Sk,D].  Peak intermediate is
    [B,H,q_chunk,k_chunk] — never Sq x Sk.  Mirrors the Pallas flash
    kernel's math so either can serve a model unchanged.
    ``window``: optional sliding-window (StarCoder2) causal mask width.
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    orig_sq = sq
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    if sq % q_chunk:
        pad = q_chunk - sq % q_chunk
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        sq += pad
    if sk % k_chunk:
        padk = k_chunk - sk % k_chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, padk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, padk), (0, 0)))
    n_q, n_k = sq // q_chunk, k.shape[2] // k_chunk
    scale = d ** -0.5
    seq_off = sk - orig_sq  # causal offset (q is the suffix)

    q_r = q.reshape(b, h, n_q, q_chunk, d)

    def q_step(qi):
        qc = q_r[:, :, qi]                     # [B,H,qc,D]
        rows = qi * q_chunk + jnp.arange(q_chunk) + seq_off

        def k_step(carry, ki):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, 2)
            vs = jax.lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, 2)
            s = jnp.einsum("bhqd,bhkd->bhqk", qc, ks,
                           preferred_element_type=jnp.float32) * scale
            cols = ki * k_chunk + jnp.arange(k_chunk)
            mask = cols[None, :] <= sk - 1     # drop kv padding
            if causal:
                mask &= cols[None, :] <= rows[:, None]
            if window is not None:
                mask &= cols[None, :] > rows[:, None] - window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l_new = l * alpha + p.sum(-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vs.dtype), vs,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, h, q_chunk, 1), -1e30, jnp.float32),
                jnp.zeros((b, h, q_chunk, 1), jnp.float32),
                jnp.zeros((b, h, q_chunk, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(k_step, init, jnp.arange(n_k))
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    # remat each q-chunk: the inner k-scan would otherwise SAVE its fp32
    # (m, l, acc) carries per k step for the backward — recomputing the
    # chunk is the flash-attention backward contract (§Perf A4)
    q_step = jax.checkpoint(q_step)
    out = jax.lax.map(q_step, jnp.arange(n_q))       # [n_q,B,H,qc,D]
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, sq, d)
    return out[:, :, :orig_sq]


def gqa_attention(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None):
    """GQA wrapper: q [B,Hq,S,D], k/v [B,Hkv,S,D].

    k/v are shared across each query group via vmap broadcasting — no
    ``repeat`` materialisation (that would multiply KV-cache bytes by the
    group size; fatal at 500k context).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hq == hkv:
        return blocked_attention_xla(q, k, v, causal=causal, window=window)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d).transpose(2, 0, 1, 3, 4)  # [G,B,Hkv,S,D]
    out = jax.vmap(lambda qq: blocked_attention_xla(
        qq, k, v, causal=causal, window=window))(qg)
    return out.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, d)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, act: str, use_bias: bool = False,
             dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": init_dense(k1, d_model, d_ff, use_bias, dtype),
         "down": init_dense(k2, d_ff, d_model, use_bias, dtype)}
    if act in ("swiglu", "geglu"):
        p["gate"] = init_dense(k3, d_model, d_ff, use_bias, dtype)
    return p


def mlp(p, x, act: str):
    # activations evaluated in the compute dtype (bf16): keeps cotangents
    # bf16 (see `dense`); norms/softmax stay fp32 where it matters.
    up = dense(p["up"], x)
    if act == "swiglu":
        up = jax.nn.silu(dense(p["gate"], x)) * up
    elif act == "geglu":
        up = jax.nn.gelu(dense(p["gate"], x)) * up
    elif act == "gelu":
        up = jax.nn.gelu(up)
    elif act == "relu":
        up = jax.nn.relu(up)
    elif act == "silu":
        up = jax.nn.silu(up)
    else:
        raise ValueError(act)
    return dense(p["down"], up)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore_id: int = -1) -> jnp.ndarray:
    """logits [..., V] fp32-safe CE with ignore mask; mean over valid."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - picked
    valid = labels != ignore_id
    return jnp.sum(nll * valid) / jnp.maximum(valid.sum(), 1)
