"""Dense GQA transformer LM (command-r-plus-104b / command-r-35b /
starcoder2-7b) with scan-over-layers (compile time independent of depth),
per-block activation remat, and three lowered entry points:

- ``train_forward``  — next-token CE loss (train_* shapes)
- ``prefill``        — causal forward returning the KV cache (prefill_*)
- ``decode_step``    — one token against a KV cache (decode_* / long_*)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = ["LMConfig", "init_lm", "train_forward", "prefill", "decode_step",
           "abstract_lm_params"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "swiglu"
    norm: str = "rmsnorm"
    parallel_block: bool = False   # command-r family: attn + mlp in parallel
    use_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None   # starcoder2: sliding-window attention
    tie_embeddings: bool = True
    remat: bool = True
    param_dtype: str = "bfloat16"
    ce_chunk: int = 256            # sequence-chunked CE: never materialise
    #                                the full [B,S,V] logits tensor
    dp_axes: tuple = ()            # mesh axes for batch ("data"[, "pod"])
    tp_axis: Optional[str] = None  # mesh axis for tensor parallelism
    sp_axis: Optional[str] = None  # sequence-parallel axis between blocks

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def n_params(self) -> int:
        d, f, v, h = self.d_model, self.d_ff, self.vocab, self.d_head
        attn = d * h * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * h * d
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        return self.n_layers * (attn + glu * d * f) + v * d


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def _init_block(key, cfg: LMConfig):
    ks = jax.random.split(key, 8)
    dt = cfg.dtype
    h = cfg.d_head
    p = {
        "ln1": L.init_norm(cfg.d_model, dt),
        "attn": {
            "wq": L.init_dense(ks[0], cfg.d_model, cfg.n_heads * h,
                               cfg.use_bias, dt),
            "wk": L.init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * h,
                               cfg.use_bias, dt),
            "wv": L.init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * h,
                               cfg.use_bias, dt),
            "wo": L.init_dense(ks[3], cfg.n_heads * h, cfg.d_model,
                               cfg.use_bias, dt),
        },
        "mlp": L.init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.act,
                          cfg.use_bias, dt),
    }
    if not cfg.parallel_block:
        p["ln2"] = L.init_norm(cfg.d_model, dt)
    return p


def init_lm(key, cfg: LMConfig):
    k_embed, k_blocks = jax.random.split(key)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(
        jax.random.split(k_blocks, cfg.n_layers))
    p = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(cfg.dtype),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg.d_model, cfg.dtype),
    }
    return p


def abstract_lm_params(cfg: LMConfig):
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return jax.eval_shape(lambda: init_lm(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _norm(cfg, p, x):
    return L.rms_norm(p, x) if cfg.norm == "rmsnorm" else L.layer_norm(p, x)


def _attention(cfg: LMConfig, p, x, positions, kv=None, kv_len=None):
    """x [B,S,d].  kv: optional (k_cache, v_cache) [B,Hkv,Smax,dh] for
    decode; returns (out [B,S,d], (k, v) computed for these tokens)."""
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = L.dense(p["wq"], x).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = L.dense(p["wk"], x).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = L.dense(p["wv"], x).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    q = L.rope(q, positions[:, None, :], cfg.rope_theta)
    k = L.rope(k, positions[:, None, :], cfg.rope_theta)
    if kv is None:
        o = L.gqa_attention(q, k, v, causal=True, window=cfg.window)
    else:
        kc, vc = kv
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 kv_len, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 kv_len, axis=2)
        from repro.kernels.flash_attention.ref import decode_ref
        o = decode_ref(q, kc, vc, kv_len + s, window=cfg.window)
        k, v = kc, vc
    o = o.astype(x.dtype)  # cache dtype may differ (e.g. fp32 cache)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return L.dense(p["wo"], o), (k, v)


def _block(cfg: LMConfig, p, x, positions):
    h = _norm(cfg, p["ln1"], x)
    a, _ = _attention(cfg, p["attn"], h, positions)
    if cfg.parallel_block:
        m = L.mlp(p["mlp"], h, cfg.act)
        return x + a + m
    x = x + a
    h2 = _norm(cfg, p["ln2"], x)
    return x + L.mlp(p["mlp"], h2, cfg.act)


def _constrain_act(cfg: LMConfig, x):
    """Sequence-parallel sharding constraint on the scan carry: the remat
    residual per layer is then S-sharded -> 1/tp of the activation bytes."""
    if cfg.sp_axis is not None:
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(
            x, P(cfg.dp_axes or None, cfg.sp_axis, None))
    return x


def _stack(cfg: LMConfig, params, x, positions):
    block = partial(_block, cfg)
    if cfg.remat:
        block = jax.checkpoint(block,
                               policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, layer_p):
        carry = _constrain_act(cfg, carry)
        return block(layer_p, carry, positions), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def _logits(cfg, params, x):
    x = _norm(cfg, params["final_norm"], x)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"],
                      preferred_element_type=jnp.float32)


def chunked_ce(cfg: LMConfig, params, x, labels):
    """Sequence-chunked cross-entropy: per-chunk logits [B,c,V] only."""
    b, s, _ = x.shape
    c = min(cfg.ce_chunk, s)
    n = s // c
    xc = x[:, :n * c].reshape(b, n, c, -1)
    lc = labels[:, :n * c].reshape(b, n, c)

    def body(carry, i):
        xi = jax.lax.dynamic_index_in_dim(xc, i, axis=1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(lc, i, axis=1, keepdims=False)
        # logits matmul stays in the compute dtype: an fp32 output here
        # would make the cotangent of x fp32 through the WHOLE backward
        # scan (2x bytes on every activation collective — §Perf A2);
        # the softmax/CE itself is fp32.
        h = _norm(cfg, params["final_norm"], xi)
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
        if cfg.tp_axis is not None:
            from jax.sharding import PartitionSpec as P
            logits = jax.lax.with_sharding_constraint(
                logits, P(cfg.dp_axes or None, None, cfg.tp_axis))
        logits32 = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits32, axis=-1)
        picked = jnp.take_along_axis(
            logits32, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = li != -1
        nll = jnp.sum((lse - picked) * valid)
        return (carry[0] + nll, carry[1] + valid.sum()), None

    body = jax.checkpoint(body) if cfg.remat else body
    (nll, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.int32(0)),
                                 jnp.arange(n))
    return nll / jnp.maximum(cnt, 1)


def train_forward(cfg: LMConfig, params, batch):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _stack(cfg, params, x, positions)
    return chunked_ce(cfg, params, x, labels)


def prefill(cfg: LMConfig, params, tokens):
    """Returns (last-token logits [B,V], cache (k,v) [L,B,Hkv,S,dh])."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(carry, layer_p):
        h = _norm(cfg, layer_p["ln1"], carry)
        a, (k, v) = _attention(cfg, layer_p["attn"], h, positions)
        if cfg.parallel_block:
            out = carry + a + L.mlp(layer_p["mlp"], h, cfg.act)
        else:
            mid = carry + a
            out = mid + L.mlp(layer_p["mlp"], _norm(cfg, layer_p["ln2"], mid),
                              cfg.act)
        return out, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    return _logits(cfg, params, x[:, -1:, :])[:, 0], (ks, vs)


def decode_step(cfg: LMConfig, params, token, cache, kv_len):
    """token [B,1]; cache (k,v) [L,B,Hkv,Smax,dh]; kv_len int32 scalar.
    Returns (logits [B,1,V], new cache)."""
    b = token.shape[0]
    positions = jnp.broadcast_to(kv_len, (b, 1)).astype(jnp.int32)
    x = jnp.take(params["embed"], token, axis=0)

    def body(carry, xs):
        layer_p, kc, vc = xs
        h = _norm(cfg, layer_p["ln1"], carry)
        a, (kc, vc) = _attention(cfg, layer_p["attn"], h, positions,
                                 kv=(kc, vc), kv_len=kv_len)
        if cfg.parallel_block:
            out = carry + a + L.mlp(layer_p["mlp"], h, cfg.act)
        else:
            mid = carry + a
            out = mid + L.mlp(layer_p["mlp"], _norm(cfg, layer_p["ln2"], mid),
                              cfg.act)
        return out, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], *cache))
    return _logits(cfg, params, x), (ks, vs)
