"""Testing utilities: the seeded fault-injection harness driving the
resilience test battery and ``benchmarks/resilience.py``."""
from repro.testing.faults import (FAULT_MODES, BitFlipFault, CompileFault,
                                  InjectedFault, NaNFault,
                                  RunnerExceptionFault, SliceFaultInjector,
                                  SliceNaNFault, SliceExceptionFault,
                                  SparseOverflowFault, StaleUpdateFault,
                                  make_fault)

__all__ = ["FAULT_MODES", "make_fault", "InjectedFault", "NaNFault",
           "BitFlipFault", "StaleUpdateFault", "RunnerExceptionFault",
           "SparseOverflowFault", "CompileFault", "SliceFaultInjector",
           "SliceNaNFault", "SliceExceptionFault"]
