"""Seeded fault injectors for the execution-core resilience layer.

Each injector subclasses :class:`repro.core.resilience.FaultInjector`
and corrupts exactly one thing, deterministically (``numpy`` Generator
seeded per instance), at a declared point in the run:

============  =========================================================
mode          what it does
============  =========================================================
``nan``       overwrites a slice of the largest float state leaf with
              NaN at a segment boundary (the classic silent-divergence
              hazard; caught by the NaN sentinel)
``bitflip``   XORs bit 30 into a few entries of the largest non-bool
              state leaf (emulates a corrupted store; caught by range/
              frozen/monotone sentinels)
``stale``     reverts a random subset of vertices to their values at
              the last checkpoint (emulates DRFrlx dropped updates;
              *invisible* to boundary sentinels by construction —
              caught by the convergence certificate, or harmlessly
              absorbed by attractive-fixpoint programs)
``exception`` raises :class:`InjectedFault` from the segment dispatch
              (emulates a runner/XLA crash)
``overflow``  forces ``sparse_edge_capacity=1`` so every sparse gather
              overflows into the dense fallback (must be result-
              invariant: overflow falls back, never drops edges)
``compile``   raises from the attempt's build step while the engine
              matches (emulates a compile failure; recovery must walk
              the degradation chain to another engine)
============  =========================================================

``once=True`` (default for state perturbations) means a mode fires a
single time — after a rollback the re-execution is clean, so recovery
must converge to the fault-free answer bit for bit.

Gateway-side injectors (``SliceExceptionFault``, ``SliceNaNFault``)
target one ticket of a continuous-batching lane: the scheduler's
recovery must quarantine only that slot.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.resilience import FaultInjector

__all__ = ["InjectedFault", "SimulatedProcessDeath", "NaNFault",
           "BitFlipFault", "StaleUpdateFault", "RunnerExceptionFault",
           "SparseOverflowFault", "CompileFault", "ProcessKillFault",
           "SliceFaultInjector", "SliceExceptionFault", "SliceNaNFault",
           "GatewayKillFault", "FAULT_MODES", "make_fault"]


class InjectedFault(RuntimeError):
    """The exception every forced-failure injector raises — tests can
    distinguish injected crashes from genuine bugs."""


class SimulatedProcessDeath(BaseException):
    """A process boundary, not a fault: deliberately a ``BaseException``
    so it escapes *every* in-process recovery net (``run_resilient``'s
    retry loop and the gateway's slice containment both catch
    ``Exception`` only) exactly the way ``SIGKILL`` would.  The chaos
    harness and crash-recovery tests catch it one frame above the
    "process", then restart from durable state — anything the killed
    process would have needed to survive must already be on disk."""


def _copy_state(state):
    return {k: np.array(v, copy=True) for k, v in state.items()}


def _array_items(state, float_only=False, skip_bool=True):
    items = []
    for k in sorted(state):
        a = np.asarray(state[k])
        if skip_bool and a.dtype == np.bool_:
            continue
        if float_only and not np.issubdtype(a.dtype, np.floating):
            continue
        items.append((k, a))
    return items


class NaNFault(FaultInjector):
    """Overwrite ``fraction`` of the largest float state leaf with NaN
    at the first segment boundary at/after ``at_iteration``."""

    def __init__(self, at_iteration: int = 1, fraction: float = 0.05,
                 seed: int = 0, once: bool = True):
        self.at_iteration = at_iteration
        self.fraction = fraction
        self.once = once
        self._rng = np.random.default_rng(seed)
        self.fired = 0

    def perturb(self, it, state, checkpoint_state):
        if it < self.at_iteration or (self.once and self.fired):
            return None
        floats = _array_items(state, float_only=True)
        if not floats:
            return None
        key, _ = max(floats, key=lambda kv: kv[1].size)
        out = _copy_state(state)
        a = out[key].reshape(-1)
        k = max(1, int(a.size * self.fraction))
        idx = self._rng.choice(a.size, size=min(k, a.size), replace=False)
        a[idx] = np.nan
        self.fired += 1
        return out


class BitFlipFault(FaultInjector):
    """XOR bit 30 into ``n_flips`` random entries of the largest
    non-bool state leaf — a corrupted store, not a plausible value."""

    def __init__(self, at_iteration: int = 1, n_flips: int = 3,
                 seed: int = 0, once: bool = True):
        self.at_iteration = at_iteration
        self.n_flips = n_flips
        self.once = once
        self._rng = np.random.default_rng(seed)
        self.fired = 0

    def perturb(self, it, state, checkpoint_state):
        if it < self.at_iteration or (self.once and self.fired):
            return None
        arrays = _array_items(state)
        if not arrays:
            return None
        key, _ = max(arrays, key=lambda kv: kv[1].size)
        out = _copy_state(state)
        a = out[key].reshape(-1)
        idx = self._rng.choice(a.size, size=min(self.n_flips, a.size),
                               replace=False)
        bits = a[idx].view(np.uint32 if a.dtype.itemsize == 4
                           else np.uint64)
        a[idx] = (bits ^ np.array(1 << 30, bits.dtype)).view(a.dtype)
        self.fired += 1
        return out


class StaleUpdateFault(FaultInjector):
    """Revert ``fraction`` of the vertices to their last-checkpoint
    values across every per-vertex leaf — the DRFrlx dropped-update
    hazard.  The reverted values equal the checkpoint's, so boundary
    sentinels structurally cannot see this; only the convergence
    certificate (or an attractive fixpoint re-absorbing it) can."""

    def __init__(self, at_iteration: int = 1, fraction: float = 0.25,
                 seed: int = 0, once: bool = True):
        self.at_iteration = at_iteration
        self.fraction = fraction
        self.once = once
        self._rng = np.random.default_rng(seed)
        self.fired = 0

    def perturb(self, it, state, checkpoint_state):
        if it < self.at_iteration or (self.once and self.fired):
            return None
        dims = [np.asarray(v).shape[0] for v in state.values()
                if np.asarray(v).ndim >= 1]
        if not dims:
            return None
        v = max(dims)
        rows = self._rng.choice(v, size=max(1, int(v * self.fraction)),
                                replace=False)
        out = _copy_state(state)
        for k in out:
            cur, old = out[k], np.asarray(checkpoint_state[k])
            if cur.ndim >= 1 and cur.shape[0] == v:
                cur[rows] = old[rows]
        self.fired += 1
        return out


class RunnerExceptionFault(FaultInjector):
    """Raise :class:`InjectedFault` before the segment dispatch at/after
    ``at_iteration`` (``times=None`` keeps failing every segment)."""

    def __init__(self, at_iteration: int = 0, times: Optional[int] = 1):
        self.at_iteration = at_iteration
        self.times = times
        self.fired = 0

    def before_segment(self, it):
        if it < self.at_iteration:
            return
        if self.times is not None and self.fired >= self.times:
            return
        self.fired += 1
        raise InjectedFault(f"injected runner exception at iteration {it}")


class SparseOverflowFault(FaultInjector):
    """Force a one-edge sparse gather capacity: every sparse iteration
    overflows and must take the dense fallback — results must be
    unchanged (the overflow path is the first rung of the degradation
    story and predates this PR)."""
    knob_overrides = {"sparse_edge_capacity": 1}


class CompileFault(FaultInjector):
    """Fail the attempt's build step while the engine matches
    ``engine`` — recovery must degrade to a different engine."""

    def __init__(self, engine: str = "fused"):
        self.engine = engine
        self.fired = 0

    def on_compile(self, knobs):
        if knobs.get("engine") == self.engine:
            self.fired += 1
            raise InjectedFault(
                f"injected compile failure for engine={self.engine!r}")


class ProcessKillFault(FaultInjector):
    """Kill the process at/after ``at_iteration`` by raising
    :class:`SimulatedProcessDeath` — the retry net cannot catch it, so
    everything in memory (the :class:`~repro.core.resilience.
    CheckpointRing` included) is lost.  Only state already spilled
    through ``checkpoint_dir`` survives.

    ``point`` picks the worst moment: ``"segment_start"`` dies before a
    dispatch (the previous boundary is safely on disk — resume replays
    nothing), ``"after_segment"`` dies after a segment executed but
    *before* its boundary checkpoint was persisted — that segment's
    work is genuinely lost and must be replayed on resume (the chaos
    benchmark's lost-work measurement)."""

    def __init__(self, at_iteration: int = 1, times: Optional[int] = 1,
                 point: str = "segment_start"):
        if point not in ("segment_start", "after_segment"):
            raise ValueError(f"unknown kill point {point!r}")
        self.at_iteration = at_iteration
        self.times = times
        self.point = point
        self.fired = 0

    def _maybe_kill(self, it):
        if it < self.at_iteration:
            return
        if self.times is not None and self.fired >= self.times:
            return
        self.fired += 1
        raise SimulatedProcessDeath(
            f"simulated process death at iteration {it}")

    def before_segment(self, it):
        if self.point == "segment_start":
            self._maybe_kill(it)

    def perturb(self, it, state, checkpoint_state):
        if self.point == "after_segment":
            self._maybe_kill(it)
        return None


# ----------------------------------------------------------------------
# gateway-side (continuous-batching slice) injectors


class SliceFaultInjector(FaultInjector):
    """Marker base for injectors targeting gateway slices."""


class SliceExceptionFault(SliceFaultInjector):
    """Fail every slice dispatch whose roster contains ``ticket_id``
    (including the solo isolation retry — the slot can only be
    quarantined).  With ``ticket_id=None``, fail the first ``times``
    slice dispatches outright."""

    def __init__(self, ticket_id: Optional[str] = None,
                 times: Optional[int] = None):
        self.ticket_id = ticket_id
        self.times = times
        self.fired = 0

    def before_slice(self, ticket_ids: List[str]):
        if self.ticket_id is not None and self.ticket_id not in ticket_ids:
            return
        if self.times is not None and self.fired >= self.times:
            return
        self.fired += 1
        raise InjectedFault(
            f"injected slice failure (tickets={ticket_ids})")


class SliceNaNFault(SliceFaultInjector):
    """Corrupt one ticket's unpacked state with NaN after a slice —
    the per-slot sentinel check must quarantine exactly that slot."""

    def __init__(self, ticket_id: str, once: bool = True):
        self.ticket_id = ticket_id
        self.once = once
        self.fired = 0

    def perturb_slot(self, ticket_id, state):
        if ticket_id != self.ticket_id or (self.once and self.fired):
            return None
        floats = _array_items(state, float_only=True)
        if not floats:
            return None
        key, _ = max(floats, key=lambda kv: kv[1].size)
        out = _copy_state(state)
        out[key].reshape(-1)[:1] = np.nan
        self.fired += 1
        return out


class GatewayKillFault(SliceFaultInjector):
    """Kill the gateway process before its ``n``-th slice dispatch
    (counting across all lanes) via :class:`SimulatedProcessDeath`.
    Every in-flight roster, parked slot and queue entry dies with it —
    recovery must come entirely from the write-ahead journal and the
    per-ticket checkpoint stores."""

    def __init__(self, after_slices: int = 2, times: Optional[int] = 1):
        self.after_slices = after_slices
        self.times = times
        self.fired = 0
        self._slices = 0

    def before_slice(self, ticket_ids: List[str]):
        self._slices += 1
        if self._slices <= self.after_slices:
            return
        if self.times is not None and self.fired >= self.times:
            return
        self.fired += 1
        raise SimulatedProcessDeath(
            f"simulated gateway death before slice {self._slices} "
            f"(tickets={ticket_ids})")


#: mode name -> injector factory (the fault-matrix test iterates this)
FAULT_MODES = {
    "nan": NaNFault,
    "bitflip": BitFlipFault,
    "stale": StaleUpdateFault,
    "exception": RunnerExceptionFault,
    "overflow": SparseOverflowFault,
    "compile": CompileFault,
}


def make_fault(mode: str, **kwargs) -> FaultInjector:
    """Instantiate one of :data:`FAULT_MODES` by name."""
    if mode not in FAULT_MODES:
        raise ValueError(f"unknown fault mode {mode!r}; "
                         f"expected one of {sorted(FAULT_MODES)}")
    return FAULT_MODES[mode](**kwargs)
