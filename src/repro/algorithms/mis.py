"""Maximal Independent Set (MIS, Luby) — Table III: static, symmetric
control, symmetric information.  Two edge phases per round: (a) min active
neighbor priority, (b) broadcast of freshly selected vertices.
Status: 0 = undecided, 1 = in MIS, 2 = removed.

The undecided set is the frontier; ``phase_min``'s ``spred`` restricts
sources to it, so the min-priority reduce is ``gatherable`` and the
shrinking tail runs sparse under dynamic configs (one direction choice
per round, recorded under the trace keys; the mark broadcast follows
the same direction densely — its sources are the freshly selected
vertices, a different mask, so it must not reuse the gather).

``state_pad`` marks padding rows "removed" (2): convergence is
``no vertex undecided``, and the packer's default zero fill would have
left padding rows undecided — a batched MIS would never converge.
``randomized=True`` + the per-graph default key fix the old shared
``jax.random.key(0)`` fallback that correlated priorities across batch
members.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms._random import graph_key
from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       MAX, MIN, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["mis"]


def mis(max_iters: int = 256) -> VertexProgram:
    phase_min = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["priority"][src],
        spred=lambda st, src: st["status"][src] == 0,
        tpred=lambda st, dst: st["status"][dst] == 0,
        frontier=lambda st: st["status"] == 0,
        gatherable=True,  # spred == frontier membership
    )
    phase_mark = EdgePhase(
        monoid=MAX,
        vprop=lambda st, src, w: jnp.ones_like(src, jnp.float32),
        spred=lambda st, src: st["status"][src] == 1,
        tpred=lambda st, dst: st["status"][dst] == 0,
        frontier=lambda st: st["status"] == 1,
    )

    def init(graph, key=None):
        key = key if key is not None else graph_key(graph, salt=0)
        v = graph.n_nodes
        # unique priorities -> deterministic, tie-free selection
        priority = jax.random.permutation(key, v).astype(jnp.float32)
        return {"status": jnp.zeros((v,), jnp.int32), "priority": priority,
                FRONTIER_DIR_KEY: jnp.asarray(False),
                FRONTIER_OCC_KEY: dense_occupancy()}

    def step(ctx, st, it):
        pull = ctx.choose_direction(phase_min.frontier(st),
                                    st[FRONTIER_DIR_KEY])
        min_nbr, occ = ctx.propagate_sparse(st, phase_min, pull)
        select = (st["status"] == 0) & (st["priority"] < min_nbr)
        st1 = {**st, "status": jnp.where(select, 1, st["status"])}
        marked = ctx.propagate_dynamic(st1, phase_mark, pull)
        status = jnp.where((st1["status"] == 0) & (marked > 0), 2,
                           st1["status"])
        return {**st1, "status": status, FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ}

    def converged(prev, cur):
        return ~jnp.any(cur["status"] == 0)

    # Certificate: the defining MIS properties, checked with one dense
    # O(E) max-reduce marking vertices that have an in-MIS neighbour —
    # independence (no member has one), maximality (every removed
    # vertex has one) and completeness (nothing undecided).
    cert_phase = EdgePhase(
        monoid=MAX,
        vprop=lambda st, src, w: jnp.ones_like(src, jnp.float32),
        spred=lambda st, src: st["status"][src] == 1,
    )

    def certificate(ctx, st):
        s = st["status"]
        nbr_in_mis = ctx.propagate(st, cert_phase) > 0
        independent = ~jnp.any((s == 1) & nbr_in_mis)
        maximal = jnp.all(jnp.where(s == 2, nbr_in_mis, True))
        decided = ~jnp.any(s == 0)
        valid = jnp.all((s >= 0) & (s <= 2))
        return independent & maximal & decided & valid

    return VertexProgram(
        name="MIS", init=init, step=step, converged=converged,
        extract=lambda st: st["status"] == 1, weighted=False,
        max_iters=max_iters,
        frontier_init=lambda g: jnp.ones((g.n_nodes,), bool),
        frontier_update=lambda st: st["status"] == 0,
        state_pad={"status": 2},
        randomized=True,
        # Luby rounds only ever decide vertices; decided statuses and
        # the drawn priorities are immutable
        sentinels={
            "status_frozen": lambda p, c: jnp.all(jnp.where(
                p["status"] != 0, c["status"] == p["status"], True)),
            "status_range": lambda p, c: jnp.all(
                (c["status"] >= 0) & (c["status"] <= 2)),
            "priority_frozen": lambda p, c: jnp.all(
                c["priority"] == p["priority"]),
        },
        certificate=certificate,
    )
