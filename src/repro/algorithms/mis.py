"""Maximal Independent Set (MIS, Luby) — Table III: static, symmetric
control, symmetric information.  Two edge phases per round: (a) min active
neighbor priority, (b) broadcast of freshly selected vertices.
Status: 0 = undecided, 1 = in MIS, 2 = removed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vertex_program import MAX, MIN, EdgePhase, VertexProgram

__all__ = ["mis"]


def mis(max_iters: int = 256) -> VertexProgram:
    phase_min = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["priority"][src],
        spred=lambda st, src: st["status"][src] == 0,
        tpred=lambda st, dst: st["status"][dst] == 0,
    )
    phase_mark = EdgePhase(
        monoid=MAX,
        vprop=lambda st, src, w: jnp.ones_like(src, jnp.float32),
        spred=lambda st, src: st["status"][src] == 1,
        tpred=lambda st, dst: st["status"][dst] == 0,
    )

    def init(graph, key=None):
        key = key if key is not None else jax.random.key(0)
        v = graph.n_nodes
        # unique priorities -> deterministic, tie-free selection
        priority = jax.random.permutation(key, v).astype(jnp.float32)
        return {"status": jnp.zeros((v,), jnp.int32), "priority": priority}

    def step(ctx, st, it):
        min_nbr = ctx.propagate(st, phase_min)
        select = (st["status"] == 0) & (st["priority"] < min_nbr)
        st1 = {**st, "status": jnp.where(select, 1, st["status"])}
        marked = ctx.propagate(st1, phase_mark)
        status = jnp.where((st1["status"] == 0) & (marked > 0), 2,
                           st1["status"])
        return {**st1, "status": status}

    def converged(prev, cur):
        return ~jnp.any(cur["status"] == 0)

    return VertexProgram(
        name="MIS", init=init, step=step, converged=converged,
        extract=lambda st: st["status"] == 1, weighted=False,
        max_iters=max_iters,
    )
