"""Connected Components (CC) — dynamic traversal (Table III: '-').

Adapted from the ECL-CC style of Jaiganesh & Burtscher [26]: per round,
(1) *hooking* — a min-label reduce over graph edges, alternating push/pull
direction per round (the paper's "non-deterministic source/target
direction"), and (2) *pointer jumping* — label[v] <- label[label[v]],
which chases transitive edges that are NOT in the input graph: the
data-dependent, dynamic traversal that precludes a static push/pull choice.

The alternating direction goes through ``ctx.dynamic_direction`` and is
recorded under ``FRONTIER_DIR_KEY`` — the old code passed
``direction=PUSH/PULL`` straight to ``ctx.propagate``, bypassing the
trace, so ``RunResult.direction_trace`` (and fig5's D*-cell direction
reporting) was silently empty for CC.  Static configs still fold the
wish to their fixed direction (the trace reports what actually ran).

Labels are *local* vertex ids; pointer jumping indexes the label array
with them, so under ``run_batch`` the packed row of a label is
``label + vertex_offset``.  ``ctx.vertex_offsets()`` supplies the shift
(a constant 0 sequentially) — without it, batched jumping would chase
graph i's labels through graph 0's rows.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       MIN, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["cc"]

_JUMPS_PER_ROUND = 2


def cc(max_iters: int = 512) -> VertexProgram:
    phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["label"][src],
    )

    def init(graph, key=None):
        v = graph.n_nodes
        return {"label": jnp.arange(v, dtype=jnp.int32),
                FRONTIER_DIR_KEY: jnp.asarray(False),
                FRONTIER_OCC_KEY: dense_occupancy()}

    def step(ctx, st, it):
        # hooking: racy min-label updates; direction alternates per round
        pull = ctx.dynamic_direction((it % 2) == 1)
        nbr_min, occ = ctx.propagate_sparse(st, phase, pull,
                                            dtype=jnp.int32)
        label = jnp.minimum(st["label"], nbr_min)
        # pointer jumping over transitive (dynamic) edges; labels are
        # local ids — shift to packed rows when batched
        off = ctx.vertex_offsets()
        for _ in range(_JUMPS_PER_ROUND):
            label = label[label + off]
        return {**st, "label": label, FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ}

    def converged(prev, cur):
        return jnp.all(prev["label"] == cur["label"])

    # Certificate: labels are valid component ids iff (a) every label is
    # in [0, own id] (hooking only ever takes minima of initial ids),
    # (b) the label array is pointer-jumping-stable (label[label] ==
    # label), and (c) both endpoints of every edge agree (the min-label
    # reduce over the edge set returns each labelled vertex's own
    # label).  A lost hook or corrupted label breaks (b) or (c).
    def certificate(ctx, st):
        lab = st["label"]
        v = lab.shape[0]
        nbr = ctx.propagate(st, phase, dtype=jnp.int32)
        has_nbr = nbr < jnp.iinfo(jnp.int32).max
        in_range = jnp.all((lab >= 0) & (lab <= jnp.arange(v)))
        at = jnp.clip(lab, 0, v - 1)  # safe gather even when corrupted
        root_fixed = jnp.all(lab[at] == lab)
        edges_agree = jnp.all(jnp.where(has_nbr, nbr == lab, True))
        return in_range & root_fixed & edges_agree

    return VertexProgram(
        name="CC", init=init, step=step, converged=converged,
        extract=lambda st: st["label"], weighted=False, max_iters=max_iters,
        frontier_init=lambda g: jnp.ones((g.n_nodes,), bool),
        frontier_update=lambda st: jnp.ones_like(st["label"], bool),
        monotone={"label": "non_increasing"},
        sentinels={"label_range": lambda p, c: jnp.all(
            (c["label"] >= 0)
            & (c["label"] <= jnp.arange(c["label"].shape[0])))},
        certificate=certificate,
    )
