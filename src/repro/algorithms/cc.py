"""Connected Components (CC) — dynamic traversal (Table III: '-').

Adapted from the ECL-CC style of Jaiganesh & Burtscher [26]: per round,
(1) *hooking* — a min-label reduce over graph edges, alternating push/pull
direction per round (the paper's "non-deterministic source/target
direction"), and (2) *pointer jumping* — label[v] <- label[label[v]],
which chases transitive edges that are NOT in the input graph: the
data-dependent, dynamic traversal that precludes a static push/pull choice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config_space import UpdateProp
from repro.core.vertex_program import MIN, EdgePhase, VertexProgram

__all__ = ["cc"]

_JUMPS_PER_ROUND = 2


def cc(max_iters: int = 512) -> VertexProgram:
    phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["label"][src],
    )

    def init(graph, key=None):
        v = graph.n_nodes
        return {"label": jnp.arange(v, dtype=jnp.int32)}

    def step(ctx, st, it):
        # hooking: racy min-label updates; direction alternates per round
        # (lax.cond executes exactly one branch at runtime)
        nbr_min = jax.lax.cond(
            it % 2 == 0,
            lambda s: ctx.propagate(s, phase, direction=UpdateProp.PUSH,
                                    dtype=jnp.int32),
            lambda s: ctx.propagate(s, phase, direction=UpdateProp.PULL,
                                    dtype=jnp.int32),
            st)
        label = jnp.minimum(st["label"], nbr_min)
        # pointer jumping over transitive (dynamic) edges
        for _ in range(_JUMPS_PER_ROUND):
            label = label[label]
        return {"label": label}

    def converged(prev, cur):
        return jnp.all(prev["label"] == cur["label"])

    return VertexProgram(
        name="CC", init=init, step=step, converged=converged,
        extract=lambda st: st["label"], weighted=False, max_iters=max_iters,
    )
