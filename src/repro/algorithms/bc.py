"""Betweenness Centrality (BC, Brandes, single root) — Table III: static,
source control, symmetric information.

Two stages inside one uniform step (lax.cond): forward BFS accumulating
shortest-path counts sigma, then backward level-by-level dependency
accumulation  delta[v] = sigma[v] * sum_{w in succ(v)} (1+delta[w])/sigma[w].
The backward reduce runs over the (symmetric) edge set with exact level
predicates on both endpoints.

Both stages are frontier phases: forward's frontier is the current BFS
level (with the unvisited set feeding the alpha test), backward's is the
level being drained.  Dynamic configs therefore direction-optimize both
sweeps; static configs constant-fold the choice.

Batch-ready layout: ``cur_level``/``phase`` are per-graph scalars
(``[B]`` when batched), so the phases compare depths against the
per-vertex broadcast ``st["lvl"] = ctx.per_vertex(cur_level)`` that
``step`` injects, and the forward/backward split goes through
``ctx.cond_per_graph`` (sequentially a ``lax.cond``; batched, graphs
flip phases at different iterations, so both branches execute and each
graph's rows keep their own).  Padding depth rows are ``state_pad``-ed
to -2 — never equal to any level and never "unvisited" (-1), so padding
neither joins frontiers nor inflates the alpha test's unexplored count.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       SUM, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["bc"]


def bc(root: int = 0, max_iters: int = 4096) -> VertexProgram:
    # phases read the per-vertex level broadcast st["lvl"] (injected by
    # step), not the per-graph scalar cur_level: [B]-shaped scalars
    # cannot compare against [B*n_q] depth rows directly
    fwd = EdgePhase(
        monoid=SUM,
        vprop=lambda st, src, w: st["sigma"][src],
        spred=lambda st, src: st["depth"][src] == st["lvl"][src],
        tpred=lambda st, dst: st["depth"][dst] == -1,
        frontier=lambda st: st["depth"] == st["lvl"],
        gatherable=True,  # spred == frontier membership
    )
    bwd = EdgePhase(
        monoid=SUM,
        vprop=lambda st, src, w: (1.0 + st["delta"][src])
        / jnp.maximum(st["sigma"][src], 1e-30),
        spred=lambda st, src: st["depth"][src] == st["lvl"][src] + 1,
        tpred=lambda st, dst: st["depth"][dst] == st["lvl"][dst],
        frontier=lambda st: st["depth"] == st["lvl"] + 1,
        gatherable=True,  # spred == frontier membership
    )

    def init(graph, key=None):
        v = graph.n_nodes
        return {
            "depth": jnp.full((v,), -1, jnp.int32).at[root].set(0),
            "sigma": jnp.zeros((v,), jnp.float32).at[root].set(1.0),
            "delta": jnp.zeros((v,), jnp.float32),
            "cur_level": jnp.int32(0),
            "phase": jnp.int32(0),  # 0 = forward, 1 = backward
            FRONTIER_DIR_KEY: jnp.asarray(False),
            FRONTIER_OCC_KEY: dense_occupancy(),
        }

    def step(ctx, st, it):
        def forward(st):
            pull = ctx.choose_direction(fwd.frontier(st),
                                        st[FRONTIER_DIR_KEY],
                                        unvisited=st["depth"] == -1)
            contrib, occ = ctx.propagate_sparse(st, fwd, pull)
            newly = (st["depth"] == -1) & (contrib > 0)
            depth = jnp.where(newly, st["lvl"] + 1, st["depth"])
            sigma = jnp.where(newly, contrib, st["sigma"])
            any_new = ctx.per_graph_any(newly)
            # forward done -> deepest level is cur_level; backward starts
            # one above the deepest (its delta is identically zero).
            return {
                **st, "depth": depth, "sigma": sigma,
                "phase": jnp.where(any_new, 0, 1).astype(jnp.int32),
                "cur_level": jnp.where(any_new, st["cur_level"] + 1,
                                       st["cur_level"] - 1).astype(jnp.int32),
                FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ,
            }

        def backward(st):
            pull = ctx.choose_direction(bwd.frontier(st),
                                        st[FRONTIER_DIR_KEY])
            red, occ = ctx.propagate_sparse(st, bwd, pull)
            hit = st["depth"] == st["lvl"]
            delta = jnp.where(hit, st["sigma"] * red, st["delta"])
            return {**st, "delta": delta,
                    "cur_level": (st["cur_level"] - 1).astype(jnp.int32),
                    FRONTIER_DIR_KEY: pull,
                    FRONTIER_OCC_KEY: occ}

        st = {**st, "lvl": ctx.per_vertex(st["cur_level"])}
        out = ctx.cond_per_graph(st["phase"] == 0, forward, backward, st)
        out.pop("lvl")
        return out

    def converged(prev, cur):
        return (cur["phase"] == 1) & (cur["cur_level"] < 0)

    def extract(st):
        # dependency scores; the root's own value is excluded by convention
        return st["delta"].at[root].set(0.0)

    return VertexProgram(
        name="BC", init=init, step=step, converged=converged,
        extract=extract, weighted=False, max_iters=max_iters,
        frontier_init=lambda g: jnp.zeros((g.n_nodes,), bool)
        .at[root].set(True),
        frontier_update=lambda st: st["depth"] == st["cur_level"],
        # padding depth must equal no level and never read "unvisited"
        state_pad={"depth": -2},
    )
