"""Graph Coloring (CLR, Jones-Plassmann) — Table III: static, symmetric
control, *target* information (the pull form hoists the target's
forbidden-color bookkeeping out of the inner loop).
Round r: every uncolored vertex whose priority beats every uncolored
neighbor takes color r.

The uncolored set is a real, shrinking frontier: ``spred`` restricts
contributing sources to exactly the uncolored mask, so the phase is
``gatherable`` — dynamic configs start pull on the saturated frontier
and hand the shrinking tail to sparse-gathered push iterations, with
direction and occupancy recorded under the standard trace keys.

``init``'s default key is derived per graph (``graph_key``) and
``randomized=True`` tells ``run_batch`` to fold the batch index into
per-graph keys — the old shared ``jax.random.key(1)`` default gave
every batch member identical priorities, correlating their tie-breaks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms._random import graph_key
from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       MAX, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["coloring"]


def coloring(max_iters: int = 512) -> VertexProgram:
    phase = EdgePhase(
        monoid=MAX,
        vprop=lambda st, src, w: st["priority"][src],
        spred=lambda st, src: st["color"][src] < 0,
        tpred=lambda st, dst: st["color"][dst] < 0,
        frontier=lambda st: st["color"] < 0,
        gatherable=True,  # spred == frontier membership
    )

    def init(graph, key=None):
        key = key if key is not None else graph_key(graph, salt=1)
        v = graph.n_nodes
        priority = jax.random.permutation(key, v).astype(jnp.float32)
        return {"color": jnp.full((v,), -1, jnp.int32),
                "priority": priority,
                FRONTIER_DIR_KEY: jnp.asarray(False),
                FRONTIER_OCC_KEY: dense_occupancy()}

    def step(ctx, st, it):
        pull = ctx.choose_direction(phase.frontier(st),
                                    st[FRONTIER_DIR_KEY])
        max_nbr, occ = ctx.propagate_sparse(st, phase, pull)
        # -inf when no uncolored neighbor
        win = (st["color"] < 0) & (st["priority"] > max_nbr)
        # per_vertex: `it` may be a per-graph [B] vector under the
        # continuous-batching slice runner — each vertex colors with its
        # own graph's round number (scalar broadcast sequentially)
        color = jnp.where(win, ctx.per_vertex(jnp.asarray(it, jnp.int32)),
                          st["color"])
        return {**st, "color": color, FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ}

    def converged(prev, cur):
        return jnp.all(cur["color"] >= 0)

    return VertexProgram(
        name="CLR", init=init, step=step, converged=converged,
        extract=lambda st: st["color"], weighted=False, max_iters=max_iters,
        frontier_init=lambda g: jnp.ones((g.n_nodes,), bool),
        frontier_update=lambda st: st["color"] < 0,
        randomized=True,
    )
