"""Graph Coloring (CLR, Jones-Plassmann) — Table III: static, symmetric
control, *target* information (the pull form hoists the target's
forbidden-color bookkeeping out of the inner loop).
Round r: every uncolored vertex whose priority beats every uncolored
neighbor takes color r.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vertex_program import MAX, EdgePhase, VertexProgram

__all__ = ["coloring"]


def coloring(max_iters: int = 512) -> VertexProgram:
    phase = EdgePhase(
        monoid=MAX,
        vprop=lambda st, src, w: st["priority"][src],
        spred=lambda st, src: st["color"][src] < 0,
        tpred=lambda st, dst: st["color"][dst] < 0,
    )

    def init(graph, key=None):
        key = key if key is not None else jax.random.key(1)
        v = graph.n_nodes
        priority = jax.random.permutation(key, v).astype(jnp.float32)
        return {"color": jnp.full((v,), -1, jnp.int32), "priority": priority}

    def step(ctx, st, it):
        max_nbr = ctx.propagate(st, phase)  # -inf when no uncolored nbr
        win = (st["color"] < 0) & (st["priority"] > max_nbr)
        color = jnp.where(win, it, st["color"])
        return {**st, "color": color}

    def converged(prev, cur):
        return jnp.all(cur["color"] >= 0)

    return VertexProgram(
        name="CLR", init=init, step=step, converged=converged,
        extract=lambda st: st["color"], weighted=False, max_iters=max_iters,
    )
