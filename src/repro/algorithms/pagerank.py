"""PageRank (PR) — Table III: static traversal, symmetric control,
source information (rank/out-degree are source-side loads push can hoist).
Topology-driven: every vertex active every iteration (trivial
predicates), so the frontier protocol runs with a dense all-ones mask —
the direction heuristic sees a saturated frontier and dynamic configs
settle on pull, and the per-iteration direction lands in
``RunResult.direction_trace`` like every other app.

Normalization is deliberately *stateful*: ``inv_v`` carries ``1/V`` of
the graph the program was initialised on as a per-graph scalar
(``[B]`` under ``run_batch``), so the teleport and dangling terms
never read the context's vertex count.  Reading ``ctx.n_nodes`` here —
the old code — normalized by the *packed* vertex count, padding
included: every batched rank was silently scaled down.  The scalar is
aligned against vertex arrays via ``ctx.align_per_graph``, which is
the identity sequentially: the rank update stays in the scalar*vector
HLO shape that rounds identically under the host and fused engines
(materializing ``1/V`` as a ``[V]`` operand makes the fma contraction
of ``(1-d)*inv_v + d*(...)`` diverge between the two compilations).
Padding rows are masked to exactly 0 through ``active`` (packed
``False``), so batched PR normalizes by each graph's *true* V,
padding stays inert, and unbatching recovers the sequential result.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       SUM, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["pagerank"]


def pagerank(damping: float = 0.85, tol: float = 1e-6,
             max_iters: int = 256) -> VertexProgram:
    phase = EdgePhase(
        monoid=SUM,
        vprop=lambda st, src, w: st["rank"][src] * st["inv_out"][src],
        frontier=lambda st: st["active"],
        # every source contributes every iteration — the frontier only
        # steers the direction heuristic, so the sparse gather is unsound
        gatherable=False,
    )

    def init(graph, key=None):
        v = graph.n_nodes
        out_deg = jnp.asarray(graph.out_degree)
        return {
            "rank": jnp.full((v,), 1.0 / v, jnp.float32),
            "inv_out": (1.0 / jnp.maximum(out_deg, 1)).astype(jnp.float32),
            "dangling": (out_deg == 0),
            "inv_v": jnp.float32(1.0 / v),
            "active": jnp.ones((v,), bool),
            FRONTIER_DIR_KEY: jnp.asarray(False),
            FRONTIER_OCC_KEY: dense_occupancy(),
        }

    def step(ctx, st, it):
        pull = ctx.choose_direction(st["active"], st[FRONTIER_DIR_KEY])
        reduced, occ = ctx.propagate_sparse(st, phase, pull)
        inv_v = ctx.align_per_graph(st["inv_v"])
        dangling_mass = ctx.align_per_graph(
            ctx.per_graph_sum(jnp.where(st["dangling"], st["rank"], 0.0)))
        rank = jnp.where(
            st["active"],
            (1.0 - damping) * inv_v
            + damping * (reduced + dangling_mass * inv_v),
            0.0)
        return {**st, "rank": rank, FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ}

    def converged(prev, cur):
        return jnp.sum(jnp.abs(prev["rank"] - cur["rank"])) < tol

    return VertexProgram(
        name="PR", init=init, step=step, converged=converged,
        extract=lambda st: st["rank"], weighted=False, max_iters=max_iters,
        frontier_init=lambda g: jnp.ones((g.n_nodes,), bool),
        frontier_update=lambda st: st["active"],
        # total mass is conserved at 1, so no rank can exceed it; a
        # corrupted rank/inv_out explodes past the bound within one
        # iteration.  No certificate: the damped iteration is an
        # attractive fixpoint, so the convergence residual itself is
        # the proof (perturbations are re-absorbed, not frozen in).
        sentinels={"rank_range": lambda p, c: jnp.all(
            (c["rank"] >= 0.0) & (c["rank"] <= 1.0 + 1e-3))},
    )
