"""PageRank (PR) — Table III: static traversal, symmetric control,
source information (rank/out-degree are source-side loads push can hoist).
Topology-driven: every vertex active every iteration (trivial predicates).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import SUM, EdgePhase, VertexProgram

__all__ = ["pagerank"]


def pagerank(damping: float = 0.85, tol: float = 1e-6,
             max_iters: int = 256) -> VertexProgram:
    phase = EdgePhase(
        monoid=SUM,
        vprop=lambda st, src, w: st["rank"][src] * st["inv_out"][src],
    )

    def init(graph, key=None):
        v = graph.n_nodes
        out_deg = jnp.asarray(graph.out_degree)
        return {
            "rank": jnp.full((v,), 1.0 / v, jnp.float32),
            "inv_out": (1.0 / jnp.maximum(out_deg, 1)).astype(jnp.float32),
            "dangling": (out_deg == 0),
        }

    def step(ctx, st, it):
        v = ctx.n_nodes
        reduced = ctx.propagate(st, phase)
        dangling_mass = jnp.sum(jnp.where(st["dangling"], st["rank"], 0.0))
        rank = (1.0 - damping) / v + damping * (reduced + dangling_mass / v)
        return {**st, "rank": rank}

    def converged(prev, cur):
        return jnp.sum(jnp.abs(prev["rank"] - cur["rank"])) < tol

    return VertexProgram(
        name="PR", init=init, step=step, converged=converged,
        extract=lambda st: st["rank"], weighted=False, max_iters=max_iters,
    )
