"""Breadth-First Search — the canonical direction-optimizing traversal.

Level-synchronous BFS with the full frontier protocol: each iteration the
frontier (vertices discovered last level) and the unvisited set feed
``EdgeContext.choose_direction`` — push (source-outer scatter from the
frontier) while the frontier is sparse, pull (target-outer scan of
undiscovered vertices) once the frontier's out-edges outnumber the
unexplored region's (Beamer's alpha test), and back to push for the
shrinking tail (beta test).  Under static configs the flag constant-folds
to the config's direction, so one program covers all 12 cells.

Sparse push iterations go through ``ctx.propagate_sparse``: when the
frontier's gathered edge list fits the context's static capacity, the
reduction runs over exactly those O(m_f) edges instead of scanning all E
under a mask; the per-iteration occupancy lands in the state under
``FRONTIER_OCC_KEY`` (-1 marks a dense iteration).

Depths use int32 with -1 for "unvisited"; the MIN monoid over
``depth[src] + 1`` makes the reduction direction-agnostic (the edge set
is symmetric and both orders carry the same predicates).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       MIN, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["bfs"]

_UNSEEN = -1


def bfs(source: int = 0, max_iters: int = 4096) -> VertexProgram:
    phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["depth"][src] + 1,
        spred=lambda st, src: st["active"][src],          # frontier only
        tpred=lambda st, dst: st["depth"][dst] == _UNSEEN,
        frontier=lambda st: st["active"],
        gatherable=True,  # spred == frontier membership
    )

    def init(graph, key=None):
        v = graph.n_nodes
        depth = jnp.full((v,), _UNSEEN, jnp.int32).at[source].set(0)
        active = jnp.zeros((v,), bool).at[source].set(True)
        return {"depth": depth, "active": active,
                FRONTIER_DIR_KEY: jnp.asarray(False),
                FRONTIER_OCC_KEY: dense_occupancy()}

    def step(ctx, st, it):
        unvisited = st["depth"] == _UNSEEN
        pull = ctx.choose_direction(phase.frontier(st), st[FRONTIER_DIR_KEY],
                                    unvisited=unvisited)
        cand, occ = ctx.propagate_sparse(st, phase, pull, dtype=jnp.int32)
        newly = unvisited & (cand < jnp.iinfo(jnp.int32).max)
        depth = jnp.where(newly, cand, st["depth"]).astype(jnp.int32)
        return {"depth": depth, "active": newly, FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ}

    def converged(prev, cur):
        return ~jnp.any(cur["active"])

    # Resilience protocol.  Depths are not raw-monotone (-1 -> level), so
    # instead of a monotone decl BFS pins the two invariants the level-
    # synchronous traversal does maintain between checkpoints: visited
    # depths never change, and every depth is -1 or a valid level.
    sentinels = {
        "depth_frozen": lambda p, c: jnp.all(jnp.where(
            p["depth"] != _UNSEEN, c["depth"] == p["depth"], True)),
        "depth_range": lambda p, c: jnp.all(
            (c["depth"] == _UNSEEN)
            | ((c["depth"] >= 0) & (c["depth"] < c["depth"].shape[0]))),
    }

    # Certificate: one dense O(E) relaxation from the visited set.  At a
    # true BFS fixpoint every reached vertex's depth equals
    # min(depth[parent]) + 1 and every vertex with a visited neighbour
    # is itself visited — a dropped update (vertex reverted to unseen)
    # or an inflated/deflated depth cannot satisfy both.
    cert_phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["depth"][src] + 1,
        spred=lambda st, src: st["depth"][src] != _UNSEEN,
    )

    def certificate(ctx, st):
        d = st["depth"]
        cand = ctx.propagate(st, cert_phase, dtype=jnp.int32)
        reach = cand < jnp.iinfo(jnp.int32).max
        is_src = jnp.arange(d.shape[0]) == source
        ok_reached = jnp.where(reach, (d == cand) | is_src, True)
        ok_unreached = jnp.where(reach, True, (d == _UNSEEN) | is_src)
        return jnp.all(ok_reached & ok_unreached) & ~jnp.any(st["active"])

    return VertexProgram(
        name="BFS", init=init, step=step, converged=converged,
        extract=lambda st: st["depth"], weighted=False, max_iters=max_iters,
        frontier_init=lambda g: jnp.zeros((g.n_nodes,), bool)
        .at[source].set(True),
        frontier_update=lambda st: st["active"],
        sentinels=sentinels,
        certificate=certificate,
    )
