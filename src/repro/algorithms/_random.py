"""Default-key derivation for programs with randomized init.

``coloring``/``mis`` draw random priorities in ``init``.  Their old
default fallback (``jax.random.key(const)``) handed *every* graph the
same key, correlating tie-breaks across supposedly independent graphs —
in a batch, every bucket member selected the same vertex ranks.
``graph_key`` folds a stable per-graph datum (the graph's exact size)
into a salted base key so two different graphs draw different
priorities by default; ``run_batch`` goes further and folds the batch
index in (see :func:`repro.core.executor.run_batch`), decorrelating
even same-shape graphs.
"""
from __future__ import annotations

import jax

__all__ = ["graph_key"]


def graph_key(graph, salt: int) -> jax.Array:
    """Stable default PRNG key for one graph: fold its (n, m) identity
    into a per-algorithm salted base key."""
    datum = (int(graph.n_nodes) * 1000003 + int(graph.n_edges)) % (2 ** 31)
    return jax.random.fold_in(jax.random.key(salt), datum)
