"""The paper's six applications (Sec. V-B) as VertexPrograms, plus BFS —
the canonical direction-optimizing traversal exercising the dynamic
("D") configs' per-iteration push/pull switch."""
from repro.algorithms.bc import bc
from repro.algorithms.bfs import bfs
from repro.algorithms.cc import cc
from repro.algorithms.coloring import coloring
from repro.algorithms.mis import mis
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp

#: name -> zero-arg factory with paper-default parameters
REGISTRY = {
    "PR": pagerank,
    "SSSP": sssp,
    "MIS": mis,
    "CLR": coloring,
    "BC": bc,
    "CC": cc,
    "BFS": bfs,
}

__all__ = ["pagerank", "sssp", "mis", "coloring", "bc", "cc", "bfs",
           "REGISTRY"]
