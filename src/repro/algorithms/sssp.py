"""Single-Source Shortest Path (SSSP) — Table III: static, source control
(push elides all non-frontier sources in the outer loop), source info.
Frontier-based Bellman-Ford relaxation with a min monoid.

The frontier (vertices whose distance improved last iteration) drives the
dynamic configs' per-iteration direction: no monotone "unvisited" set
exists (re-relaxations can reactivate settled vertices), so the push->pull
trigger is the frontier-edge-density fallback of
:func:`repro.core.frontier.choose_direction`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       MIN, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["sssp"]


def sssp(source: int = 0, max_iters: int = 4096) -> VertexProgram:
    phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["dist"][src] + w,
        spred=lambda st, src: st["active"][src],  # frontier only
        frontier=lambda st: st["active"],
        gatherable=True,  # spred == frontier membership
    )

    def init(graph, key=None):
        v = graph.n_nodes
        dist = jnp.full((v,), jnp.inf, jnp.float32).at[source].set(0.0)
        active = jnp.zeros((v,), bool).at[source].set(True)
        return {"dist": dist, "active": active,
                FRONTIER_DIR_KEY: jnp.asarray(False),
                FRONTIER_OCC_KEY: dense_occupancy()}

    def step(ctx, st, it):
        pull = ctx.choose_direction(phase.frontier(st), st[FRONTIER_DIR_KEY])
        cand, occ = ctx.propagate_sparse(st, phase, pull)
        dist = jnp.minimum(st["dist"], cand)
        active = dist < st["dist"]
        return {"dist": dist, "active": active, FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ}

    def converged(prev, cur):
        return ~jnp.any(cur["active"])

    # Certificate: one dense O(E) relaxation over all finite-distance
    # sources.  At a Bellman-Ford fixpoint every reached non-source
    # vertex's distance equals min(dist[u] + w) exactly (each candidate
    # is the same single f32 add the run performed, and MIN is an exact
    # reduction, so the equality is bitwise); an unreached vertex with a
    # reached neighbour, or a distance above/below the relaxation bound,
    # fails the proof.
    cert_phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["dist"][src] + w,
        spred=lambda st, src: jnp.isfinite(st["dist"][src]),
    )

    def certificate(ctx, st):
        d = st["dist"]
        cand = ctx.propagate(st, cert_phase)
        reach = jnp.isfinite(cand)
        is_src = jnp.arange(d.shape[0]) == source
        ok = jnp.where(reach, (d == cand) | is_src, jnp.isinf(d) | is_src)
        return jnp.all(ok) & ~jnp.any(st["active"])

    return VertexProgram(
        name="SSSP", init=init, step=step, converged=converged,
        extract=lambda st: st["dist"], weighted=True, max_iters=max_iters,
        frontier_init=lambda g: jnp.zeros((g.n_nodes,), bool)
        .at[source].set(True),
        frontier_update=lambda st: st["active"],
        # the MIN-monoid fixpoint only ever improves distances — the
        # exact reorderable-combine property DRFrlx relies on
        monotone={"dist": "non_increasing"},
        sentinels={"dist_nonnegative":
                   lambda p, c: jnp.all(c["dist"] >= 0.0)},
        certificate=certificate,
    )
