"""Single-Source Shortest Path (SSSP) — Table III: static, source control
(push elides all non-frontier sources in the outer loop), source info.
Frontier-based Bellman-Ford relaxation with a min monoid.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import MIN, EdgePhase, VertexProgram

__all__ = ["sssp"]


def sssp(source: int = 0, max_iters: int = 4096) -> VertexProgram:
    phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["dist"][src] + w,
        spred=lambda st, src: st["active"][src],  # frontier only
    )

    def init(graph, key=None):
        v = graph.n_nodes
        dist = jnp.full((v,), jnp.inf, jnp.float32).at[source].set(0.0)
        active = jnp.zeros((v,), bool).at[source].set(True)
        return {"dist": dist, "active": active}

    def step(ctx, st, it):
        cand = ctx.propagate(st, phase)
        dist = jnp.minimum(st["dist"], cand)
        active = dist < st["dist"]
        return {"dist": dist, "active": active}

    def converged(prev, cur):
        return ~jnp.any(cur["active"])

    return VertexProgram(
        name="SSSP", init=init, step=step, converged=converged,
        extract=lambda st: st["dist"], weighted=True, max_iters=max_iters,
    )
