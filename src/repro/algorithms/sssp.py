"""Single-Source Shortest Path (SSSP) — Table III: static, source control
(push elides all non-frontier sources in the outer loop), source info.
Frontier-based Bellman-Ford relaxation with a min monoid.

The frontier (vertices whose distance improved last iteration) drives the
dynamic configs' per-iteration direction: no monotone "unvisited" set
exists (re-relaxations can reactivate settled vertices), so the push->pull
trigger is the frontier-edge-density fallback of
:func:`repro.core.frontier.choose_direction`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       MIN, EdgePhase, VertexProgram,
                                       dense_occupancy)

__all__ = ["sssp"]


def sssp(source: int = 0, max_iters: int = 4096) -> VertexProgram:
    phase = EdgePhase(
        monoid=MIN,
        vprop=lambda st, src, w: st["dist"][src] + w,
        spred=lambda st, src: st["active"][src],  # frontier only
        frontier=lambda st: st["active"],
        gatherable=True,  # spred == frontier membership
    )

    def init(graph, key=None):
        v = graph.n_nodes
        dist = jnp.full((v,), jnp.inf, jnp.float32).at[source].set(0.0)
        active = jnp.zeros((v,), bool).at[source].set(True)
        return {"dist": dist, "active": active,
                FRONTIER_DIR_KEY: jnp.asarray(False),
                FRONTIER_OCC_KEY: dense_occupancy()}

    def step(ctx, st, it):
        pull = ctx.choose_direction(phase.frontier(st), st[FRONTIER_DIR_KEY])
        cand, occ = ctx.propagate_sparse(st, phase, pull)
        dist = jnp.minimum(st["dist"], cand)
        active = dist < st["dist"]
        return {"dist": dist, "active": active, FRONTIER_DIR_KEY: pull,
                FRONTIER_OCC_KEY: occ}

    def converged(prev, cur):
        return ~jnp.any(cur["active"])

    return VertexProgram(
        name="SSSP", init=init, step=step, converged=converged,
        extract=lambda st: st["dist"], weighted=True, max_iters=max_iters,
        frontier_init=lambda g: jnp.zeros((g.n_nodes,), bool)
        .at[source].set(True),
        frontier_update=lambda st: st["active"],
    )
