"""Pure-numpy oracles for the six applications (test-side ground truth)."""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph

__all__ = ["pagerank_np", "sssp_np", "cc_np", "bc_np", "bfs_np",
           "is_independent_set", "is_maximal_independent_set",
           "is_proper_coloring"]


def bfs_np(g: Graph, source=0):
    """Level-synchronous BFS depths; -1 for unreachable vertices."""
    v = g.n_nodes
    row_ptr = np.asarray(g.row_ptr_out, np.int64)
    col = np.asarray(g.dst, np.int64)
    depth = np.full(v, -1, np.int32)
    depth[source] = 0
    frontier = [source]
    while frontier:
        nxt = []
        for u in frontier:
            for e in range(row_ptr[u], row_ptr[u + 1]):
                t = col[e]
                if depth[t] == -1:
                    depth[t] = depth[u] + 1
                    nxt.append(t)
        frontier = nxt
    return depth


def pagerank_np(g: Graph, damping=0.85, tol=1e-6, max_iters=256):
    v = g.n_nodes
    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    out_deg = np.asarray(g.out_degree, np.float64)
    rank = np.full(v, 1.0 / v)
    inv = 1.0 / np.maximum(out_deg, 1)
    dangling = out_deg == 0
    for _ in range(max_iters):
        contrib = np.zeros(v)
        np.add.at(contrib, dst, rank[src] * inv[src])
        dm = rank[dangling].sum()
        new = (1 - damping) / v + damping * (contrib + dm / v)
        if np.abs(new - rank).sum() < tol:
            rank = new
            break
        rank = new
    return rank.astype(np.float32)


def sssp_np(g: Graph, source=0):
    """Bellman-Ford (graphs are symmetric; no negative weights)."""
    v = g.n_nodes
    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    w = np.asarray(g.weight, np.float64)
    dist = np.full(v, np.inf)
    dist[source] = 0.0
    for _ in range(v):
        cand = dist[src] + w
        new = dist.copy()
        np.minimum.at(new, dst, cand)
        if np.array_equal(new, dist, equal_nan=True):
            break
        dist = new
    return dist.astype(np.float32)


def cc_np(g: Graph):
    """Min-vertex-id component labels via BFS union."""
    v = g.n_nodes
    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    label = np.arange(v)
    changed = True
    while changed:
        new = label.copy()
        np.minimum.at(new, dst, label[src])
        np.minimum.at(new, src, label[dst])
        new = new[new]  # pointer jump
        changed = not np.array_equal(new, label)
        label = new
    return label.astype(np.int32)


def bc_np(g: Graph, root=0):
    """Brandes single-root dependency scores (unweighted)."""
    v = g.n_nodes
    row_ptr = np.asarray(g.row_ptr_out, np.int64)
    col = np.asarray(g.dst, np.int64)
    depth = np.full(v, -1, np.int64)
    sigma = np.zeros(v)
    depth[root], sigma[root] = 0, 1.0
    frontier = [root]
    order = [root]
    while frontier:
        nxt = []
        for u in frontier:
            for e in range(row_ptr[u], row_ptr[u + 1]):
                t = col[e]
                if depth[t] == -1:
                    depth[t] = depth[u] + 1
                    nxt.append(t)
                    order.append(t)
                if depth[t] == depth[u] + 1:
                    sigma[t] += sigma[u]
        frontier = nxt
    delta = np.zeros(v)
    for u in reversed(order):
        for e in range(row_ptr[u], row_ptr[u + 1]):
            t = col[e]
            if depth[t] == depth[u] + 1:
                delta[u] += sigma[u] / sigma[t] * (1.0 + delta[t])
    delta[root] = 0.0
    return delta.astype(np.float32)


def is_independent_set(g: Graph, member: np.ndarray) -> bool:
    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    return not np.any(member[src] & member[dst])


def is_maximal_independent_set(g: Graph, member: np.ndarray) -> bool:
    if not is_independent_set(g, member):
        return False
    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    # every non-member must have a member neighbor
    covered = np.zeros(g.n_nodes, bool)
    covered[dst[member[src]]] = True
    covered[src[member[dst]]] = True
    return bool(np.all(member | covered))


def is_proper_coloring(g: Graph, color: np.ndarray) -> bool:
    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    return bool(np.all(color >= 0)
                and not np.any(color[src] == color[dst]))
