"""repro: push/pull x coherence x consistency specialization for graph
analytics (Salvador et al., CS.DC 2020), rebuilt as a multi-pod JAX/TPU
framework.  See DESIGN.md for the system inventory."""

__version__ = "1.0.0"
