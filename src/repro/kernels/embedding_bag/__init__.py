from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

__all__ = ["embedding_bag_pallas", "embedding_bag", "embedding_bag_ref"]
