"""Public embedding-bag op with impl switch (pallas kernel / XLA gather)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref

__all__ = ["embedding_bag"]


def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray, *,
                  mode: str = "sum", impl: str = "xla",
                  interpret: bool = True) -> jnp.ndarray:
    if impl == "pallas":
        return embedding_bag_pallas(table, indices, mode=mode,
                                    interpret=interpret)
    return embedding_bag_ref(table, indices, mode=mode)
