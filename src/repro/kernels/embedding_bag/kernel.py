"""Embedding-bag gather+pool — Pallas TPU.

The table stays in HBM (``memory_space=ANY``); bag indices are scalar-
prefetched (available before the body runs, so row DMAs can be issued
immediately); each grid step pools one tile of bags.  Rows stream
HBM->VMEM via explicit async copies — the TPU analogue of the FBGEMM
table-batched-embedding hot loop, and exactly the memory pattern DLRM's
roofline is dominated by.

All P row copies of a bag tile are issued before any is awaited (DMA
pipelining inside the step); cross-step pipelining via double buffering is
a recorded perf iteration (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag_pallas"]


def _embag_kernel(idx_ref, table_ref, out_ref, rows_scr, sem, *,
                  bags_per_step: int, pool: int, mode: str):
    step = pl.program_id(0)

    def copy(b, p):
        gid = step * bags_per_step + b
        return pltpu.make_async_copy(
            table_ref.at[pl.ds(idx_ref[gid, p], 1), :],
            rows_scr.at[pl.ds(b * pool + p, 1), :],
            sem.at[b * pool + p],
        )

    # issue every row DMA first, then await: in-step pipelining
    for b in range(bags_per_step):
        for p in range(pool):
            copy(b, p).start()
    for b in range(bags_per_step):
        for p in range(pool):
            copy(b, p).wait()

    rows = rows_scr[...].reshape(bags_per_step, pool, -1)
    pooled = rows.sum(axis=1)
    if mode == "mean":
        pooled = pooled / pool
    out_ref[...] = pooled.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "bags_per_step",
                                             "interpret"))
def embedding_bag_pallas(table: jnp.ndarray, indices: jnp.ndarray, *,
                         mode: str = "sum", bags_per_step: int = 8,
                         interpret: bool = True) -> jnp.ndarray:
    """table [R, D]; indices [B, P] int32 -> [B, D]."""
    r, d = table.shape
    bsz, pool = indices.shape
    bags_per_step = min(bags_per_step, bsz)
    n_steps = -(-bsz // bags_per_step)
    pad = n_steps * bags_per_step - bsz
    if pad:
        indices = jnp.concatenate(
            [indices, jnp.zeros((pad, pool), indices.dtype)])

    kernel = functools.partial(_embag_kernel, bags_per_step=bags_per_step,
                               pool=pool, mode=mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_steps,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],  # table in HBM
        out_specs=pl.BlockSpec((bags_per_step, d), lambda i, idx: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bags_per_step * pool, d), table.dtype),
            pltpu.SemaphoreType.DMA((bags_per_step * pool,)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_steps * bags_per_step, d),
                                       table.dtype),
        interpret=interpret,
    )(indices, table)
    return out[:bsz]
