"""Pure-jnp oracle for embedding-bag (JAX has no native EmbeddingBag —
gather + reduce IS the implementation contract, kernel_taxonomy §RecSys)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["embedding_bag_ref"]


def embedding_bag_ref(table: jnp.ndarray, indices: jnp.ndarray,
                      mode: str = "sum") -> jnp.ndarray:
    """table [R, D]; indices [B, P] -> [B, D] pooled over P."""
    rows = jnp.take(table, indices, axis=0)        # [B, P, D]
    if mode == "sum":
        return rows.sum(axis=1)
    if mode == "mean":
        return rows.mean(axis=1)
    raise ValueError(mode)
