"""Jit-safe wrappers around the blocked segment-reduce kernels.

The tiling plan depends only on the (static) binned segment ids, so it is
built once on host (numpy) and the returned reducer is safe to call inside
jit — values are gathered with a static index array at runtime.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.kernel import (plan_tiles, seg_minmax_pallas,
                                                 seg_sum_pallas)

__all__ = ["BlockedSegmentReducer"]


class BlockedSegmentReducer:
    """Plan once (host), reduce many times (device, inside jit).

    ``segment_ids`` must arrive binned by target block (``Graph.perm_owned``
    order) with ``block_ptr`` giving per-block edge offsets — exactly what
    :class:`repro.graph.Graph` maintains.

    Construction is the expensive part (the vectorized
    :func:`plan_tiles` plus an O(n_tiles * tile_e) local-id rewrite);
    ``repro.core.plan_cache.PLAN_CACHE`` therefore caches built reducer
    instances per graph so a design-space sweep pays the plan exactly
    once.  ``n_tiles`` exposes the plan size for benchmarks and tests.
    """

    def __init__(self, segment_ids: np.ndarray, block_ptr: np.ndarray,
                 num_segments: int, block_size: int, tile_e: int = 512,
                 interpret: bool = True):
        ids = np.asarray(segment_ids, np.int64)
        self.gather_idx, self.tile_block_id, self.tile_first = plan_tiles(
            block_ptr, tile_e)
        self.n_tiles = int(self.gather_idx.shape[0])
        self.tile_e = int(tile_e)
        pad = self.gather_idx < 0
        safe = np.where(pad, 0, self.gather_idx)
        lids = ids[safe] - self.tile_block_id[:, None].astype(np.int64) \
            * block_size
        self.lids = jnp.asarray(np.where(pad, -1, lids).astype(np.int32))
        self.gather = jnp.asarray(safe.astype(np.int32))
        self.pad_mask = jnp.asarray(pad)
        self.tbid = jnp.asarray(self.tile_block_id)
        self.tfirst = jnp.asarray(self.tile_first)
        self.num_segments = int(num_segments)
        self.block_size = int(block_size)
        self.num_out_blocks = -(-int(num_segments) // int(block_size))
        self.interpret = bool(interpret)

    def _tile_values(self, values: jnp.ndarray, fill) -> jnp.ndarray:
        squeeze = values.ndim == 1
        if squeeze:
            values = values[:, None]
        tiled = jnp.take(values, self.gather.reshape(-1), axis=0)
        tiled = tiled.reshape(*self.gather.shape, values.shape[-1])
        tiled = jnp.where(self.pad_mask[..., None], fill, tiled)
        return tiled, squeeze

    def sum(self, values: jnp.ndarray) -> jnp.ndarray:
        tiled, squeeze = self._tile_values(values, 0)
        out = seg_sum_pallas(tiled, self.lids, self.tbid, self.tfirst,
                             block_size=self.block_size,
                             num_out_blocks=self.num_out_blocks,
                             interpret=self.interpret)
        out = out[:self.num_segments]
        return out[:, 0] if squeeze else out

    def _minmax(self, values, is_min):
        dtype = values.dtype
        if jnp.issubdtype(dtype, jnp.floating):
            # match jax.ops.segment_min/max: empty segments hold +/-inf
            ident = float("inf") if is_min else float("-inf")
        else:
            ident = int(jnp.iinfo(dtype).max if is_min
                        else jnp.iinfo(dtype).min)
        tiled, squeeze = self._tile_values(values, ident)
        out = seg_minmax_pallas(tiled, self.lids, self.tbid, self.tfirst,
                                block_size=self.block_size,
                                num_out_blocks=self.num_out_blocks,
                                is_min=is_min, interpret=self.interpret)
        out = out[:self.num_segments]
        return out[:, 0] if squeeze else out

    def min(self, values: jnp.ndarray) -> jnp.ndarray:
        return self._minmax(values, True)

    def max(self, values: jnp.ndarray) -> jnp.ndarray:
        return self._minmax(values, False)

    def reduce(self, values: jnp.ndarray, kind: str) -> jnp.ndarray:
        return getattr(self, kind)(values)

    @staticmethod
    def identity(kind: str, dtype) -> jnp.ndarray:
        """The monoid identity this reducer assumes for ``kind``."""
        dtype = jnp.dtype(dtype)
        if kind == "sum":
            return jnp.zeros((), dtype)
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(jnp.inf if kind == "min" else -jnp.inf, dtype)
        info = jnp.iinfo(dtype)
        return jnp.array(info.max if kind == "min" else info.min, dtype)

    def masked(self, values: jnp.ndarray, mask: jnp.ndarray,
               kind: str, ident=None) -> jnp.ndarray:
        """Reduce with an [E] edge mask: masked-out edges contribute the
        identity.  This is the predicate (``spred``/``tpred``) entry
        point for both the push/owned and the pull/CSC fast paths.
        Callers already holding their monoid's identity (the executor's
        ``Monoid.identity``) pass it via ``ident`` so the two
        definitions can't drift."""
        if ident is None:
            ident = self.identity(kind, values.dtype)
        if values.ndim == mask.ndim + 1:
            mask = mask[..., None]
        return self.reduce(jnp.where(mask, values, ident), kind)
