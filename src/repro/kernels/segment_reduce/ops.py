"""Jit-safe wrappers around the blocked segment-reduce kernels.

The tiling plan depends only on the (static) binned segment ids, so it is
built once on host (numpy) and the returned reducer is safe to call inside
jit — values are gathered with a static index array at runtime.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.kernel import (plan_tiles, seg_minmax_pallas,
                                                 seg_sum_pallas)

__all__ = ["BlockedSegmentReducer", "TilingPlan", "DEFAULT_PLAN",
           "coarsen_block_ptr", "bin_edges_by_block"]


@dataclasses.dataclass(frozen=True)
class TilingPlan:
    """One point of the blocked-reducer tuning space.

    Hashable (frozen, scalar fields) so it can key plan-cache entries
    directly.  The defaults reproduce the pre-autotuner static tiling
    exactly — :data:`DEFAULT_PLAN` is always one candidate of any
    autotune sweep, so tuning can never do worse than the old
    hard-coded configuration on the tuner's own measurements.

    - ``tile_e`` — edges per grid step of the blocked kernels (one
      VMEM-resident gather tile).
    - ``block_mult`` — output-block coarsening factor: the reducer's
      segment block covers ``block_mult`` consecutive base blocks
      (``Graph.block_size`` vertices each).  Coarsening is always sound
      on block-binned edge orders: a coarse block is a union of
      consecutive base blocks, so edges sorted by base block are also
      sorted by coarse block (see :func:`coarsen_block_ptr`).
    - ``block_div`` — output-block *refinement* factor (blocks of
      ``base // block_div`` vertices).  Sound only for edge orders
      sorted by destination (the pull/CSC order): a fully sorted order
      stays binned under any block partition, whereas the owned order
      is binned only at base-block granularity.  Mutually exclusive
      with coarsening.
    - ``gather_splits`` — how many partial scatters the sparse
      frontier-gathered reduction splits its ``[cap_e]`` slice into
      (1 = today's single scatter).
    - ``source`` — provenance tag ("default" | "heuristic" | "tuned" |
      "disk"), carried for observability only; excluded from equality
      so a disk-warmed plan compares equal to the freshly measured one.
    """

    tile_e: int = 512
    block_mult: int = 1
    block_div: int = 1
    gather_splits: int = 1
    source: str = dataclasses.field(default="default", compare=False)

    def __post_init__(self):
        if self.block_mult > 1 and self.block_div > 1:
            raise ValueError("TilingPlan: block_mult and block_div are "
                             "mutually exclusive")
        if min(self.tile_e, self.block_mult, self.block_div,
               self.gather_splits) < 1:
            raise ValueError("TilingPlan fields must be >= 1")

    def astuple(self):
        """The identity-relevant fields (cache/JSON key material)."""
        return (self.tile_e, self.block_mult, self.block_div,
                self.gather_splits)

    def block_size(self, base_block_size: int) -> int:
        """Effective output-block size on a base blocking."""
        if self.block_div > 1:
            return max(1, base_block_size * self.block_mult
                       // self.block_div)
        return base_block_size * self.block_mult


#: The pre-autotuner static tiling every call site used to hard-code.
DEFAULT_PLAN = TilingPlan()


def coarsen_block_ptr(block_ptr: np.ndarray, mult: int) -> np.ndarray:
    """Per-block edge offsets after merging ``mult`` consecutive blocks.

    Edges binned by base block stay binned under the coarser blocking
    (each coarse block is a contiguous run of base blocks), so the
    coarse plan is just the base ``block_ptr`` sampled every ``mult``
    entries (the final boundary is always kept).
    """
    block_ptr = np.asarray(block_ptr)
    if mult <= 1:
        return block_ptr
    n_blocks = block_ptr.shape[0] - 1
    n_coarse = -(-n_blocks // mult)
    idx = np.minimum(np.arange(n_coarse + 1) * mult, n_blocks)
    return block_ptr[idx]


def bin_edges_by_block(dst: np.ndarray, n_nodes: int,
                       block_size: int) -> tuple:
    """Bin an edge list by destination block: ``(perm, block_ptr)``.

    ``perm`` stable-sorts edges by ``dst // block_size`` (preserving the
    input order inside each block — the property the owned/DeNovo path
    relies on for dense source reads) and ``block_ptr`` gives per-block
    edge offsets.  This is the host-side construction behind
    :class:`~repro.graph.structure.Graph`'s owned order; the batched
    executor also uses it to re-bin a block-diagonal packed edge list
    whose per-graph vertex offsets don't align with block boundaries.
    """
    dst = np.asarray(dst, np.int64)
    n_blocks = (int(n_nodes) + block_size - 1) // block_size
    blk = dst // block_size
    perm = np.argsort(blk, kind="stable")
    block_ptr = np.zeros(n_blocks + 1, dtype=np.int64)
    np.add.at(block_ptr, blk + 1, 1)
    return perm.astype(np.int32), np.cumsum(block_ptr).astype(np.int32)


class BlockedSegmentReducer:
    """Plan once (host), reduce many times (device, inside jit).

    ``segment_ids`` must arrive binned by target block (``Graph.perm_owned``
    order) with ``block_ptr`` giving per-block edge offsets — exactly what
    :class:`repro.graph.Graph` maintains.

    Construction is the expensive part (the vectorized
    :func:`plan_tiles` plus an O(n_tiles * tile_e) local-id rewrite);
    ``repro.core.plan_cache.PLAN_CACHE`` therefore caches built reducer
    instances per graph so a design-space sweep pays the plan exactly
    once.  ``n_tiles`` exposes the plan size for benchmarks and tests.
    """

    def __init__(self, segment_ids: np.ndarray, block_ptr: np.ndarray,
                 num_segments: int, block_size: int, tile_e: int = 512,
                 interpret: bool = True, plan: "TilingPlan | None" = None):
        self.plan = plan if plan is not None else TilingPlan(tile_e=tile_e)
        # int32 end to end: the kernels index with int32, and the plan's
        # [n_tiles, tile_e] arrays are the dominant host/device index
        # traffic — int64 intermediates would double it (plan_tiles
        # guards the edge-count range).
        ids = np.asarray(segment_ids, np.int32)
        self.gather_idx, self.tile_block_id, self.tile_first = plan_tiles(
            block_ptr, tile_e)
        self.n_tiles = int(self.gather_idx.shape[0])
        self.tile_e = int(tile_e)
        pad = self.gather_idx < 0
        safe = np.where(pad, np.int32(0), self.gather_idx)
        lids = ids[safe] - self.tile_block_id[:, None] * np.int32(block_size)
        self.lids = jnp.asarray(np.where(pad, np.int32(-1), lids))
        self.gather = jnp.asarray(safe)
        self.pad_mask = jnp.asarray(pad)
        self.tbid = jnp.asarray(self.tile_block_id)
        self.tfirst = jnp.asarray(self.tile_first)
        self.num_segments = int(num_segments)
        self.block_size = int(block_size)
        self.num_out_blocks = -(-int(num_segments) // int(block_size))
        self.interpret = bool(interpret)

    @classmethod
    def from_plan(cls, segment_ids: np.ndarray, block_ptr: np.ndarray,
                  num_segments: int, base_block_size: int,
                  plan: "TilingPlan | None" = None,
                  interpret: bool = True) -> "BlockedSegmentReducer":
        """Plan-parameterized constructor (the autotuner entry point).

        ``block_ptr``/``base_block_size`` describe the edge order's
        *base* blocking (``Graph.block_size``); the plan's
        ``block_mult`` coarsens both consistently before the tiling
        plan is built, and ``tile_e`` sizes the edge tiles.
        ``plan=None`` (or :data:`DEFAULT_PLAN`) reproduces the
        pre-autotuner construction bit for bit.  Refinement
        (``block_div > 1``) cannot be expressed from a base
        ``block_ptr`` alone — refined reducers are built from the
        per-vertex row offsets instead (see
        :func:`repro.kernels.autotune.build_reducer`).
        """
        plan = plan if plan is not None else DEFAULT_PLAN
        if plan.block_div > 1:
            raise ValueError("from_plan cannot refine blocks (block_div "
                             "> 1) from a base block_ptr; build from "
                             "per-vertex row offsets instead")
        return cls(segment_ids, coarsen_block_ptr(block_ptr, plan.block_mult),
                   num_segments, base_block_size * plan.block_mult,
                   tile_e=plan.tile_e, interpret=interpret, plan=plan)

    def _tile_values(self, values: jnp.ndarray, fill) -> jnp.ndarray:
        squeeze = values.ndim == 1
        if squeeze:
            values = values[:, None]
        tiled = jnp.take(values, self.gather.reshape(-1), axis=0)
        tiled = tiled.reshape(*self.gather.shape, values.shape[-1])
        tiled = jnp.where(self.pad_mask[..., None], fill, tiled)
        return tiled, squeeze

    def sum(self, values: jnp.ndarray) -> jnp.ndarray:
        tiled, squeeze = self._tile_values(values, 0)
        out = seg_sum_pallas(tiled, self.lids, self.tbid, self.tfirst,
                             block_size=self.block_size,
                             num_out_blocks=self.num_out_blocks,
                             interpret=self.interpret)
        out = out[:self.num_segments]
        return out[:, 0] if squeeze else out

    def _minmax(self, values, is_min):
        dtype = values.dtype
        if jnp.issubdtype(dtype, jnp.floating):
            # match jax.ops.segment_min/max: empty segments hold +/-inf
            ident = float("inf") if is_min else float("-inf")
        else:
            ident = int(jnp.iinfo(dtype).max if is_min
                        else jnp.iinfo(dtype).min)
        tiled, squeeze = self._tile_values(values, ident)
        out = seg_minmax_pallas(tiled, self.lids, self.tbid, self.tfirst,
                                block_size=self.block_size,
                                num_out_blocks=self.num_out_blocks,
                                is_min=is_min, interpret=self.interpret)
        out = out[:self.num_segments]
        return out[:, 0] if squeeze else out

    def min(self, values: jnp.ndarray) -> jnp.ndarray:
        return self._minmax(values, True)

    def max(self, values: jnp.ndarray) -> jnp.ndarray:
        return self._minmax(values, False)

    def reduce(self, values: jnp.ndarray, kind: str) -> jnp.ndarray:
        return getattr(self, kind)(values)

    @staticmethod
    def identity(kind: str, dtype) -> jnp.ndarray:
        """The monoid identity this reducer assumes for ``kind``."""
        dtype = jnp.dtype(dtype)
        if kind == "sum":
            return jnp.zeros((), dtype)
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(jnp.inf if kind == "min" else -jnp.inf, dtype)
        info = jnp.iinfo(dtype)
        return jnp.array(info.max if kind == "min" else info.min, dtype)

    def masked(self, values: jnp.ndarray, mask: jnp.ndarray,
               kind: str, ident=None) -> jnp.ndarray:
        """Reduce with an [E] edge mask: masked-out edges contribute the
        identity.  This is the predicate (``spred``/``tpred``) entry
        point for both the push/owned and the pull/CSC fast paths.
        Callers already holding their monoid's identity (the executor's
        ``Monoid.identity``) pass it via ``ident`` so the two
        definitions can't drift."""
        if ident is None:
            ident = self.identity(kind, values.dtype)
        if values.ndim == mask.ndim + 1:
            mask = mask[..., None]
        return self.reduce(jnp.where(mask, values, ident), kind)
