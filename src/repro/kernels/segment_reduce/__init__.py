from repro.kernels.segment_reduce.ops import (DEFAULT_PLAN,
                                              BlockedSegmentReducer,
                                              TilingPlan, bin_edges_by_block,
                                              coarsen_block_ptr)
from repro.kernels.segment_reduce.ref import (segment_max_ref,
                                              segment_min_ref,
                                              segment_sum_ref)
from repro.kernels.segment_reduce.sparse import (gathered_segment_reduce,
                                                 gathered_segment_reduce_ref)

__all__ = ["BlockedSegmentReducer", "TilingPlan", "DEFAULT_PLAN",
           "bin_edges_by_block",
           "coarsen_block_ptr", "segment_sum_ref", "segment_min_ref",
           "segment_max_ref", "gathered_segment_reduce",
           "gathered_segment_reduce_ref"]
