from repro.kernels.segment_reduce.ops import BlockedSegmentReducer
from repro.kernels.segment_reduce.ref import (segment_max_ref,
                                              segment_min_ref,
                                              segment_sum_ref)
from repro.kernels.segment_reduce.sparse import (gathered_segment_reduce,
                                                 gathered_segment_reduce_ref)

__all__ = ["BlockedSegmentReducer", "segment_sum_ref", "segment_min_ref",
           "segment_max_ref", "gathered_segment_reduce",
           "gathered_segment_reduce_ref"]
