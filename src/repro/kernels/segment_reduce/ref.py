"""Pure-jnp oracle for the blocked segment reductions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_sum_ref", "segment_min_ref", "segment_max_ref"]


def segment_sum_ref(values: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """values [E] or [E, D]; ids [E] int32 in [0, num_segments) (out-of-
    range ids are dropped, matching the kernel's padding contract)."""
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)


def segment_min_ref(values, segment_ids, num_segments):
    return jax.ops.segment_min(values, segment_ids,
                               num_segments=num_segments)


def segment_max_ref(values, segment_ids, num_segments):
    return jax.ops.segment_max(values, segment_ids,
                               num_segments=num_segments)
