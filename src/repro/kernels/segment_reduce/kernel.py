"""Blocked segment reduction — the DeNovo-coherence analogue on TPU.

The target-vertex range is tiled into blocks of ``block_size`` segments;
edges arrive binned by target block (``Graph.perm_owned`` order).  Each
output block is "owned" in VMEM across the consecutive grid steps that feed
it ("ownership registration at L1"), accumulated locally, and written back
to HBM exactly once — versus the LLC-analogue global XLA scatter that
resolves every update at HBM.

Sum uses the canonical TPU trick: scatter-within-block == one-hot matmul on
the MXU (contrib = onehot(local_ids)^T @ values).  Min/max use a masked
VPU reduce over a feature tile.

Grid: one step per edge tile; ``tile_block_id`` (scalar-prefetched) steers
the output BlockSpec so Pallas keeps the same VMEM block resident across
consecutive tiles of one block. ``tile_first`` zeroes the accumulator when
a new block begins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["seg_sum_pallas", "seg_minmax_pallas", "plan_tiles"]


def plan_tiles(block_ptr: np.ndarray, tile_e: int):
    """Host-side tiling plan over block-binned edges.

    Returns (gather_idx [n_tiles, tile_e] int32 into the binned edge order,
    -1 = padding; tile_block_id [n_tiles]; tile_first [n_tiles]).  Every
    output block gets at least one tile so it is always initialised.

    Fully vectorized numpy bucket arithmetic (no per-block Python loop):
    this sits on the plan cache's cold path, so an O(n_blocks)
    interpreted loop would dominate first-touch latency on large graphs.
    Tile *t* of block *b* gathers edges ``block_ptr[b] + t*tile_e ..``,
    clipped to the block's edge range with -1 padding.

    All index arithmetic — including the [n_tiles, tile_e] ``gather``
    intermediate, the plan's largest array — runs in int32: edge ids fit
    (the kernels and :class:`~repro.graph.structure.Graph` are int32
    throughout), and an int64 intermediate would double the plan's host
    memory traffic exactly when a tuned large-``tile_e`` plan makes the
    array widest.
    """
    block_ptr = np.asarray(block_ptr)
    if block_ptr.size and int(block_ptr[-1]) >= np.iinfo(np.int32).max:
        raise ValueError("plan_tiles: edge count exceeds int32 index range")
    block_ptr = block_ptr.astype(np.int32)
    n_blocks = block_ptr.shape[0] - 1
    counts = np.diff(block_ptr)
    # ceil(counts / tile_e), but empty blocks still get one (all-padding)
    # tile so their output block is initialised
    tiles_per_block = np.maximum(1, -(-counts // tile_e)).astype(np.int32)
    n_tiles = int(tiles_per_block.sum())
    tbid = np.repeat(np.arange(n_blocks, dtype=np.int32), tiles_per_block)
    first_tile = (np.cumsum(tiles_per_block, dtype=np.int32)
                  - tiles_per_block)
    tfirst = np.zeros(n_tiles, np.int32)
    tfirst[first_tile] = 1
    # within-block tile ordinal of every tile
    local = np.arange(n_tiles, dtype=np.int32) - first_tile[tbid]
    offs = (block_ptr[tbid][:, None]
            + local[:, None] * np.int32(tile_e)
            + np.arange(tile_e, dtype=np.int32)[None, :])
    gather = np.where(offs < block_ptr[tbid + 1][:, None], offs,
                      np.int32(-1))
    return (gather, tbid, tfirst)


# ---------------------------------------------------------------------------
# sum kernel (MXU one-hot matmul)
# ---------------------------------------------------------------------------
def _sum_kernel(tbid_ref, tfirst_ref, lid_ref, vals_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(tfirst_ref[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lids = lid_ref[0, :]                       # [tile_e] local ids, -1 pad
    vals = vals_ref[0]                         # [tile_e, D]
    tile_e = lids.shape[0]
    block = out_ref.shape[0]
    onehot = (lids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tile_e, block), 1)).astype(vals.dtype)
    contrib = jax.lax.dot_general(
        onehot, vals,
        dimension_numbers=(((0,), (0,)), ((), ())),  # onehot^T @ vals
        preferred_element_type=jnp.float32)
    out_ref[...] += contrib.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "num_out_blocks",
                                             "interpret"))
def seg_sum_pallas(vals_tiled: jnp.ndarray,   # [n_tiles, tile_e, D]
                   lids_tiled: jnp.ndarray,   # [n_tiles, tile_e]
                   tile_block_id: jnp.ndarray,
                   tile_first: jnp.ndarray,
                   *, block_size: int, num_out_blocks: int,
                   interpret: bool = True) -> jnp.ndarray:
    n_tiles, tile_e, d = vals_tiled.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_e), lambda i, tbid, tfirst: (i, 0)),
            pl.BlockSpec((1, tile_e, d), lambda i, tbid, tfirst: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_size, d),
                               lambda i, tbid, tfirst: (tbid[i], 0)),
    )

    return pl.pallas_call(
        _sum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_out_blocks * block_size, d),
                                       vals_tiled.dtype),
        interpret=interpret,
    )(tile_block_id, tile_first, lids_tiled, vals_tiled)


# ---------------------------------------------------------------------------
# min/max kernel (masked VPU reduce, feature-tiled)
# ---------------------------------------------------------------------------
def _minmax_kernel(tbid_ref, tfirst_ref, lid_ref, vals_ref, out_ref, *,
                   is_min: bool, ident):
    i = pl.program_id(1)  # edge-tile index (innermost: consecutive revisits)

    @pl.when(tfirst_ref[i] == 1)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    lids = lid_ref[0, :]
    vals = vals_ref[0]                          # [tile_e, bd]
    tile_e = lids.shape[0]
    block = out_ref.shape[0]
    onehot = lids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tile_e, block), 1)
    masked = jnp.where(onehot[:, :, None], vals[:, None, :], ident)
    red = masked.min(axis=0) if is_min else masked.max(axis=0)
    cur = out_ref[...]
    out_ref[...] = jnp.minimum(cur, red) if is_min else jnp.maximum(cur, red)


@functools.partial(jax.jit, static_argnames=("block_size", "num_out_blocks",
                                             "is_min", "interpret", "bd"))
def seg_minmax_pallas(vals_tiled, lids_tiled, tile_block_id, tile_first, *,
                      block_size: int, num_out_blocks: int, is_min: bool,
                      bd: int = 8, interpret: bool = True) -> jnp.ndarray:
    n_tiles, tile_e, d = vals_tiled.shape
    n_d = -(-d // bd)
    if n_d * bd != d:
        pad = n_d * bd - d
        vals_tiled = jnp.pad(vals_tiled, ((0, 0), (0, 0), (0, pad)))
    dtype = vals_tiled.dtype
    if jnp.issubdtype(dtype, jnp.floating):
        ident = float("inf") if is_min else float("-inf")
    else:
        ident = int(jnp.iinfo(dtype).max if is_min else jnp.iinfo(dtype).min)

    # feature tile j is OUTER, edge tile i INNER so revisits of one output
    # block happen on consecutive grid steps (Pallas revisit contract).
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_d, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile_e), lambda j, i, tbid, tfirst: (i, 0)),
            pl.BlockSpec((1, tile_e, bd),
                         lambda j, i, tbid, tfirst: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_size, bd),
                               lambda j, i, tbid, tfirst: (tbid[i], j)),
    )

    kernel = functools.partial(_minmax_kernel, is_min=is_min, ident=ident)

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_out_blocks * block_size, n_d * bd),
                                       dtype),
        interpret=interpret,
    )(tile_block_id, tile_first, lids_tiled, vals_tiled)
    return out[:, :d]
