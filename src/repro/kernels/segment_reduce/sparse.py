"""Gathered-frontier segment reduction: O(cap_e) instead of O(E).

:class:`~repro.kernels.segment_reduce.ops.BlockedSegmentReducer` builds
its tiling plan on host from *static* segment ids, so it can only serve
reductions over a fixed edge order (the full CSR/CSC/owned edge set).  A
frontier-gathered edge subset is a traced array that changes every
iteration — no host-side plan can exist for it.  The sparse path
therefore reduces with XLA's native scatter over exactly the gathered
``[cap_e]`` slice: the work is proportional to the static gather
capacity (sized ~|E|/alpha by the executor), not to |E|, which is the
entire point of gathering.

Padding and predicate-masked slots carry segment id -1 and are routed to
a trash segment, so callers need not substitute the monoid identity into
the value array first.  Empty segments come back holding the reduction's
identity (0 / +inf / -inf, or the integer extrema), exactly matching the
dense executor path's masked-identity convention — the two paths are
bit-identical for min/max and exact-sum inputs.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_reduce.ref import (segment_max_ref,
                                              segment_min_ref,
                                              segment_sum_ref)

__all__ = ["gathered_segment_reduce", "gathered_segment_reduce_ref"]

# one monoid-name dispatch for the package: the gathered entry point and
# the blocked kernels' oracles must agree on op semantics by construction
_OPS = {"sum": segment_sum_ref, "min": segment_min_ref,
        "max": segment_max_ref}


_COMBINE = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}


def gathered_segment_reduce(values: jnp.ndarray, segment_ids: jnp.ndarray,
                            num_segments: int, kind: str,
                            plan=None) -> jnp.ndarray:
    """Reduce a gathered edge subset into ``[num_segments]``.

    ``values``/``segment_ids`` are the ``[cap_e]`` gathered slice;
    ``segment_ids < 0`` marks padding or masked-out slots whose values
    are ignored (their value may be arbitrary — no identity substitution
    required).  ``kind`` is the monoid name ('sum' | 'min' | 'max').

    ``plan`` (a :class:`~repro.kernels.segment_reduce.ops.TilingPlan`)
    optionally splits the slice into ``plan.gather_splits`` independent
    partial scatters combined elementwise — the gathered path's tunable,
    analogous to the blocked kernels' ``tile_e``.  ``plan=None`` or
    ``gather_splits=1`` is the original single scatter.  Min/max and
    exact (integer-valued) sums are split-invariant; inexact float sums
    may differ in final ULPs across split counts, exactly like the
    dense path's chunk schedules.
    """
    splits = int(getattr(plan, "gather_splits", 1) or 1) if plan else 1
    ids = jnp.where(segment_ids < 0, num_segments, segment_ids)
    if splits <= 1 or splits >= ids.shape[0]:
        out = _OPS[kind](values, ids, num_segments + 1)
        return out[:num_segments]
    e = ids.shape[0]
    chunk = -(-e // splits)
    pad = chunk * splits - e
    if pad:
        # padding slots route to the trash segment like any masked slot
        ids = jnp.concatenate(
            [ids, jnp.full((pad,), num_segments, ids.dtype)])
        values = jnp.concatenate(
            [values, jnp.zeros((pad,) + values.shape[1:], values.dtype)])
    ids = ids.reshape(splits, chunk)
    values = values.reshape(splits, chunk, *values.shape[1:])
    combine = _COMBINE[kind]
    out = _OPS[kind](values[0], ids[0], num_segments + 1)
    for s in range(1, splits):
        out = combine(out, _OPS[kind](values[s], ids[s], num_segments + 1))
    return out[:num_segments]


def gathered_segment_reduce_ref(values, segment_ids, num_segments: int,
                                kind: str) -> np.ndarray:
    """Numpy oracle for :func:`gathered_segment_reduce` (tests only)."""
    values = np.asarray(values)
    segment_ids = np.asarray(segment_ids)
    if kind == "sum":
        ident, combine = np.zeros((), values.dtype), np.add
    elif kind == "min":
        ident = (np.iinfo(values.dtype).max
                 if np.issubdtype(values.dtype, np.integer) else np.inf)
        combine = np.minimum
    else:
        ident = (np.iinfo(values.dtype).min
                 if np.issubdtype(values.dtype, np.integer) else -np.inf)
        combine = np.maximum
    out = np.full((num_segments,), ident, values.dtype)
    for v, s in zip(values, segment_ids):
        if 0 <= s < num_segments:
            out[s] = combine(out[s], v)
    return out
