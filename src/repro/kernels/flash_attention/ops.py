"""Public attention ops: TPU Pallas kernel or XLA reference, one switch.

``attention(..., impl='pallas'|'xla')`` — models call this; the dry-run
lowers with impl='xla' (the kernel is validated separately in interpret
mode; on real TPU hardware impl='pallas' with interpret=False is the fast
path).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import decode_ref, gqa_ref

__all__ = ["attention", "decode_attention"]


def attention(q, k, v, *, causal: bool = True, impl: str = "xla",
              interpret: bool = True) -> jnp.ndarray:
    """GQA attention; q [B,Hq,S,D], k/v [B,Hkv,S,D]."""
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, interpret=interpret)
    return gqa_ref(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, kv_len) -> jnp.ndarray:
    """One-token decode against a (possibly over-allocated) KV cache."""
    return decode_ref(q, k_cache, v_cache, kv_len)
