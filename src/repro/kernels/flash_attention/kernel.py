"""Flash attention forward (GQA + causal), Pallas TPU.

Standard IO-aware blocked softmax: grid (batch, q_head, q_tiles, k_tiles)
with the k axis innermost; VMEM scratch carries the running max ``m``,
normaliser ``l`` and un-normalised accumulator across k steps; the output
tile is written once at the last visited k tile (hence O(Sq*D) VMEM per
(b,h,q) and no S*S materialisation).  Causal q tiles skip fully-masked k
tiles via the grid index map (they are still visited but masked cheaply;
full skipping is a documented perf iteration).

GQA is expressed in the k/v index maps: kv head = q head // group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, scale: float, bq: int, bk: int, n_k: int,
                  seq_off: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                # [bq, d]
    k = k_ref[0, 0]                                # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        # query rows are offset by (Sk - Sq) when q is a suffix of k
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
            + seq_off
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_scr[...]                            # [bq, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                         # [bq, bk]
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    m_scr[...] = m_cur
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q [B,Hq,Sq,D], k/v [B,Hkv,Sk,D], Hq % Hkv == 0 -> [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    n_q = -(-sq // bq)
    n_k = -(-sk // bk)
    scale = 1.0 / (d ** 0.5)
    seq_off = sk - sq  # causal offset when decoding a suffix

    kernel = functools.partial(_flash_kernel, causal=causal, scale=scale,
                               bq=bq, bk=bk, n_k=n_k, seq_off=seq_off)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
