"""Pure-jnp oracle for flash attention (GQA, causal or full)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["mha_ref", "gqa_ref", "decode_ref"]


def mha_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """q [B,H,Sq,D], k/v [B,H,Sk,D] -> [B,H,Sq,D] (fp32 softmax)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        ki = jnp.arange(sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), v)


def gqa_ref(q, k, v, causal: bool = True):
    """q [B,Hq,Sq,D], k/v [B,Hkv,Sk,D] with Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    return mha_ref(q, kx, vx, causal=causal)


def decode_ref(q, k, v, kv_len, window=None):
    """Single-token decode: q [B,Hq,1,D] against cache k/v [B,Hkv,S,D];
    positions >= kv_len are masked (cache may be over-allocated);
    ``window`` additionally masks positions < kv_len - window (sliding-
    window models).  GQA via a grouped einsum — no k/v repeat."""
    b, hkv, s, d = k.shape
    hq = q.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, q.shape[2], d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    pos = jnp.arange(s)[None, None, None, None, :]
    mask = pos < kv_len
    if window is not None:
        mask &= pos >= kv_len - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(q.dtype), v)
    return out.reshape(b, hq, q.shape[2], d)
