from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import attention, decode_attention
from repro.kernels.flash_attention.ref import decode_ref, gqa_ref, mha_ref

__all__ = ["flash_attention", "attention", "decode_attention",
           "mha_ref", "gqa_ref", "decode_ref"]
