"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package: kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd wrapper), ref.py (pure-jnp oracle).  Validated in interpret mode
on CPU (tests/test_kernels.py); interpret=False targets TPU Mosaic.
"""
