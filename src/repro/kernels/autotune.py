"""Degree-aware empirical autotuner for the blocked segment reducers.

The paper's core finding — no single best configuration; specialize per
workload — applies to kernel tiling just as it does to push/pull and
consistency: the best ``(tile_e, block_mult)`` for the blocked Pallas
reducers depends on the graph's degree distribution.  A near-regular
low-degree graph wants small edge tiles (or coarser output blocks) so
tiles are not mostly padding; a heavy-tailed graph wants large tiles so
hub blocks take few grid steps.  Gunrock-style frameworks win their
speedups from exactly this per-workload kernel-parameter selection.

Three entry points, cheapest first:

- :func:`suggest_plan` — zero-measurement heuristic from
  :func:`degree_features`; what autotune-off-but-degree-aware callers
  (``run(..., autotune="heuristic")``) use.
- :func:`tune` — the empirical sweep: benchmark a candidate grid of
  :class:`~repro.kernels.segment_reduce.TilingPlan` points (pruned by
  the degree features so the sweep stays cheap; the static default is
  always one candidate) and return the fastest measured plan.
- :func:`autotune_plan` — :func:`tune` wrapped in two cache layers:
  the process-wide :data:`~repro.core.plan_cache.PLAN_CACHE` under
  ``kind="tuned_tiling"`` (keyed by graph identity, edge order, reduce
  kind, dtype, feature width, mode and — for the gathered order — the
  slice capacity) and a **disk** cache
  (``results/autotune_cache.json``, keyed by the quantized
  :func:`degree_signature` so structurally similar graphs hit warm).
  Sweeps and repeat serving traffic therefore never re-tune.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_reduce import (DEFAULT_PLAN,
                                          BlockedSegmentReducer, TilingPlan,
                                          gathered_segment_reduce)

__all__ = [
    "degree_features", "degree_signature", "candidate_plans", "suggest_plan",
    "build_reducer", "measure_plan", "tune", "autotune_plan", "TuneResult",
    "load_disk_cache", "store_disk_entry", "persist_tune_result",
    "DEFAULT_CACHE_PATH",
]

#: Where tuned plans persist across processes (CI uploads it alongside
#: the benchmark artifact).
DEFAULT_CACHE_PATH = "results/autotune_cache.json"

#: Edge orders the blocked reducer serves; "gathered" is the sparse
#: frontier path whose only tunable is ``gather_splits``.
ORDERS = ("owned", "pull", "gathered")

_MIN_TILE = 128
_MAX_TILE = 4096


def _default_cap_e(n_edges: int) -> int:
    """The executor's default sparse-gather capacity for this edge count
    (same formula as ``EdgeContext.default_sparse_capacity``)."""
    # deferred: repro.core's package __init__ imports the executor,
    # which imports this module — cyclic at module-import time
    from repro.core.frontier import ALPHA
    return min(n_edges, max(16, -(-n_edges // int(ALPHA))))


# ---------------------------------------------------------------------------
# degree-distribution features and their quantized signature
# ---------------------------------------------------------------------------
def degree_features(graph) -> Dict[str, float]:
    """Degree-distribution features that steer candidate pruning.

    Per-*block* edge counts (``diff(block_ptr)``) matter most: both
    block-binned orders (owned and CSC/pull) bin edges by destination
    block, so the same counts describe either order's tiling problem.
    Headline degree stats (mean/p95 out-degree, skew, n/m) ride along
    for the signature and the heuristic.
    """
    deg = np.asarray(graph.out_degree, np.float64)
    per_block = np.diff(np.asarray(graph.block_ptr, np.int64)).astype(
        np.float64)
    mean_deg = float(deg.mean()) if deg.size else 0.0
    std_deg = float(deg.std()) if deg.size else 0.0
    return {
        "n_nodes": int(graph.n_nodes),
        "n_edges": int(graph.n_edges),
        "block_size": int(graph.block_size),
        "n_blocks": int(per_block.size),
        "mean_out_degree": mean_deg,
        "p95_out_degree": float(np.percentile(deg, 95)) if deg.size else 0.0,
        "max_out_degree": float(deg.max()) if deg.size else 0.0,
        # coefficient of variation: ~0 for regular graphs, >1 heavy tail
        "degree_skew": std_deg / mean_deg if mean_deg else 0.0,
        "nm_ratio": graph.n_nodes / max(graph.n_edges, 1),
        "mean_edges_per_block": float(per_block.mean())
        if per_block.size else 0.0,
        "p95_edges_per_block": float(np.percentile(per_block, 95))
        if per_block.size else 0.0,
        "max_edges_per_block": float(per_block.max())
        if per_block.size else 0.0,
    }


def _log2_bucket(x: float) -> int:
    return int(round(math.log2(x))) if x > 0 else 0


def degree_signature(graph_or_features) -> str:
    """Quantized feature key for the disk cache.

    Log2-bucketed sizes and degree shape: graphs of the same generator
    family and scale quantize to the same signature, so a tuned plan
    warms structurally similar graphs without an exact-graph match.
    """
    f = (graph_or_features if isinstance(graph_or_features, dict)
         else degree_features(graph_or_features))
    return (f"v{_log2_bucket(f['n_nodes'])}"
            f"e{_log2_bucket(f['n_edges'])}"
            f"b{int(f['block_size'])}"
            f"d{_log2_bucket(max(f['mean_out_degree'], 1.0))}"
            f"p{_log2_bucket(max(f['p95_out_degree'], 1.0))}"
            f"s{_log2_bucket(1.0 + f['degree_skew'])}")


# ---------------------------------------------------------------------------
# candidate grid (degree-pruned) and the zero-measurement heuristic
# ---------------------------------------------------------------------------
def _pow2_clamp(x: float, lo: int, hi: int) -> int:
    x = max(float(x), 1.0)
    return int(min(max(2 ** round(math.log2(x)), lo), hi))


def _coarsening(feats: Dict[str, float]) -> int:
    """Largest useful output-block coarsening for these block counts.

    Coarsen while typical blocks underfill the smallest tile and at
    least two coarse blocks remain (one block means no revisit
    structure left to exploit).
    """
    mult = 1
    epb = max(feats["mean_edges_per_block"], 1.0)
    while (mult < 8 and feats["n_blocks"] // (mult * 2) >= 2
           and epb * mult < _MIN_TILE):
        mult *= 2
    return mult


def candidate_plans(graph=None, features: Optional[Dict[str, float]] = None,
                    order: str = "owned", max_candidates: int = 6,
                    cap_e: Optional[int] = None) -> Tuple[TilingPlan, ...]:
    """The degree-pruned candidate grid; the static default comes first.

    For the blocked orders the grid spans ``tile_e`` powers of two from
    half the mean per-(coarse-)block edge count up to the p95 block
    (clamped to [128, 4096]) × block coarsening {1, best}; tiles far
    above the p95 block are pure padding and tiles far below the mean
    multiply grid steps, so neither is swept.  The "gathered" order's
    only tunable is the scatter split count, pruned against ``cap_e``
    — the slice capacity the plan will actually be measured at and
    serve (defaults to the executor's default capacity).
    """
    feats = features if features is not None else degree_features(graph)
    if order == "gathered":
        cands = [DEFAULT_PLAN]
        cap = int(cap_e) if cap_e else _default_cap_e(int(feats["n_edges"]))
        for splits in (2, 4):
            if cap // splits >= 256:  # tiny slices: splitting is all overhead
                cands.append(dataclasses.replace(
                    DEFAULT_PLAN, gather_splits=splits, source="candidate"))
        return tuple(cands[:max_candidates])

    plans: List[TilingPlan] = [DEFAULT_PLAN]

    def add(**kw):
        p = TilingPlan(source="candidate", **kw)
        if p.astuple() not in {q.astuple() for q in plans}:
            plans.append(p)

    epb = max(feats["mean_edges_per_block"], 1.0)
    if order == "pull":
        # The CSC order is fully dst-sorted, so output blocks may be
        # *refined* below the base block size — smaller blocks shrink
        # every tile's scatter footprint.  Tile sizes track the
        # refined per-block edge count.  Refinement candidates come
        # first (deepest first): they are the reliable winners, so
        # they survive aggressive ``max_candidates`` truncation
        # (e.g. the CI smoke job's 2-candidate grid).
        for div in (4, 2):
            eff_bs = feats["block_size"] // div
            if eff_bs < 32 or feats["n_nodes"] // eff_bs < 2:
                continue
            sub_epb = epb / div
            for t in sorted({_pow2_clamp(sub_epb / 2, _MIN_TILE, 1024),
                             _pow2_clamp(sub_epb, _MIN_TILE, 1024)}):
                add(tile_e=t, block_div=div)
        if epb > 4 * DEFAULT_PLAN.tile_e:
            add(tile_e=_pow2_clamp(epb / 2, _MIN_TILE, _MAX_TILE))
        return tuple(plans[:max_candidates])

    # owned order: binned only at base-block granularity, so the grid
    # sweeps tile_e (mean/2 .. p95 per coarse block) x coarsening
    mults = [1]
    best_mult = _coarsening(feats)
    if best_mult > 1:
        mults.append(best_mult)
    lo = max(epb / 2, _MIN_TILE)
    hi = max(feats["p95_edges_per_block"], lo)
    for mult in mults:
        t = _pow2_clamp(lo * mult, _MIN_TILE, _MAX_TILE)
        t_hi = _pow2_clamp(hi * mult, _MIN_TILE, _MAX_TILE)
        while True:
            add(tile_e=t, block_mult=mult)
            if t >= t_hi:
                break
            t *= 2
    return tuple(plans[:max_candidates])


def suggest_plan(features: Dict[str, float],
                 order: str = "owned") -> TilingPlan:
    """Zero-measurement heuristic plan from degree features.

    Used by ``autotune="heuristic"`` runs (and as the tuner's fallback
    when measurement is disabled).  Owned order: size one edge tile to
    cover a typical (coarse) block, stretched toward the p95 block on
    heavy-tailed graphs so hub blocks take few grid steps.  Pull/CSC
    order: refine output blocks to the smallest size with healthy
    per-block edge counts — a sorted order pays nothing for finer
    blocks, and every tile's scatter footprint shrinks with them.  The
    gathered path has no degree model; it keeps its default.
    """
    if order == "gathered":
        return DEFAULT_PLAN
    epb = max(features["mean_edges_per_block"], 1.0)
    if order == "pull":
        div = 1
        while (div < 4 and features["block_size"] // (div * 2) >= 64
               and features["n_nodes"] // (features["block_size"]
                                           // (div * 2)) >= 2):
            div *= 2
        if div == 1:
            return dataclasses.replace(DEFAULT_PLAN, source="heuristic")
        return TilingPlan(
            tile_e=_pow2_clamp(epb / div, _MIN_TILE, 1024),
            block_div=div, source="heuristic")
    mult = _coarsening(features)
    target = epb * mult
    if features["degree_skew"] > 1.0:
        target = max(target, features["p95_edges_per_block"] * mult / 2)
    return TilingPlan(tile_e=_pow2_clamp(target, _MIN_TILE, _MAX_TILE),
                      block_mult=mult, source="heuristic")


# ---------------------------------------------------------------------------
# reducer construction + measurement
# ---------------------------------------------------------------------------
def build_reducer(graph, order: str, plan: Optional[TilingPlan] = None,
                  interpret: bool = True) -> BlockedSegmentReducer:
    """Build the blocked reducer for one edge order under ``plan``.

    The single construction path shared by the executor and the tuner,
    so a tuned plan is realised identically in both.  ``order`` is
    "owned" (dst-block-binned by-src order — the DeNovo push path) or
    "pull" (CSC order, trivially dst-block-binned).
    """
    v = int(graph.n_nodes)
    if order == "owned":
        dst_owned = np.asarray(graph.dst)[np.asarray(graph.perm_owned)]
        return BlockedSegmentReducer.from_plan(
            dst_owned, np.asarray(graph.block_ptr), v, graph.block_size,
            plan, interpret=interpret)
    if order == "pull":
        # The CSC order is fully dst-sorted, so it is binned under ANY
        # block partition — the plan's effective block size (coarsened
        # or refined) is realised directly by sampling the per-vertex
        # row offsets at its block bounds.
        plan = plan if plan is not None else DEFAULT_PLAN
        eff_bs = plan.block_size(graph.block_size)
        n_blocks = -(-v // eff_bs)
        bounds = np.minimum(np.arange(n_blocks + 1) * eff_bs, v)
        pull_ptr = np.asarray(graph.row_ptr_in)[bounds]
        return BlockedSegmentReducer(
            np.asarray(graph.dst_in), pull_ptr, v, eff_bs,
            tile_e=plan.tile_e, interpret=interpret, plan=plan)
    raise ValueError(f"unknown blocked order {order!r}")


def _bench(fn, args, repeats: int) -> float:
    jax.block_until_ready(fn(*args))  # warmup/compile outside the timing
    best = math.inf
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_plan(graph, plan: TilingPlan, order: str = "owned",
                 kind: str = "mixed", dtype=jnp.float32, d: int = 1,
                 repeats: int = 3, cap_e: Optional[int] = None) -> float:
    """Best-of-``repeats`` seconds for one reduction under ``plan``.

    Values are seeded random, identical across candidates of one sweep
    (same shape/dtype), so measured deltas are tiling deltas.

    ``kind="mixed"`` times one sum **plus** one min per call — the
    balanced objective the executor tunes with, since a bound reducer
    serves whatever monoids the program's phases use (BFS/SSSP pull
    mins through the same instance BC/PR push sums through) and the
    MXU sum kernel and VPU min/max kernel scale differently with the
    tiling.
    """
    rng = np.random.default_rng(0)
    dtype = jnp.dtype(dtype)
    kinds = ("sum", "min") if kind == "mixed" else (kind,)
    if order == "gathered":
        cap = int(cap_e) if cap_e else _default_cap_e(int(graph.n_edges))
        ids_np = np.asarray(graph.dst)[
            rng.integers(0, max(graph.n_edges, 1), cap)].astype(np.int32)
        ids_np[rng.random(cap) < 0.1] = -1  # padding/masked slots
        shape = (cap,) if d == 1 else (cap, d)
        vals = jnp.asarray(rng.standard_normal(shape).astype(dtype))
        ids = jnp.asarray(ids_np)
        fn = jax.jit(lambda v, i: tuple(
            gathered_segment_reduce(v, i, graph.n_nodes, k, plan=plan)
            for k in kinds))
        return _bench(fn, (vals, ids), repeats)
    red = build_reducer(graph, order, plan)
    shape = (graph.n_edges,) if d == 1 else (graph.n_edges, d)
    vals = jnp.asarray(rng.standard_normal(shape).astype(dtype))
    # jitted like the executor's step: the value gather/mask fuse with
    # the kernel call, so candidates are ranked under the execution
    # semantics production actually runs (eager per-op dispatch would
    # overweight grid-step count)
    fn = jax.jit(lambda v: tuple(red.reduce(v, k) for k in kinds))
    return _bench(fn, (vals,), repeats)


# ---------------------------------------------------------------------------
# disk persistence (degree-signature keyed)
# ---------------------------------------------------------------------------
def _disk_key(sig: str, order: str, kind: str, dtype, d: int,
              cap_e: Optional[int] = None) -> str:
    # cap_e participates for the gathered order: its split winner is
    # measured against a specific slice capacity, so a plan tuned at
    # one capacity must not serve a different one (0 = blocked orders,
    # which have no capacity axis)
    return (f"{sig}|{order}|{kind}|{jnp.dtype(dtype).name}|{int(d)}"
            f"|c{int(cap_e or 0)}")


def load_disk_cache(path=DEFAULT_CACHE_PATH) -> Dict[str, dict]:
    """The persisted ``{disk_key: plan-entry}`` map ({} if absent/bad)."""
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return {}
    entries = data.get("entries") if isinstance(data, dict) else None
    return entries if isinstance(entries, dict) else {}


def store_disk_entry(key: str, entry: dict,
                     path=DEFAULT_CACHE_PATH) -> None:
    """Merge one tuned entry into the JSON cache (atomic replace)."""
    path = Path(path)
    entries = load_disk_cache(path)
    entries[key] = entry
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(
        {"version": 1, "entries": entries}, indent=2, sort_keys=True))
    os.replace(tmp, path)


def persist_tune_result(result: "TuneResult", dtype=jnp.float32, d: int = 1,
                        cap_e: Optional[int] = None,
                        cache_path=...) -> str:
    """Persist one sweep's winner as the disk entry ``autotune_plan``
    recalls (same key derivation), returning that key.

    Lets a caller that already ran :func:`tune` (e.g. the benchmark,
    which records the sweep's raw measurements) seed the cache instead
    of paying a second identical sweep inside :func:`autotune_plan`.
    """
    if cache_path is ...:
        cache_path = DEFAULT_CACHE_PATH
    dkey = _disk_key(result.signature, result.order, result.kind, dtype, d,
                     cap_e)
    if cache_path is None:
        return dkey
    tile_e, block_mult, block_div, gather_splits = result.plan.astuple()
    store_disk_entry(dkey, {
        "tile_e": tile_e, "block_mult": block_mult,
        "block_div": block_div, "gather_splits": gather_splits,
        "order": result.order, "kind": result.kind,
        "signature": result.signature,
        "best_us": (result.best_seconds or 0.0) * 1e6,
        "default_us": (result.default_seconds or 0.0) * 1e6,
        "n_candidates": len(result.measurements),
    }, path=cache_path)
    return dkey


def _plan_from_entry(entry: dict) -> Optional[TilingPlan]:
    try:
        return TilingPlan(tile_e=int(entry["tile_e"]),
                          block_mult=int(entry["block_mult"]),
                          block_div=int(entry.get("block_div", 1)),
                          gather_splits=int(entry["gather_splits"]),
                          source="disk")
    except (KeyError, TypeError, ValueError):
        return None


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TuneResult:
    """What one empirical sweep measured (or recalled)."""
    plan: TilingPlan
    order: str
    kind: str
    signature: str
    #: ``[(plan, best_seconds)]`` per candidate; empty on a disk hit.
    measurements: Tuple[Tuple[TilingPlan, float], ...] = ()
    from_disk: bool = False

    @property
    def default_seconds(self) -> Optional[float]:
        for p, s in self.measurements:
            if p.astuple() == DEFAULT_PLAN.astuple():
                return s
        return None

    @property
    def best_seconds(self) -> Optional[float]:
        return min((s for _, s in self.measurements), default=None)

    @property
    def plan_seconds(self) -> Optional[float]:
        """Measured seconds of the *chosen* plan (the margin rule may
        keep the default even when a candidate measured faster)."""
        for p, s in self.measurements:
            if p.astuple() == self.plan.astuple():
                return s
        return None

    @property
    def speedup_vs_default(self) -> Optional[float]:
        """default/chosen — what binding this result's plan actually
        buys, exactly 1.0 when the margin rule kept the default (a
        within-noise raw best would otherwise overclaim)."""
        d, c = self.default_seconds, self.plan_seconds
        return d / c if d and c else None


def tune(graph, order: str = "owned", kind: str = "mixed", dtype=jnp.float32,
         d: int = 1, repeats: int = 3, max_candidates: int = 6,
         cap_e: Optional[int] = None,
         candidates: Optional[Sequence[TilingPlan]] = None,
         margin: float = 0.02) -> TuneResult:
    """Empirically sweep the candidate grid; fastest measured plan wins.

    The default plan is always swept, so on the tuner's own
    measurements the winner is never slower than the static tiling.
    A non-default candidate must additionally beat the default by more
    than ``margin`` (relative) to displace it — measurement-noise ties
    stay on the default plan rather than churning the cached/persisted
    plan for a within-noise "win".
    """
    feats = degree_features(graph)
    cands = tuple(candidates) if candidates is not None else candidate_plans(
        features=feats, order=order, max_candidates=max_candidates,
        cap_e=cap_e)
    measured = []
    for plan in cands:
        secs = measure_plan(graph, plan, order=order, kind=kind, dtype=dtype,
                            d=d, repeats=repeats, cap_e=cap_e)
        measured.append((plan, secs))
    best_plan, best_secs = min(measured, key=lambda ps: ps[1])
    default_secs = next((s for p, s in measured
                         if p.astuple() == DEFAULT_PLAN.astuple()), None)
    if (default_secs is not None
            and default_secs <= best_secs * (1.0 + margin)):
        best_plan = DEFAULT_PLAN
    if best_plan.astuple() != DEFAULT_PLAN.astuple():
        best_plan = dataclasses.replace(best_plan, source="tuned")
    return TuneResult(plan=best_plan, order=order, kind=kind,
                      signature=degree_signature(feats),
                      measurements=tuple(measured))


def autotune_plan(graph, order: str = "owned", kind: str = "mixed",
                  dtype=jnp.float32, d: int = 1, mode: str = "measure",
                  repeats: int = 3, max_candidates: int = 6,
                  cap_e: Optional[int] = None,
                  cache_path=...) -> TilingPlan:
    """The cached tuner the executor calls.

    Resolution order: process-wide ``PLAN_CACHE`` (``tuned_tiling``
    entry keyed by graph identity + (order, kind, dtype, d, mode)) →
    disk cache (``cache_path``, keyed by :func:`degree_signature`) →
    empirical :func:`tune` sweep, whose winner is persisted to disk.
    ``mode="heuristic"`` skips both measurement and disk and returns
    :func:`suggest_plan` (still process-cached).

    ``cache_path`` defaults to the *current* :data:`DEFAULT_CACHE_PATH`
    (resolved at call time, so tests can repoint it); pass ``None`` to
    disable disk persistence entirely.
    """
    if cache_path is ...:
        cache_path = DEFAULT_CACHE_PATH
    if mode not in ("heuristic", "measure"):
        raise ValueError(f"unknown autotune mode {mode!r}; "
                         "expected 'heuristic' or 'measure'")
    # deferred: repro.core's package __init__ imports the executor,
    # which imports this module — a module-level import would be cyclic
    from repro.core.plan_cache import PLAN_CACHE
    # cache_path participates in the key so alternate caches (tests,
    # ad-hoc sweeps) can't serve each other's plans for one live graph;
    # cap_e because a gathered plan is only valid for the capacity it
    # was measured at
    key = (order, kind, jnp.dtype(dtype).name, int(d), mode,
           str(cache_path), int(cap_e or 0))

    def build() -> TilingPlan:
        if mode == "heuristic":
            return suggest_plan(degree_features(graph), order=order)
        sig = degree_signature(graph)
        dkey = _disk_key(sig, order, kind, dtype, d, cap_e)
        if cache_path is not None:
            plan = _plan_from_entry(load_disk_cache(cache_path).get(dkey, {}))
            if plan is not None:
                return plan
        result = tune(graph, order=order, kind=kind, dtype=dtype, d=d,
                      repeats=repeats, max_candidates=max_candidates,
                      cap_e=cap_e)
        try:
            persist_tune_result(result, dtype=dtype, d=d, cap_e=cap_e,
                                cache_path=cache_path)
        except OSError:
            # The disk cache is an optimization: a fresh checkout
            # creates results/ on first write (store_disk_entry mkdirs
            # defensively), but an unwritable path — e.g. "results"
            # existing as a plain file, or a read-only serving image —
            # must cost the persistence, never the run.
            pass
        return result.plan

    return PLAN_CACHE.get(graph, "tuned_tiling", key, build)
