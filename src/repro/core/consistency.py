"""Consistency dimension: schedule freedom of the update stream (DESIGN.md §2).

Given a per-chunk reduction ``chunk_reduce(chunk_idx) -> [V'] partial``:

- **DRF0**  — one monolithic reduction; a hard phase boundary (the GPU's
  full L1 invalidate/flush at every synchronization).
- **DRF1**  — ordered chunk pipeline via ``lax.scan``: chunk *k*'s gather/
  compute overlaps chunk *k-1*'s accumulate, but partial accumulation is
  ordered with respect to itself (data may reorder w.r.t. unpaired sync,
  sync stays ordered w.r.t. sync).
- **DRFrlx** — independent partial reductions (vmapped) followed by a
  commutative tree-combine: the chunks may complete in any order, the MLP
  the paper gets from relaxed atomics.

All three are mathematically identical because the monoid is commutative-
associative — exactly the property that makes relaxed atomics legal for
these workloads.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.config_space import Consistency
from repro.core.vertex_program import Monoid

__all__ = ["scheduled_reduce"]


def scheduled_reduce(chunk_reduce: Callable[[int], jnp.ndarray],
                     n_chunks: int, consistency: Consistency,
                     monoid: Monoid) -> jnp.ndarray:
    """Combine ``n_chunks`` partial reductions under a consistency model."""
    if consistency is Consistency.DRF0 or n_chunks == 1:
        # chunk_reduce must have been built with a single chunk.
        return chunk_reduce(0)

    if consistency is Consistency.DRF1:
        def body(carry, idx):
            return monoid.combine(carry, chunk_reduce(idx)), None
        first = chunk_reduce(0)
        out, _ = jax.lax.scan(body, first, jnp.arange(1, n_chunks))
        return out

    # DRFrlx: all partials independent, then reorderable combine.
    partials = jax.vmap(chunk_reduce)(jnp.arange(n_chunks))  # [C, V']
    if monoid.name == "sum":
        return jnp.sum(partials, axis=0)
    if monoid.name == "min":
        return jnp.min(partials, axis=0)
    return jnp.max(partials, axis=0)
