"""Learned best-config specialization (paper Sec. IV, the *predictive*
half of the specialization contribution).

``core/model.py`` carries the paper's static prose decision trees; this
module learns the same mapping — workload features to the best
(push/pull/dynamic x coherence x consistency) :class:`SystemConfig` —
from the measured 36-workload matrix the repo already produces
(``results/BENCH_matrix.json``).  The scorer is a small CART-style
decision tree fit in pure numpy (no new dependencies), serialized to a
versioned JSON model file (:data:`DEFAULT_MODEL_PATH`) that serving
loads lazily.

Features are exactly what is computable at **admission time** — before
the workload has run — so the same vector feeds training (from the
matrix artifact's ``inputs`` records) and serving (from the live graph
via :func:`repro.graph.datasets.degree_profile`):

- graph shape: log2 |V|, log2 |E|, log2 avg-degree, out-degree
  coefficient of variation (the autotuner's ``degree_skew``),
- the :data:`~repro.graph.datasets.DEGREE_PROFILES` class one-hot
  (near-regular / social / web-crawl),
- the app's Table III :class:`AlgorithmicProperties` one-hots
  (traversal, control locus, information locus).

The matrix's per-iteration direction/occupancy traces (Fig. 5) are
*label-side* signal: they are recorded per training workload in the
model file's diagnostics and drive the optional trace-augmented
ablation model (:func:`fit_matrix` with ``trace_features=True``, an
upper bound reported by ``benchmarks/specialize.py``), but the serving
model never depends on them — at admission time no trace exists yet.

Serving resolution (:func:`resolve_config`) implements the fallback
chain **learned -> static partial model -> caller config**: a missing,
corrupt or version-skewed model file degrades to the Sec. IV-B static
partial tree with a structured :class:`SpecializeFallbackWarning`
(never a crash), and a workload without Table III properties keeps the
caller's config.  Decisions are cached twice: per graph *identity* in
:data:`~repro.core.plan_cache.PLAN_CACHE` under
``kind="specialized_config"`` (next to ``tuned_tiling``), and per
quantized :func:`~repro.kernels.autotune.degree_signature` in a
process-wide memo so a fresh graph that quantizes like one already
seen inherits its decision without re-extracting features.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import warnings
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.config_space import SystemConfig, UpdateProp
from repro.core.model import specialize, specialize_partial
from repro.core.plan_cache import PLAN_CACHE
from repro.core.properties import TABLE_III, AlgorithmicProperties, Locus, \
    Traversal
from repro.core.taxonomy import profile_graph

__all__ = [
    "DEFAULT_MODEL_PATH", "MODEL_FORMAT", "MODEL_VERSION",
    "FEATURES", "TRACE_FEATURES",
    "SpecializeFallbackWarning", "ModelFileError",
    "LearnedSpecializer", "WorkloadRecord",
    "features_from_graph", "features_from_input", "training_table",
    "fit_matrix", "load_model", "save_model",
    "project_config", "static_config_for", "resolve_config",
    "memo_stats", "clear_memo",
]

#: Where the serving model persists (CI uploads it with the benchmark
#: artifact; ``benchmarks/specialize.py`` refreshes it — see
#: docs/SPECIALIZATION.md "Refreshing the model file").
DEFAULT_MODEL_PATH = "results/specialize_model.json"
MODEL_FORMAT = "repro-specialize-model"
MODEL_VERSION = 1

#: Admission-time feature vector, in serialized order.  Training and
#: serving must agree on this list; the model file pins its own copy
#: and :func:`load_model` rejects a mismatch.
FEATURES = (
    "log2_nodes", "log2_edges", "log2_avg_degree", "degree_skew",
    "profile_near_regular", "profile_social", "profile_web_crawl",
    "trav_dynamic",
    "ctrl_source", "ctrl_target", "ctrl_symmetric",
    "info_source", "info_target", "info_symmetric",
)

#: Trace-derived features (training-time ablation only — see module
#: docstring): fraction of pull iterations and of sparse-gathered
#: iterations in the matrix's first dynamic cell for the workload.
TRACE_FEATURES = ("dyn_pull_frac", "dyn_sparse_frac")

_PROFILES = ("near-regular", "social", "web-crawl")


class SpecializeFallbackWarning(UserWarning):
    """A specialization tier was unavailable and a lower tier served the
    decision.  The message carries a structured ``code=`` prefix
    (``model_missing`` / ``model_corrupt`` / ``no_properties`` /
    ``predict_failed``)."""


class ModelFileError(ValueError):
    """The model file exists but cannot serve predictions."""

    def __init__(self, code: str, detail: str):
        self.code = code
        super().__init__(f"{code}: {detail}")


# ---------------------------------------------------------------------------
# feature extraction
# ---------------------------------------------------------------------------
def _props_onehots(props: AlgorithmicProperties) -> Dict[str, float]:
    return {
        "trav_dynamic": 1.0 if props.traversal is Traversal.DYNAMIC else 0.0,
        "ctrl_source": 1.0 if props.control is Locus.SOURCE else 0.0,
        "ctrl_target": 1.0 if props.control is Locus.TARGET else 0.0,
        "ctrl_symmetric": 1.0 if props.control is Locus.SYMMETRIC else 0.0,
        "info_source": 1.0 if props.information is Locus.SOURCE else 0.0,
        "info_target": 1.0 if props.information is Locus.TARGET else 0.0,
        "info_symmetric": 1.0 if props.information is Locus.SYMMETRIC
        else 0.0,
    }


def _shape_features(n_nodes: int, n_edges: int, degree_skew: float,
                    profile: str) -> Dict[str, float]:
    n, m = max(int(n_nodes), 1), max(int(n_edges), 1)
    feats = {
        "log2_nodes": math.log2(n),
        "log2_edges": math.log2(m),
        "log2_avg_degree": math.log2(max(m / n, 1e-6)),
        "degree_skew": float(degree_skew),
    }
    for p in _PROFILES:
        feats[f"profile_{p.replace('-', '_')}"] = 1.0 if profile == p else 0.0
    return feats


def features_from_input(props: AlgorithmicProperties,
                        input_record: Dict[str, Any]) -> Dict[str, float]:
    """Feature dict from a matrix artifact's ``inputs[name]`` record."""
    return {**_shape_features(input_record["n_nodes"],
                              input_record["n_edges"],
                              input_record["degree_skew"],
                              input_record["profile"]),
            **_props_onehots(props)}


def features_from_graph(props: AlgorithmicProperties,
                        graph) -> Dict[str, float]:
    """Admission-time feature dict from a live graph (same vector the
    trainer derives from the matrix artifact)."""
    from repro.graph.datasets import degree_profile
    prof = degree_profile(graph)
    return {**_shape_features(prof["n_nodes"], prof["n_edges"],
                              prof["degree_skew"], prof["profile"]),
            **_props_onehots(props)}


def _vector(feats: Dict[str, float], names: Sequence[str]) -> np.ndarray:
    return np.asarray([float(feats.get(n, 0.0)) for n in names], np.float64)


# ---------------------------------------------------------------------------
# pure-numpy CART (gini) — deterministic: first strictly-better split wins
# ---------------------------------------------------------------------------
def _gini(counts: np.ndarray) -> float:
    tot = counts.sum()
    if tot == 0:
        return 0.0
    p = counts / tot
    return float(1.0 - np.sum(p * p))


def _fit_tree(X: np.ndarray, y: np.ndarray, n_classes: int,
              max_depth: int, min_leaf: int, depth: int = 0) -> dict:
    counts = np.bincount(y, minlength=n_classes)
    leaf = {"counts": counts.tolist()}
    if (depth >= max_depth or counts.max() == y.size
            or y.size < 2 * min_leaf):
        return leaf
    parent = _gini(counts)
    best: Optional[Tuple[float, int, float]] = None  # (impurity, j, thr)
    for j in range(X.shape[1]):
        vals = np.unique(X[:, j])
        if vals.size < 2:
            continue
        for thr in (vals[:-1] + vals[1:]) / 2.0:
            mask = X[:, j] <= thr
            nl, nr = int(mask.sum()), int((~mask).sum())
            if nl < min_leaf or nr < min_leaf:
                continue
            imp = (nl * _gini(np.bincount(y[mask], minlength=n_classes))
                   + nr * _gini(np.bincount(y[~mask], minlength=n_classes))
                   ) / y.size
            if best is None or imp < best[0] - 1e-12:
                best = (imp, j, float(thr))
    if best is None or best[0] >= parent - 1e-12:
        return leaf
    _, j, thr = best
    mask = X[:, j] <= thr
    return {"feature": int(j), "threshold": thr,
            "left": _fit_tree(X[mask], y[mask], n_classes, max_depth,
                              min_leaf, depth + 1),
            "right": _fit_tree(X[~mask], y[~mask], n_classes, max_depth,
                               min_leaf, depth + 1)}


def _tree_predict(node: dict, x: np.ndarray) -> int:
    while "feature" in node:
        node = node["left"] if x[node["feature"]] <= node["threshold"] \
            else node["right"]
    return int(np.argmax(node["counts"]))  # ties -> lowest class index


def _tree_depth(node: dict) -> int:
    if "feature" not in node:
        return 0
    return 1 + max(_tree_depth(node["left"]), _tree_depth(node["right"]))


def _tree_leaves(node: dict) -> int:
    if "feature" not in node:
        return 1
    return _tree_leaves(node["left"]) + _tree_leaves(node["right"])


# ---------------------------------------------------------------------------
# the model object + (de)serialization
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LearnedSpecializer:
    """A trained best-config predictor: feature order, class (config
    name) vocabulary, and the fitted tree."""
    features: Tuple[str, ...]
    classes: Tuple[str, ...]
    tree: dict
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def predict_name(self, feats: Dict[str, float]) -> str:
        """Predicted config *name* for one feature dict."""
        return self.classes[_tree_predict(self.tree,
                                          _vector(feats, self.features))]

    def predict(self, props: AlgorithmicProperties, graph,
                n_chunks: int = 8) -> SystemConfig:
        """Predicted :class:`SystemConfig` for a live workload."""
        name = self.predict_name(features_from_graph(props, graph))
        return SystemConfig.from_name(name, n_chunks=n_chunks)

    def to_json(self) -> dict:
        return {"format": MODEL_FORMAT, "version": MODEL_VERSION,
                "features": list(self.features),
                "classes": list(self.classes),
                "tree": self.tree,
                "depth": _tree_depth(self.tree),
                "n_leaves": _tree_leaves(self.tree),
                "meta": self.meta}

    @classmethod
    def from_json(cls, data: Any) -> "LearnedSpecializer":
        if not isinstance(data, dict):
            raise ModelFileError("model_corrupt", "not a JSON object")
        if data.get("format") != MODEL_FORMAT:
            raise ModelFileError(
                "model_corrupt", f"format {data.get('format')!r} != "
                f"{MODEL_FORMAT!r}")
        if data.get("version") != MODEL_VERSION:
            raise ModelFileError(
                "model_version", f"model version {data.get('version')!r} "
                f"!= supported {MODEL_VERSION}")
        try:
            feats = tuple(str(f) for f in data["features"])
            classes = tuple(str(c) for c in data["classes"])
            tree = data["tree"]
            for c in classes:
                SystemConfig.from_name(c)  # vocabulary must be decodable
            if not isinstance(tree, dict) or not classes:
                raise KeyError("tree/classes")
        except (KeyError, TypeError, ValueError, IndexError) as exc:
            raise ModelFileError("model_corrupt",
                                 f"bad model payload ({exc!r})") from exc
        return cls(features=feats, classes=classes, tree=tree,
                   meta=data.get("meta", {}))


def save_model(model: LearnedSpecializer, path=DEFAULT_MODEL_PATH) -> str:
    """Serialize with the versioned header (atomic replace); returns
    the path written."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_name(p.name + ".tmp")
    tmp.write_text(json.dumps(model.to_json(), indent=2, sort_keys=True))
    os.replace(tmp, p)
    return str(p)


def load_model(path=DEFAULT_MODEL_PATH) -> LearnedSpecializer:
    """Load + validate a model file.  Raises ``OSError`` when the file
    is absent/unreadable and :class:`ModelFileError` when present but
    unusable (corrupt JSON, wrong format/version, bad payload)."""
    text = Path(path).read_text()
    try:
        data = json.loads(text)
    except ValueError as exc:
        raise ModelFileError("model_corrupt",
                             f"invalid JSON in {path} ({exc})") from exc
    return LearnedSpecializer.from_json(data)


# ---------------------------------------------------------------------------
# training from the matrix artifact
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkloadRecord:
    """One training row distilled from a matrix cell."""
    workload: str           # "<input>/<app>"
    app: str
    input_name: str
    features: Dict[str, float]
    label: str              # measured-best config name
    seconds: Dict[str, float]  # config name -> best-of-repeats seconds
    trace: Dict[str, float]    # TRACE_FEATURES (0.0 when no dynamic cell)


def _trace_features(cell_configs: Dict[str, dict]) -> Dict[str, float]:
    for cname in sorted(cell_configs):
        if not cname.startswith("D"):
            continue
        cell = cell_configs[cname]
        dirs = cell.get("directions") or ""
        its = max(int(cell.get("iterations", 0)), 1)
        if dirs:
            return {"dyn_pull_frac": dirs.count("T") / len(dirs),
                    "dyn_sparse_frac": (cell.get("n_sparse") or 0) / its}
    return {"dyn_pull_frac": 0.0, "dyn_sparse_frac": 0.0}


def training_table(matrix: dict) -> List[WorkloadRecord]:
    """Distill a ``BENCH_matrix.json`` dict into training rows.

    Workloads whose app has no Table III properties are skipped (none
    of the registered apps hit this today).
    """
    rows: List[WorkloadRecord] = []
    inputs = matrix.get("inputs", {})
    for wl, cell in sorted(matrix.get("cells", {}).items()):
        input_name, app = wl.split("/", 1)
        props = TABLE_III.get(app)
        rec = inputs.get(input_name)
        if props is None or rec is None:
            continue
        secs = {c: float(v["seconds"])
                for c, v in cell["configs"].items()}
        rows.append(WorkloadRecord(
            workload=wl, app=app, input_name=input_name,
            features=features_from_input(props, rec),
            label=min(secs, key=secs.get),
            seconds=secs,
            trace=_trace_features(cell["configs"])))
    return rows


def fit_matrix(matrix: dict, max_depth: int = 6, min_leaf: int = 1,
               trace_features: bool = False) -> LearnedSpecializer:
    """Fit the decision-tree scorer against the measured-best cells.

    ``trace_features=True`` appends :data:`TRACE_FEATURES` to the
    vector — the ablation model ``benchmarks/specialize.py`` reports as
    an upper bound; the serving model is always trained without them
    (admission time has no trace).
    """
    rows = training_table(matrix)
    if not rows:
        raise ValueError("matrix artifact has no trainable cells")
    names = FEATURES + (TRACE_FEATURES if trace_features else ())
    classes = tuple(sorted({r.label for r in rows}))
    cls_idx = {c: i for i, c in enumerate(classes)}
    X = np.stack([_vector({**r.features, **r.trace}, names) for r in rows])
    y = np.asarray([cls_idx[r.label] for r in rows], np.int64)
    tree = _fit_tree(X, y, len(classes), max_depth, min_leaf)
    model = LearnedSpecializer(features=names, classes=classes, tree=tree)
    correct = sum(model.predict_name({**r.features, **r.trace}) == r.label
                  for r in rows)
    wl = matrix.get("workload", {})
    model.meta = {
        "trained_on": {
            "n_workloads": len(rows), "smoke": bool(matrix.get("smoke")),
            "configs": wl.get("configs"), "apps": wl.get("apps"),
            "graphs": wl.get("graphs"), "scale": wl.get("scale"),
        },
        "trace_features": bool(trace_features),
        "training_accuracy": correct / len(rows),
        "label_histogram": {c: int(np.sum(y == i))
                            for i, c in enumerate(classes)},
        # label-side trace diagnostics: which workloads' dynamic cell
        # actually mixed directions / ran the sparse path
        "workload_traces": {r.workload: r.trace for r in rows},
    }
    return model


# ---------------------------------------------------------------------------
# static-model helpers shared by serving and evaluation
# ---------------------------------------------------------------------------
def project_config(name: str, available: Sequence[str]) -> str:
    """Project a config name onto an available vocabulary.

    Exact match wins; otherwise the same-direction config closest on
    (coherence, consistency); otherwise the first available name
    (sorted).  Evaluating the 18-cell static trees against a reduced
    (e.g. smoke, 3-config) matrix needs this — the tree may name a
    cell the table never measured.
    """
    avail = sorted(available)
    if name in avail:
        return name
    same_dir = [c for c in avail if c[0] == name[0]]
    if same_dir:
        return min(same_dir, key=lambda c: (c[1] != name[1],
                                            c[2] != name[2], c))
    return avail[0]


def static_config_for(props: AlgorithmicProperties, graph,
                      partial: bool = False) -> SystemConfig:
    """The static tree's choice for a live workload (profiles the graph
    through the Sec. III taxonomy, cached per graph in the plan
    cache)."""
    profile = PLAN_CACHE.get(graph, "graph_profile", (),
                             lambda: profile_graph(graph))
    return (specialize_partial if partial else specialize)(props, profile)


# ---------------------------------------------------------------------------
# serving-time resolution: learned -> static partial -> caller
# ---------------------------------------------------------------------------
_MODEL_CACHE: Dict[Tuple[str, int], LearnedSpecializer] = {}
#: (degree_signature, app, mode, model_tag) -> (config_name, source):
#: lets a *fresh* graph that quantizes like one already decided reuse
#: the decision without feature extraction (the plan cache above it is
#: keyed on graph identity, so it cannot serve this case).
_SIG_MEMO: Dict[tuple, Tuple[str, str]] = {}
_MEMO_LOCK = threading.Lock()
_MEMO_STATS = {"hits": 0, "misses": 0}


def memo_stats() -> Dict[str, int]:
    """Hit/miss counters of the signature-level decision memo."""
    with _MEMO_LOCK:
        return dict(_MEMO_STATS, entries=len(_SIG_MEMO))


def clear_memo() -> None:
    with _MEMO_LOCK:
        _SIG_MEMO.clear()
        _MEMO_STATS.update(hits=0, misses=0)
    _MODEL_CACHE.clear()


def _normalize_specialize(mode) -> str:
    if mode in (None, False, "off"):
        return "off"
    if mode in ("static", "learned"):
        return mode
    raise ValueError(f"unknown specialize mode {mode!r}; expected "
                     "'off', 'static' or 'learned' (or None/False)")


def _current_model(path) -> LearnedSpecializer:
    """Load the model file, cached on (path, mtime) so serving reloads
    automatically after a refresh without re-parsing per admission."""
    p = str(path)
    mtime = os.stat(p).st_mtime_ns
    key = (p, mtime)
    model = _MODEL_CACHE.get(key)
    if model is None:
        model = load_model(p)
        _MODEL_CACHE.clear()  # one live generation per path is plenty
        _MODEL_CACHE[key] = model
    return model


def _model_tag(path) -> tuple:
    try:
        return (str(path), os.stat(str(path)).st_mtime_ns)
    except OSError:
        return (str(path), None)


def _warn(code: str, detail: str) -> None:
    warnings.warn(f"code={code}: {detail}", SpecializeFallbackWarning,
                  stacklevel=3)


def _decide(mode: str, props: AlgorithmicProperties, graph,
            model_path) -> Tuple[str, str]:
    """(config_name, source) for one workload, applying the fallback
    chain.  Never raises: the last tier is unreachable only if the
    static partial tree itself throws, which degrades to the caller."""
    if mode == "static":
        return static_config_for(props, graph, partial=False).name, "static"
    try:
        model = _current_model(model_path)
        return (model.predict_name(features_from_graph(props, graph)),
                "learned")
    except OSError as exc:
        _warn("model_missing",
              f"no readable model at {model_path} ({exc}); falling back "
              "to the static partial tree")
    except ModelFileError as exc:
        _warn(exc.code, f"{exc}; falling back to the static partial tree")
    except Exception as exc:  # noqa: BLE001 — prediction must never crash
        _warn("predict_failed",
              f"learned prediction failed ({exc!r}); falling back to the "
              "static partial tree")
    return static_config_for(props, graph, partial=True).name, \
        "static_partial"


def resolve_config(program, graph, config: SystemConfig, specialize,
                   model_path=None) -> Tuple[SystemConfig, str]:
    """Resolve the config one workload should actually run under.

    ``specialize`` is the serving knob: ``"off"``/``None`` keeps the
    caller's ``config`` (source ``"caller"``); ``"static"`` applies the
    paper's full Fig. 4 tree; ``"learned"`` consults the trained model
    (``model_path``, default :data:`DEFAULT_MODEL_PATH` resolved at
    call time) with the structured fallback chain **learned -> static
    partial -> caller**.  Returns ``(config, source)`` where ``source``
    is ``"caller" | "static" | "static_partial" | "learned"``.

    Decisions are cached in :data:`PLAN_CACHE` under
    ``kind="specialized_config"`` per graph identity, and process-wide
    per degree signature (see :func:`memo_stats`), so repeat admission
    of a same-signature graph never re-extracts features.  The
    predicted config inherits the caller's ``n_chunks``.
    """
    mode = _normalize_specialize(specialize)
    if mode == "off":
        return config, "caller"
    props = getattr(program, "properties", None) \
        if getattr(program, "name", None) in TABLE_III else None
    if props is None:
        _warn("no_properties",
              f"program {getattr(program, 'name', program)!r} has no "
              "Table III properties; keeping the caller's config")
        return config, "caller"
    if model_path is None:
        model_path = DEFAULT_MODEL_PATH
    tag = _model_tag(model_path) if mode == "learned" else ()
    key = (props, mode, tag)

    def build() -> Tuple[str, str]:
        from repro.kernels.autotune import degree_signature
        sig_key = (degree_signature(graph),) + key
        with _MEMO_LOCK:
            hit = _SIG_MEMO.get(sig_key)
            if hit is not None:
                _MEMO_STATS["hits"] += 1
                return hit
            _MEMO_STATS["misses"] += 1
        decision = _decide(mode, props, graph, model_path)
        with _MEMO_LOCK:
            _SIG_MEMO.setdefault(sig_key, decision)
        return decision

    name, source = PLAN_CACHE.get(graph, "specialized_config", key, build)
    return SystemConfig.from_name(name, n_chunks=config.n_chunks), source
