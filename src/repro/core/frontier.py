"""Frontier representation + direction-optimizing push/pull heuristic.

The paper's dynamic update-propagation mode ("D" configs, Table I) lets
the system choose the edge-iteration direction *per iteration* instead of
fixing it for the whole run.  This module supplies the two ingredients:

1. **Frontier representations.**  The canonical device-side form is a
   dense ``[V]`` boolean mask (jit-friendly: fixed shape, no host sync).
   :func:`dense_to_sparse` / :func:`sparse_to_dense` convert to/from a
   padded index list of static capacity for kernels that want the sparse
   (queue-like) view, and :func:`gather_frontier_edges` expands the
   sparse vertex list into the frontier's *edge* list by slicing CSR row
   offsets — the Gunrock-style "advance" primitive that makes a sparse
   iteration cost O(m_f) gathered work instead of an O(E) masked scan.
   Both sparse forms carry the true (pre-truncation) element count so
   callers can detect capacity overflow and fall back to the dense path
   instead of silently dropping work.

2. **The direction heuristic.**  :func:`choose_direction` is the
   Beamer-style (direction-optimizing BFS) rule also used by Gunrock's
   frontier operators:

   - while **pushing**, switch to pull when the frontier's out-edge count
     ``m_f`` grows past the unexplored edge count ``m_u / alpha`` — at
     that point scanning all destinations and pulling from any frontier
     neighbor touches less memory than scattering every frontier edge;
   - while **pulling**, switch back to push when the frontier shrinks
     below ``|V| / beta`` vertices — a sparse frontier makes the
     source-outer scatter cheap again.

   When no monotone "unexplored" set exists (e.g. SSSP re-relaxations can
   reactivate settled vertices), the push->pull trigger falls back to
   frontier edge *density*: pull when ``m_f > |E| / alpha``.

   Everything is a pure function of traced arrays, so the choice runs
   inside jit; :meth:`repro.core.executor.EdgeContext.propagate_dynamic`
   branches on the resulting boolean with ``lax.cond`` between the two
   pre-chunked edge orders.

``ALPHA``/``BETA`` default to the values from Beamer et al. (alpha=14,
beta=24), which transfer well because they are ratios of traffic, not
absolute sizes.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

__all__ = ["ALPHA", "BETA", "frontier_size", "frontier_edges",
           "frontier_density", "choose_direction", "choose_direction_batch",
           "SparseFrontier", "FrontierEdges", "dense_to_sparse",
           "sparse_to_dense", "gather_frontier_edges"]

#: push->pull trigger: pull once frontier out-edges exceed unexplored/ALPHA.
ALPHA = 14.0
#: pull->push trigger: push once the frontier holds fewer than V/BETA nodes.
BETA = 24.0


def frontier_size(mask: jnp.ndarray) -> jnp.ndarray:
    """Number of frontier vertices (``n_f``)."""
    return jnp.sum(mask.astype(jnp.int32))


def frontier_edges(mask: jnp.ndarray, out_degree: jnp.ndarray) -> jnp.ndarray:
    """Number of edges leaving the frontier (``m_f``)."""
    return jnp.sum(jnp.where(mask, out_degree.astype(jnp.int32), 0))


def frontier_density(mask: jnp.ndarray, out_degree: jnp.ndarray,
                     n_edges: int) -> jnp.ndarray:
    """Fraction of all edges that leave the frontier, in [0, 1]."""
    return frontier_edges(mask, out_degree) / jnp.maximum(n_edges, 1)


def choose_direction(mask: jnp.ndarray, out_degree: jnp.ndarray,
                     n_edges: int, n_nodes: int, prev_pull,
                     unvisited: Optional[jnp.ndarray] = None,
                     alpha: float = ALPHA, beta: float = BETA) -> jnp.ndarray:
    """Per-iteration push/pull decision; returns a traced bool (True=pull).

    ``prev_pull`` supplies the hysteresis: the pull->push threshold
    (``n_f < V/beta``) is deliberately lower than where push->pull fired,
    so the direction does not oscillate on a plateauing frontier.
    """
    m_f = frontier_edges(mask, out_degree)
    n_f = frontier_size(mask)
    if unvisited is None:
        to_pull = m_f * alpha > n_edges
    else:
        m_u = frontier_edges(unvisited, out_degree)
        to_pull = m_f * alpha > m_u
    to_push = n_f * beta < n_nodes
    prev_pull = jnp.asarray(prev_pull, bool)
    return jnp.where(prev_pull, ~to_push, to_pull)


def choose_direction_batch(mask: jnp.ndarray, out_degree: jnp.ndarray,
                           n_edges: jnp.ndarray, n_nodes: jnp.ndarray,
                           prev_pull, unvisited: Optional[jnp.ndarray] = None,
                           alpha: float = ALPHA,
                           beta: float = BETA) -> jnp.ndarray:
    """Row-wise :func:`choose_direction` for a batch of packed graphs.

    ``mask``/``out_degree``/``unvisited`` are ``[B, n_q]`` per-graph rows
    (graph g padded to the bucket width ``n_q``; padding columns must be
    False in ``mask``/``unvisited``), ``n_edges``/``n_nodes`` are ``[B]``
    *true* per-graph sizes and ``prev_pull`` the ``[B]`` hysteresis
    flags.  Returns ``[B]`` bools (True=pull).

    Every row reproduces the scalar heuristic bit for bit: the frontier
    statistics are the same int32 sums (restricted to the graph's own
    columns), and the ``m_f * alpha > ...`` comparisons promote to
    float32 exactly as the scalar path does for any graph with fewer
    than 2**24 edges — so a batched run's per-iteration direction trace
    matches the per-graph sequential traces.
    """
    deg = out_degree.astype(jnp.int32)
    m_f = jnp.sum(jnp.where(mask, deg, 0), axis=1)
    n_f = jnp.sum(mask.astype(jnp.int32), axis=1)
    if unvisited is None:
        to_pull = m_f * alpha > n_edges
    else:
        m_u = jnp.sum(jnp.where(unvisited, deg, 0), axis=1)
        to_pull = m_f * alpha > m_u
    to_push = n_f * beta < n_nodes
    prev_pull = jnp.asarray(prev_pull, bool)
    return jnp.where(prev_pull, ~to_push, to_pull)


class SparseFrontier(NamedTuple):
    """Padded sparse frontier plus its true size.

    ``ids`` is the ``[capacity]`` int32 vertex-id list (ascending, -1
    padding); ``count`` is the frontier's *true* vertex count, which may
    exceed ``capacity`` — :attr:`overflowed` is the signal that ``ids``
    is a truncation and any consumer must fall back to the dense mask.
    """
    ids: jnp.ndarray
    count: jnp.ndarray

    @property
    def overflowed(self) -> jnp.ndarray:
        """Traced bool: True iff frontier vertices were dropped."""
        return self.count > self.ids.shape[0]


class FrontierEdges(NamedTuple):
    """Padded frontier-edge list plus the gathered frontier's edge count.

    ``edge_ids`` indexes the CSR (by-src) edge arrays (``[capacity]``
    int32, -1 padding); ``count`` is the total out-edge count of the
    *gathered* vertex list.  If the vertex list itself overflowed,
    ``count`` undercounts the real m_f — check both overflow flags.
    """
    edge_ids: jnp.ndarray
    count: jnp.ndarray

    @property
    def overflowed(self) -> jnp.ndarray:
        """Traced bool: True iff frontier edges were dropped."""
        return self.count > self.edge_ids.shape[0]


def dense_to_sparse(mask: jnp.ndarray, capacity: int) -> SparseFrontier:
    """Dense [V] mask -> :class:`SparseFrontier` of static ``capacity``.

    ``capacity`` is static (jit requires fixed shapes).  Frontier
    vertices beyond it do not fit in ``ids``; the returned ``count`` is
    the true frontier size so callers observe the overflow (via
    :attr:`SparseFrontier.overflowed`) instead of silently computing on
    a truncated frontier.  Size ``capacity`` at V for exactness.
    """
    v = mask.shape[0]
    ids = jnp.nonzero(mask, size=capacity, fill_value=v)[0]
    ids = jnp.where(ids < v, ids, -1).astype(jnp.int32)
    return SparseFrontier(ids=ids, count=frontier_size(mask))


def sparse_to_dense(ids: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    """Padded vertex-id list (-1 padding) -> dense [V] boolean mask."""
    mask = jnp.zeros((n_nodes + 1,), bool)
    safe = jnp.where(ids < 0, n_nodes, ids)
    return mask.at[safe].set(True)[:n_nodes]


def gather_frontier_edges(ids: jnp.ndarray, row_ptr: jnp.ndarray,
                          capacity: int) -> FrontierEdges:
    """Expand a sparse vertex list into its CSR out-edge list.

    For each non-padding vertex in ``ids``, slice its edge range out of
    ``row_ptr`` ([V+1] CSR row offsets) and concatenate the ranges into
    a padded ``[capacity]`` list of edge indices (-1 padding).  Work and
    memory are O(capacity + |ids|), independent of |E| — this is what
    makes a sparse push iteration O(m_f).

    The slot->vertex mapping is a searchsorted over the running degree
    sum: output slot ``j`` belongs to the k-th listed vertex where
    ``cum[k-1] <= j < cum[k]``, at offset ``j - cum[k-1]`` within its
    row.  Padding ids (-1) have degree 0 and are never selected.
    """
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    starts = row_ptr[safe].astype(jnp.int32)
    degs = jnp.where(valid, row_ptr[safe + 1].astype(jnp.int32) - starts, 0)
    cum = jnp.cumsum(degs)
    total = cum[-1]
    slot = jnp.arange(capacity, dtype=jnp.int32)
    k = jnp.searchsorted(cum, slot, side="right")
    k = jnp.minimum(k, ids.shape[0] - 1)
    edge = starts[k] + (slot - (cum[k] - degs[k]))
    edge_ids = jnp.where(slot < jnp.minimum(total, capacity), edge, -1)
    return FrontierEdges(edge_ids=edge_ids.astype(jnp.int32), count=total)
