"""Amortized construction of per-graph execution-plan artifacts.

Binding a graph to a :class:`~repro.core.executor.EdgeContext` builds
expensive host-side artifacts: the device-resident edge orders, the
pre-chunked push/pull arrays, and the blocked Pallas reducers whose
tiling plans walk the full edge set.  A 12-cell design-space sweep
(``benchmarks/fig5.py``) binds the *same* graph 12 times (x repeats),
but most artifacts do not depend on the full config — the CSC chunking
depends only on ``n_chunks``, the reducers only on the graph — so
rebuilding them per cell is pure waste on the sweep's critical path.

:class:`PlanCache` is a process-wide store keyed on *graph identity*
plus an artifact kind and its build parameters.  Graph identity is
``id(graph)`` guarded by a ``weakref.finalize`` hook that evicts every
entry of a collected graph, so the cache can never resurrect a plan for
a recycled ``id``.  Values are built lazily by the caller-supplied
thunk; hits and misses are counted for tests and benchmarks.

The cache stores two granularities:

- **artifacts** (``"device"``, ``"chunked"``, ``"owned_reducer"``, ...)
  shared *across* configs of one graph, and
- whole **contexts** (``"context"``, keyed additionally on the config,
  ``use_pallas`` and the sparse capacity) so repeated ``run`` calls on
  the same cell reuse the bound ``EdgeContext`` outright.

The batched serving path adds two kinds: ``"batch_pack"`` (a
block-diagonal :class:`~repro.core.batch.GraphBatch`, anchored on the
batch's first member graph and keyed on the member identities — the
batch pins members ``1..B-1`` strongly so their ids cannot recycle
under the entry) and ``"batch_context"`` (a bound
:class:`~repro.core.batch.BatchedEdgeContext`, anchored on the packed
graph).  Repeat serving traffic over one graph set therefore reuses the
pack, the bound context and — through ``"exec_fn"`` on the packed
graph — the compiled whole-batch runner.

The resilience layer (``repro.core.resilience``) adds three kinds
anchored on the bound graph: ``"fused_seg"`` (the segmented fused
runner — the segment end is a traced operand, so one compiled
executable serves every checkpoint interval), ``"sentinel_eval"`` (the
standalone jitted sentinel battery used at host-engine boundaries and
to re-check perturbed states) and ``"certificate"`` (the O(E) fixpoint
proof evaluated once at convergence).

The specialization layer (``repro.core.specialize_learned``) adds two
kinds next to ``"tuned_tiling"``: ``"graph_profile"`` (the Sec. III
taxonomy :class:`~repro.core.taxonomy.GraphProfile`, an O(E) +
per-block clustering pass the static trees consume) and
``"specialized_config"`` (the resolved best-config decision per
(properties, mode, model generation) — repeat admission of an
already-seen graph never re-extracts features or re-walks a tree).
"""
from __future__ import annotations

import threading
import weakref
from typing import Any, Callable, Dict, Hashable, Tuple

__all__ = ["PlanCache", "PLAN_CACHE"]


class PlanCache:
    """Process-wide (graph, kind, params) -> artifact store with counters."""

    def __init__(self):
        self._store: Dict[Tuple[int, str, Hashable], Any] = {}
        self._finalizers: Dict[int, weakref.finalize] = {}
        #: graph ids whose entries await pruning.  Finalizers only
        #: append here (an atomic list op): a cyclic-GC pass can run a
        #: dead graph's finalizer on this same thread *while* we hold
        #: the lock or iterate ``_store``, so the finalizer itself must
        #: never lock or mutate the store — pruning happens lazily at
        #: the top of :meth:`get`, before lookup, so a recycled id can
        #: never serve a dead graph's entries.
        self._dead: list = []
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        #: per-artifact-kind counters: kind -> [hits, misses].  Lets
        #: cache effectiveness be judged per subsystem (e.g. how often
        #: ``tuned_tiling`` re-tunes vs recalls) instead of only in
        #: aggregate; surfaced through :meth:`stats` and
        #: ``executor.STATS.plan_cache()``.
        self._by_kind: Dict[str, list] = {}

    # ------------------------------------------------------------------
    def get(self, graph: Any, kind: str, params: Hashable,
            build: Callable[[], Any],
            capacity: int | None = None) -> Any:
        """Return the cached artifact, building (and caching) on miss.

        ``params`` must capture everything ``build`` depends on besides
        the graph itself (e.g. ``n_chunks`` for a chunking plan).
        ``capacity`` optionally bounds how many entries of this
        ``(graph, kind)`` bucket are retained: on insert, the
        least-recently-used entries beyond it are evicted (hits refresh
        recency by reinserting the key) — used for per-program compiled
        executables, which would otherwise grow without bound across
        distinct program instances on one long-lived graph.
        """
        key = (id(graph), kind, params)
        with self._lock:
            self._prune()
            counters = self._by_kind.setdefault(kind, [0, 0])
            if key in self._store:
                self.hits += 1
                counters[0] += 1
                # refresh recency: dict order is the LRU order
                value = self._store.pop(key)
                self._store[key] = value
                return value
            self.misses += 1
            counters[1] += 1
            self._watch(graph)
        # build outside the lock: builders may recurse into the cache
        # (a context builds artifacts), and plans can take a while
        value = build()
        with self._lock:
            value = self._store.setdefault(key, value)
            if capacity is not None:
                bucket = [k for k in self._store
                          if k[0] == key[0] and k[1] == kind]
                for stale in bucket[:-capacity]:
                    del self._store[stale]
            return value

    def _watch(self, graph: Any) -> None:
        gid = id(graph)
        if gid not in self._finalizers:
            self._finalizers[gid] = weakref.finalize(
                graph, self._evict, gid)

    def _evict(self, gid: int) -> None:
        # finalizer context: may fire mid-iteration of _store on this
        # very thread — only queue (list.append is atomic and safe)
        self._dead.append(gid)

    def _prune(self) -> None:
        """Drop entries of collected graphs.  Call with the lock held.

        A GC pass during the iteration below can only *append* to
        ``_dead`` (finalizers never touch ``_store``), so iterating the
        store here is safe.
        """
        while self._dead:
            gid = self._dead.pop()
            self._finalizers.pop(gid, None)
            for key in [k for k in self._store if k[0] == gid]:
                del self._store[key]

    # ------------------------------------------------------------------
    def clear(self) -> None:
        """Drop every entry and reset the hit/miss counters."""
        with self._lock:
            for fin in self._finalizers.values():
                fin.detach()
            self._finalizers.clear()
            self._store.clear()
            self._dead.clear()
            self.hits = 0
            self.misses = 0
            self._by_kind.clear()

    def stats(self) -> Dict[str, Any]:
        """Global and per-kind counters.

        ``by_kind`` maps each artifact kind to its own
        ``{hits, misses, entries}`` so e.g. autotune cache
        effectiveness (``tuned_tiling``) is observable independently of
        the context/exec_fn churn around it.
        """
        with self._lock:
            self._prune()
            kinds = {k[1] for k in self._store}
            by_kind = {
                kind: {"hits": hm[0], "misses": hm[1],
                       "entries": sum(1 for k in self._store
                                      if k[1] == kind)}
                for kind, hm in self._by_kind.items()
            }
            for kind in kinds:  # entries whose counters were cleared
                by_kind.setdefault(kind, {"hits": 0, "misses": 0,
                                          "entries": sum(
                                              1 for k in self._store
                                              if k[1] == kind)})
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses, "by_kind": by_kind}

    def kind_stats(self, kind: str) -> Dict[str, int]:
        """One kind's ``{hits, misses, entries}`` (zeros when the kind
        has never been touched) — the shape serving/property tests
        assert plan-cache warmth with."""
        return self.stats()["by_kind"].get(
            kind, {"hits": 0, "misses": 0, "entries": 0})

    def __len__(self) -> int:
        with self._lock:
            self._prune()
            return len(self._store)


#: The process-wide cache :class:`~repro.core.executor.EdgeContext` uses.
PLAN_CACHE = PlanCache()
