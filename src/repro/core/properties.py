"""Algorithmic properties (paper Sec. III-B, Table III).

Traversal: STATIC (updates flow over input-graph edges) or DYNAMIC
(data-dependent source/target, e.g. pointer jumping over transitive edges).
Control: where predicate work is elided (SOURCE favours push, TARGET pull).
Information: where property loads hoist (SOURCE favours push, TARGET pull).
"""
from __future__ import annotations

import dataclasses
import enum

__all__ = ["Traversal", "Locus", "AlgorithmicProperties", "TABLE_III"]


class Traversal(enum.Enum):
    STATIC = "static"
    DYNAMIC = "dynamic"


class Locus(enum.Enum):
    SOURCE = "source"
    TARGET = "target"
    SYMMETRIC = "symmetric"
    NA = "-"  # dynamic-traversal apps: not used for specialization


@dataclasses.dataclass(frozen=True)
class AlgorithmicProperties:
    traversal: Traversal
    control: Locus
    information: Locus


#: Table III, verbatim.
TABLE_III = {
    "PR": AlgorithmicProperties(Traversal.STATIC, Locus.SYMMETRIC, Locus.SOURCE),
    "SSSP": AlgorithmicProperties(Traversal.STATIC, Locus.SOURCE, Locus.SOURCE),
    "MIS": AlgorithmicProperties(Traversal.STATIC, Locus.SYMMETRIC, Locus.SYMMETRIC),
    "CLR": AlgorithmicProperties(Traversal.STATIC, Locus.SYMMETRIC, Locus.TARGET),
    "BC": AlgorithmicProperties(Traversal.STATIC, Locus.SOURCE, Locus.SYMMETRIC),
    "CC": AlgorithmicProperties(Traversal.DYNAMIC, Locus.NA, Locus.NA),
    # Not in the paper's Table III: direction-optimizing BFS picks its
    # source/target direction per iteration from frontier occupancy —
    # dynamic traversal, so the model maps it to the DD1 cell.
    "BFS": AlgorithmicProperties(Traversal.DYNAMIC, Locus.NA, Locus.NA),
}
