"""Persistent checkpoints: spill :class:`CheckpointRing` boundaries to disk.

PR 8's resilience layer survives *in-process* faults — a NaN, a runner
exception, a compile failure — because the :class:`~repro.core.
resilience.CheckpointRing` keeps clean boundaries in host memory.  A
process death loses the ring.  This module is the crash-durability
extension: :class:`CheckpointStore` writes every ring boundary to disk
in a self-verifying format, so ``run(..., checkpoint_dir=...)`` can be
killed at any instant (power loss, OOM kill, preemption) and a fresh
process resumes from the newest intact boundary, **bit-identical** to
an uninterrupted run — segment boundaries land on the same iteration
multiples whether the run restarted or not, and the trace buffers
travel inside the snapshot.

File format (one file per checkpoint generation, ``ckpt-<seq>.rck``)::

    magic   8 bytes   b"RPCKPT1\\n"
    version u32 LE    format version (current: 1)
    length  u64 LE    payload byte count
    digest  32 bytes  SHA-256 of the payload
    payload           npz archive: "__meta__" JSON (iteration, done flag,
                      run fingerprint, buffer presence) + one entry per
                      state leaf / trace buffer

Every hazard a crash can leave behind is detected at *load*, not at
use: a truncated file fails the length check, a bit-flipped byte fails
the digest, a stale directory from a different run — different program,
config, graph *content* (SHA-256 over every array) or PRNG key —
fails the fingerprint — each rejected with a structured
:class:`~repro.core.resilience.ExecutionFault` (``code=
"corrupt_checkpoint"`` / ``"checkpoint_mismatch"``).  Recovery then
falls back generation by generation: the newest intact file wins,
corrupt ones are recorded in the run's fault history, and when *no*
generation survives the run cold-starts from ``program.init`` — never
a silently wrong resume.

Writes are atomic (write to a ``.tmp-`` sibling, fsync, then
``os.replace``), so a kill mid-write can only ever lose the checkpoint
being written — the previous generation stays intact.  The store
prunes itself to ``keep`` generations, always pinning the oldest
(initial) one — mirroring the in-memory ring's cold-restart floor —
and always retaining the newest one, the resume point.

The serving gateway's write-ahead journal (:mod:`repro.launch.journal`)
reuses this store per ticket: each slice commit persists the ticket's
post-slice state, so :meth:`~repro.launch.serve.GraphGateway.recover`
re-admits unfinished tickets from their newest persisted boundary
instead of iteration 0.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import struct
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.resilience import (DEFAULT_RING_CAPACITY, Checkpoint,
                                   ExecutionFault)

__all__ = ["CheckpointStore", "CHECKPOINT_MAGIC", "CHECKPOINT_VERSION"]

CHECKPOINT_MAGIC = b"RPCKPT1\n"
CHECKPOINT_VERSION = 1
_HEADER = struct.Struct("<8sIQ32s")  # magic, version, payload_len, sha256


def _encode_payload(cp: Checkpoint, fingerprint: Optional[dict]) -> bytes:
    """Serialize one checkpoint into the npz payload (host numpy only)."""
    if not isinstance(cp.state, dict):
        raise ValueError("CheckpointStore persists dict state pytrees; "
                         f"got {type(cp.state).__name__}")
    meta = {
        "it": int(cp.it),
        "done": bool(cp.done),
        "fingerprint": fingerprint,
        "state_keys": sorted(cp.state),
        "has_dir": cp.dir_buf is not None,
        "has_occ": cp.occ_buf is not None,
    }
    arrays: Dict[str, np.ndarray] = {
        "__meta__": np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), np.uint8),
    }
    for k in meta["state_keys"]:
        arrays[f"state:{k}"] = np.asarray(cp.state[k])
    if cp.dir_buf is not None:
        arrays["dir_buf"] = np.asarray(cp.dir_buf)
    if cp.occ_buf is not None:
        arrays["occ_buf"] = np.asarray(cp.occ_buf)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _decode_payload(payload: bytes) -> Tuple[Checkpoint, Optional[dict]]:
    with np.load(io.BytesIO(payload), allow_pickle=False) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        state = {k: z[f"state:{k}"].copy() for k in meta["state_keys"]}
        dir_buf = z["dir_buf"].copy() if meta["has_dir"] else None
        occ_buf = z["occ_buf"].copy() if meta["has_occ"] else None
    cp = Checkpoint(it=int(meta["it"]), done=bool(meta["done"]),
                    state=state, dir_buf=dir_buf, occ_buf=occ_buf)
    return cp, meta.get("fingerprint")


class CheckpointStore:
    """Durable, self-verifying checkpoint generations under one directory.

    ``fingerprint`` identifies the run the checkpoints belong to (the
    resilience layer passes program name, config name, graph shape, a
    content SHA-256 over every graph array, and the serialized PRNG
    key — so a same-shape graph with different edges/weights, or a
    rerun under a different key, never matches); a generation written
    under a different fingerprint is rejected at load with
    ``code="checkpoint_mismatch"`` — a reused directory can therefore
    never resume the wrong run.  ``keep`` bounds how many generations
    stay on disk: the oldest (initial) generation is pinned as the
    cold-restart floor and the newest is always retained as the resume
    point (even with ``keep=1``), the rest rotate out.
    """

    def __init__(self, root, keep: int = DEFAULT_RING_CAPACITY,
                 fingerprint: Optional[dict] = None):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = int(keep)
        self.fingerprint = fingerprint
        existing = self.generations()
        self._seq = (self._gen_seq(existing[0]) + 1) if existing else 0

    # -- write ----------------------------------------------------------
    def save(self, cp: Checkpoint) -> Path:
        """Persist one checkpoint atomically; returns its final path.

        The payload is fully written and fsynced under a ``.tmp-`` name
        before ``os.replace`` publishes it — readers (including a
        recovery racing this writer's death) only ever see complete
        generations or none.
        """
        payload = _encode_payload(cp, self.fingerprint)
        header = _HEADER.pack(CHECKPOINT_MAGIC, CHECKPOINT_VERSION,
                              len(payload), hashlib.sha256(payload).digest())
        final = self.root / f"ckpt-{self._seq:08d}.rck"
        tmp = self.root / f".tmp-{final.name}"
        with open(tmp, "wb") as f:
            f.write(header)
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        self._seq += 1
        self._prune()
        return final

    def _prune(self) -> None:
        gens = self.generations()          # newest first
        if len(gens) <= self.keep:
            return
        # the newest generation (the resume point — possibly the file
        # just saved) and the oldest (the initial cold-restart floor)
        # are both unconditionally retained: with keep=1 this store
        # holds two files rather than deleting the checkpoint it just
        # wrote and degrading every resume to a cold restart
        pinned = {gens[0], gens[-1]}
        for path in gens[self.keep - 1:]:
            if path not in pinned:
                path.unlink(missing_ok=True)

    # -- read -----------------------------------------------------------
    @staticmethod
    def _gen_seq(path: Path) -> int:
        return int(path.stem.split("-")[1])

    def generations(self) -> List[Path]:
        """Published generation files, newest first."""
        return sorted(self.root.glob("ckpt-*.rck"),
                      key=self._gen_seq, reverse=True)

    def load(self, path) -> Checkpoint:
        """Load and verify one generation.

        Raises :class:`ExecutionFault` with ``code="corrupt_checkpoint"``
        for any integrity failure (short header, bad magic/version,
        truncated payload, digest mismatch, undecodable payload) and
        ``code="checkpoint_mismatch"`` when the file is intact but
        belongs to a different run fingerprint.
        """
        path = Path(path)
        raw = path.read_bytes()
        if len(raw) < _HEADER.size:
            raise ExecutionFault("corrupt_checkpoint", {
                "path": str(path), "reason": "short_header",
                "bytes": len(raw)})
        magic, version, length, digest = _HEADER.unpack_from(raw)
        if magic != CHECKPOINT_MAGIC:
            raise ExecutionFault("corrupt_checkpoint", {
                "path": str(path), "reason": "bad_magic"})
        if version != CHECKPOINT_VERSION:
            raise ExecutionFault("corrupt_checkpoint", {
                "path": str(path), "reason": "unknown_version",
                "version": int(version)})
        payload = raw[_HEADER.size:]
        if len(payload) != length:
            raise ExecutionFault("corrupt_checkpoint", {
                "path": str(path), "reason": "truncated",
                "expected_bytes": int(length), "got_bytes": len(payload)})
        if hashlib.sha256(payload).digest() != digest:
            raise ExecutionFault("corrupt_checkpoint", {
                "path": str(path), "reason": "checksum_mismatch"})
        try:
            cp, fp = _decode_payload(payload)
        except Exception as err:
            raise ExecutionFault("corrupt_checkpoint", {
                "path": str(path), "reason": "undecodable",
                "error": repr(err)}) from err
        if self.fingerprint is not None and fp != self.fingerprint:
            raise ExecutionFault("checkpoint_mismatch", {
                "path": str(path), "expected": self.fingerprint,
                "found": fp})
        return cp

    def load_all(self) -> Tuple[List[Checkpoint], List[dict]]:
        """Every intact generation oldest-first, plus structured fault
        records for the ones that were rejected.

        This is the resume path: the caller seeds a fresh in-memory ring
        with the surviving boundaries (so post-restart retry rollback
        has the same depth an uninterrupted run would) and appends the
        fault records to the run's fault history.  An empty first list
        means cold restart.
        """
        good: List[Checkpoint] = []
        faults: List[dict] = []
        for path in reversed(self.generations()):   # oldest first
            try:
                good.append(self.load(path))
            except ExecutionFault as err:
                faults.append({"kind": err.code, **err.detail})
        return good, faults

    def load_latest(self) -> Tuple[Optional[Checkpoint], List[dict]]:
        """The newest intact generation (or None), plus fault records
        for every newer generation that had to be rejected first."""
        faults: List[dict] = []
        for path in self.generations():             # newest first
            try:
                return self.load(path), faults
            except ExecutionFault as err:
                faults.append({"kind": err.code, **err.detail})
        return None, faults

    def clear(self) -> None:
        """Remove every generation (including stale tmp files)."""
        for path in self.root.glob("ckpt-*.rck"):
            path.unlink(missing_ok=True)
        for path in self.root.glob(".tmp-*"):
            path.unlink(missing_ok=True)
        self._seq = 0

    def __len__(self) -> int:
        return len(self.generations())
