from repro.core.config_space import (ALL_CONFIGS, DYNAMIC_CONFIGS,
                                     STATIC_CONFIGS, Coherence, Consistency,
                                     SystemConfig, UpdateProp)
from repro.core.executor import (STATS, EdgeContext, ExecutorStats,
                                 RunResult, run, run_batch)
from repro.core.batch import (BatchedEdgeContext, GraphBatch, bucket_key,
                              bucket_shape, get_graph_batch, pack_graphs)
from repro.core.plan_cache import PLAN_CACHE, PlanCache
from repro.core.durability import (CHECKPOINT_MAGIC, CHECKPOINT_VERSION,
                                   CheckpointStore)
from repro.core.resilience import (DEFAULT_CHECKPOINT_EVERY,
                                   DEFAULT_RING_CAPACITY, Checkpoint,
                                   CheckpointRing, ExecutionFault,
                                   FaultInjector, RetryPolicy,
                                   build_sentinels, check_certificate,
                                   check_state_host, run_resilient)
from repro.core.frontier import (FrontierEdges, SparseFrontier,
                                 choose_direction, dense_to_sparse,
                                 frontier_density, frontier_edges,
                                 frontier_size, gather_frontier_edges,
                                 sparse_to_dense)
from repro.core.model import specialize, specialize_partial
from repro.core.specialize_learned import (DEFAULT_MODEL_PATH,
                                           LearnedSpecializer,
                                           ModelFileError,
                                           SpecializeFallbackWarning,
                                           features_from_graph, fit_matrix,
                                           load_model, project_config,
                                           resolve_config, save_model,
                                           static_config_for)
from repro.core.properties import (TABLE_III, AlgorithmicProperties, Locus,
                                   Traversal)
from repro.core.taxonomy import (PAPER_GPU, TPU_V5E, GraphProfile, HwProfile,
                                 classify, profile_graph)
from repro.core.vertex_program import (DENSE_OCC, FRONTIER_DIR_KEY,
                                       FRONTIER_OCC_KEY, MAX, MIN, SUM,
                                       EdgePhase, Monoid, VertexProgram,
                                       dense_occupancy)

__all__ = [
    "ALL_CONFIGS", "DYNAMIC_CONFIGS", "STATIC_CONFIGS",
    "Coherence", "Consistency", "SystemConfig", "UpdateProp",
    "EdgeContext", "RunResult", "run", "run_batch", "ExecutorStats",
    "STATS",
    "BatchedEdgeContext", "GraphBatch", "bucket_key", "bucket_shape",
    "get_graph_batch", "pack_graphs",
    "PLAN_CACHE", "PlanCache",
    "CHECKPOINT_MAGIC", "CHECKPOINT_VERSION", "CheckpointStore",
    "DEFAULT_CHECKPOINT_EVERY", "DEFAULT_RING_CAPACITY", "Checkpoint",
    "CheckpointRing", "ExecutionFault", "FaultInjector", "RetryPolicy",
    "build_sentinels", "check_certificate", "check_state_host",
    "run_resilient",
    "FrontierEdges", "SparseFrontier",
    "choose_direction", "dense_to_sparse", "frontier_density",
    "frontier_edges", "frontier_size", "gather_frontier_edges",
    "sparse_to_dense",
    "specialize", "specialize_partial",
    "DEFAULT_MODEL_PATH", "LearnedSpecializer", "ModelFileError",
    "SpecializeFallbackWarning", "features_from_graph", "fit_matrix",
    "load_model", "project_config", "resolve_config", "save_model",
    "static_config_for",
    "TABLE_III", "AlgorithmicProperties", "Locus", "Traversal",
    "PAPER_GPU", "TPU_V5E", "GraphProfile", "HwProfile", "classify",
    "profile_graph",
    "DENSE_OCC", "FRONTIER_DIR_KEY", "FRONTIER_OCC_KEY", "MAX", "MIN",
    "SUM", "EdgePhase", "Monoid", "VertexProgram", "dense_occupancy",
]
