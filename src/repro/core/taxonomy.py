"""Graph-structure taxonomy: Volume, Reuse, Imbalance (paper Sec. III-A).

Implements Equations 1-7 plus the paper's empirically chosen thresholds
(Sec. V-A) for H/M/L classification.  Two hardware profiles are provided:

- ``PAPER_GPU``: the simulated GPU of Table IV (15 SMs, 32 KB L1, 4 MB L2,
  |TB| = 256).  Used for the paper-faithfulness tests: with the published
  |V|, |E| the Volume classes of Table II reproduce exactly.
- ``TPU_V5E``: the deployment profile.  The unit of scheduling locality is
  the per-core vertex tile (Pallas target block); "L1" is VMEM and "L2/SM"
  is the per-core HBM working-set budget.  Classes drive the same decision
  tree; only thresholds differ (DESIGN.md Sec. 2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structure import Graph

__all__ = ["HwProfile", "PAPER_GPU", "TPU_V5E", "GraphProfile",
           "volume_kb", "reuse", "imbalance", "classify", "profile_graph",
           "classify_volume_kb"]

BYTES_PER_ELEMENT = 4  # one fp32/int32 property word per vertex + per edge


@dataclasses.dataclass(frozen=True)
class HwProfile:
    name: str
    n_cores: int            # |SM| in Eq. 1
    l1_bytes: int           # per-core fast memory
    l2_bytes: int           # shared capacity
    tb_size: int            # |TB| in Eqs. 2-7 (vertex tile size)
    # classification thresholds (Sec. V-A)
    vol_low_factor: float = 1.5     # low: < 1.5 x L1
    reuse_low: float = 0.15
    reuse_high: float = 0.40
    imb_low: float = 0.05
    imb_high: float = 0.25
    kmeans_threshold: float = 10.0  # max-degree centroid differential

    @property
    def vol_low_kb(self) -> float:
        return self.vol_low_factor * self.l1_bytes / 1024.0

    @property
    def vol_high_kb(self) -> float:
        return self.l2_bytes / self.n_cores / 1024.0


#: Table IV simulated hardware.
PAPER_GPU = HwProfile(name="paper_gpu", n_cores=15, l1_bytes=32 * 1024,
                      l2_bytes=4 * 1024 * 1024, tb_size=256)

#: TPU v5e-ish deployment profile: 1 TensorCore per chip; VMEM ~128 MB
#: plays the L1 role; treat a 16 MB per-core HBM hot-set budget as the
#: "shared" capacity knee (beyond it, expect streaming behaviour).
TPU_V5E = HwProfile(name="tpu_v5e", n_cores=1, l1_bytes=128 * 1024 * 1024,
                    l2_bytes=16 * 1024 * 1024 * 1024, tb_size=1024)


# --------------------------------------------------------------------------
# Eq. 1 - Volume
# --------------------------------------------------------------------------
def volume_kb(n_nodes: int, n_edges: int, hw: HwProfile = PAPER_GPU) -> float:
    """Eq. 1 scaled to KB: average working set per core."""
    return (n_nodes + n_edges) * BYTES_PER_ELEMENT / hw.n_cores / 1024.0


def classify_volume_kb(kb: float, hw: HwProfile = PAPER_GPU) -> str:
    if kb < hw.vol_low_kb:
        return "L"
    if kb > hw.vol_high_kb:
        return "H"
    return "M"


# --------------------------------------------------------------------------
# Eqs. 2-6 - Reuse
# --------------------------------------------------------------------------
def an_local_remote(g: Graph, tb_size: int) -> tuple[float, float]:
    """AN_L (Eq. 4) and AN_R (Eq. 5): average local/remote neighbors,
    where local means same thread block / vertex tile (Eqs. 2-3)."""
    src = np.asarray(g.src, dtype=np.int64)
    dst = np.asarray(g.dst, dtype=np.int64)
    same = (src // tb_size) == (dst // tb_size)
    non_self = src != dst  # self edges contribute 0 to both (Eqs. 2-3)
    an_l = float(np.count_nonzero(same & non_self)) / g.n_nodes
    an_r = float(np.count_nonzero(~same & non_self)) / g.n_nodes
    return an_l, an_r


def reuse_from_an(an_l: float, an_r: float, avg_degree: float) -> float:
    """Eq. 6."""
    if avg_degree == 0:
        return 0.0
    return 0.5 * (1.0 + (an_l - an_r) / avg_degree)


def reuse(g: Graph, hw: HwProfile = PAPER_GPU) -> float:
    an_l, an_r = an_local_remote(g, hw.tb_size)
    avg_degree = g.n_edges / max(g.n_nodes, 1)
    return reuse_from_an(an_l, an_r, avg_degree)


def classify_reuse(r: float, hw: HwProfile = PAPER_GPU) -> str:
    if r < hw.reuse_low:
        return "L"
    if r > hw.reuse_high:
        return "H"
    return "M"


# --------------------------------------------------------------------------
# Eq. 7 - Imbalance (k-means over per-warp max degree)
# --------------------------------------------------------------------------
WARP_SIZE = 32


def _kmeans2(values: np.ndarray, iters: int = 16) -> tuple[float, float]:
    """Tiny fixed-k (k=2) 1-D k-means; returns the two centroids."""
    lo, hi = float(values.min()), float(values.max())
    if lo == hi:
        return lo, hi
    c0, c1 = lo, hi
    for _ in range(iters):
        mid = (c0 + c1) / 2.0
        left = values[values <= mid]
        right = values[values > mid]
        n0 = c0 if left.size == 0 else float(left.mean())
        n1 = c1 if right.size == 0 else float(right.mean())
        if n0 == c0 and n1 == c1:
            break
        c0, c1 = n0, n1
    return c0, c1


def imbalance(g: Graph, hw: HwProfile = PAPER_GPU) -> float:
    """Eq. 7: fraction of thread blocks marked imbalanced, where a block is
    marked if 2-means clustering of its warps' max degree yields centroids
    separated by more than the threshold (Sec. III-A3, V-A)."""
    deg = np.asarray(g.out_degree, dtype=np.float64)
    tb, warp = hw.tb_size, WARP_SIZE
    n_blocks = int(np.ceil(g.n_nodes / tb))
    pad = n_blocks * tb - g.n_nodes
    if pad:
        deg = np.concatenate([deg, np.zeros(pad)])
    # [n_blocks, warps_per_block]: max degree processed by each warp
    warp_max = deg.reshape(n_blocks, tb // warp, warp).max(axis=2)
    marked = 0
    for b in range(n_blocks):
        c0, c1 = _kmeans2(warp_max[b])
        if (c1 - c0) > hw.kmeans_threshold:
            marked += 1
    return marked / max(n_blocks, 1)


def classify_imbalance(i: float, hw: HwProfile = PAPER_GPU) -> str:
    if i < hw.imb_low:
        return "L"
    if i > hw.imb_high:
        return "H"
    return "M"


# --------------------------------------------------------------------------
# Combined profile
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GraphProfile:
    """Taxonomy inputs to the specialization model (Sec. IV)."""
    volume_kb: float
    reuse: float
    imbalance: float
    volume_class: str
    reuse_class: str
    imbalance_class: str

    @classmethod
    def from_classes(cls, vol: str, reu: str, imb: str) -> "GraphProfile":
        return cls(float("nan"), float("nan"), float("nan"), vol, reu, imb)


def classify(vol_kb: float, r: float, i: float,
             hw: HwProfile = PAPER_GPU) -> GraphProfile:
    return GraphProfile(
        volume_kb=vol_kb, reuse=r, imbalance=i,
        volume_class=classify_volume_kb(vol_kb, hw),
        reuse_class=classify_reuse(r, hw),
        imbalance_class=classify_imbalance(i, hw),
    )


def profile_graph(g: Graph, hw: HwProfile = PAPER_GPU) -> GraphProfile:
    return classify(volume_kb(g.n_nodes, g.n_edges, hw), reuse(g, hw),
                    imbalance(g, hw), hw)
