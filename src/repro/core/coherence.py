"""Coherence dimension: where the scatter-reduction resolves (DESIGN.md §2).

- :func:`segment_reduce` — the **LLC / GPU-coherence analogue**: one global
  reduction into the full HBM-resident vertex array (XLA scatter/segment op;
  on GPU this was "atomics execute at the L2").
- :func:`segment_reduce_owned` — the **DeNovo analogue**: edges arrive
  pre-binned by target block (``Graph.perm_owned``); updates to one
  VMEM-resident block are accumulated locally and written back once
  ("ownership registration at L1, atomics at L1").  On TPU this is the
  Pallas ``segment_reduce`` kernel; the pure-jnp path reduces over the
  binned order (block-major scatter locality) and is the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vertex_program import Monoid

__all__ = ["segment_reduce", "segment_reduce_owned"]

_SEGMENT_OPS = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
}


def segment_reduce(values: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, monoid: Monoid,
                   indices_are_sorted: bool = False) -> jnp.ndarray:
    """Monoid-dispatched segment reduction (LLC-resolved accumulation).

    ``indices_are_sorted=True`` is the pull path: by-dst edge order makes
    the reduction a dense segmented scan — the "non-atomic" local update of
    the paper.  Unsorted ids are the push path ("atomics").
    """
    op = _SEGMENT_OPS[monoid.name]
    return op(values, segment_ids, num_segments=num_segments,
              indices_are_sorted=indices_are_sorted)


def segment_reduce_owned(values: jnp.ndarray, segment_ids: jnp.ndarray,
                         num_segments: int, monoid: Monoid) -> jnp.ndarray:
    """Owned (DeNovo-analogue) accumulation, pure-jnp realisation.

    Callers pass edges already permuted into target-block-binned order;
    XLA reduces over the binned order (block-major scatter locality).  The
    TPU realisation is the Pallas blocked kernel
    (:class:`repro.kernels.segment_reduce.BlockedSegmentReducer`), wired up
    by :class:`repro.core.executor.EdgeContext` when ``use_pallas=True``.
    """
    return segment_reduce(values, segment_ids, num_segments, monoid,
                          indices_are_sorted=False)
