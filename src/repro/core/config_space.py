"""The paper's 3-axis system design space (Table I), as executable config.

Naming follows the paper's Fig. 5 labels: ``<dir><coh><cons>`` where
direction T = Target-outer (pull), S = Source-outer (push), D = Dynamic
(push+pull); coherence G = GPU-analogue (LLC/HBM-resolved accumulation),
D = DeNovo-analogue (owned/VMEM-block accumulation); consistency
0 = DRF0 (barriered), 1 = DRF1 (ordered chunk overlap), R = DRFrlx
(reorderable partial reductions).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools

__all__ = ["UpdateProp", "Coherence", "Consistency", "SystemConfig",
           "ALL_CONFIGS", "STATIC_CONFIGS", "DYNAMIC_CONFIGS"]


class UpdateProp(enum.Enum):
    PULL = "T"        # target in outer loop; sparse remote reads
    PUSH = "S"        # source in outer loop; sparse remote updates
    PUSH_PULL = "D"   # dynamic traversal; racy reads and updates


class Coherence(enum.Enum):
    #: GPU coherence: atomics at LLC, L1 self-invalidate/write-through.
    #: TPU analogue: one global HBM-resolved scatter/segment reduction.
    GPU = "G"
    #: DeNovo: ownership at L1, local atomics, update reuse.
    #: TPU analogue: target-block-owned VMEM accumulation, write back once.
    DENOVO = "D"


class Consistency(enum.Enum):
    #: SC for DRF; every phase fully barriered.
    DRF0 = "0"
    #: unpaired sync may overlap data: ordered chunk pipeline.
    DRF1 = "1"
    #: relaxed atomics reorder w.r.t. each other: independent partial
    #: reductions in flight (MLP analogue).
    DRFRLX = "R"


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    prop: UpdateProp
    coherence: Coherence
    consistency: Consistency
    #: edge chunks used by the DRF1/DRFrlx schedules (1 => DRF0-equivalent).
    n_chunks: int = 8

    @property
    def name(self) -> str:
        return f"{self.prop.value}{self.coherence.value}{self.consistency.value}"

    @classmethod
    def from_name(cls, name: str, n_chunks: int = 8) -> "SystemConfig":
        prop = {u.value: u for u in UpdateProp}[name[0]]
        coh = {c.value: c for c in Coherence}[name[1]]
        cons = {c.value: c for c in Consistency}[name[2]]
        return cls(prop, coh, cons, n_chunks=n_chunks)

    def __str__(self) -> str:  # pragma: no cover
        return self.name


def _configs(props):
    return tuple(
        SystemConfig(p, c, m)
        for p, c, m in itertools.product(props, Coherence, Consistency)
    )


#: All 12 configurations of the full design space (paper Sec. I).
ALL_CONFIGS = _configs(UpdateProp)
#: The 12 static-traversal configs are (pull|push) x coh x cons; pull does
#: not use fine-grained atomics so its coherence/consistency variants
#: coincide (paper shows only TG0) - we keep them addressable regardless.
STATIC_CONFIGS = _configs([UpdateProp.PULL, UpdateProp.PUSH])
DYNAMIC_CONFIGS = _configs([UpdateProp.PUSH_PULL])
