"""Vertex-centric program abstraction (paper Fig. 1, typed).

A ``VertexProgram`` is written against the :class:`EdgeContext` API
(``ctx.propagate``) which hides the system configuration: update direction
(push/pull), coherence (LLC vs owned accumulation) and consistency schedule
(DRF0/DRF1/DRFrlx).  This is the paper's contract: the *algorithm* supplies
``spred``/``tpred`` (algorithmic control), ``vprop`` (algorithmic
information) and the reduction monoid ``op``; the *system* decides how
edge-propagated updates execute.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.core.properties import TABLE_III, AlgorithmicProperties

__all__ = ["Monoid", "SUM", "MIN", "MAX", "EdgePhase", "VertexProgram",
           "FRONTIER_DIR_KEY", "FRONTIER_OCC_KEY", "DENSE_OCC",
           "dense_occupancy"]

State = dict  # str -> jnp.ndarray pytree

#: State key under which frontier-aware programs record the direction
#: their step chose (bool scalar, True=pull).  ``run`` reads it back per
#: iteration to build :attr:`RunResult.direction_trace`.
FRONTIER_DIR_KEY = "pull"

#: State key under which frontier-aware programs record this iteration's
#: sparse-gather occupancy (float scalar): ``m_f / sparse_edge_capacity``
#: when :meth:`~repro.core.executor.EdgeContext.propagate_sparse` took
#: the gathered O(m_f) path, -1.0 when the iteration ran the dense O(E)
#: scan (pull direction, capacity overflow, or a static config).  ``run``
#: reads it back per iteration into :attr:`RunResult.occupancy_trace`.
FRONTIER_OCC_KEY = "sparse_occ"

#: Occupancy value marking a dense O(E) iteration in the
#: :data:`FRONTIER_OCC_KEY` trace.  Every producer — the executor's
#: ``propagate_sparse`` branches and the frontier-aware programs' init
#: states — must construct it through :func:`dense_occupancy` so the
#: sentinel is one ``jnp.float32`` scalar everywhere (a dtype or
#: weak-type asymmetry between branches would fail ``lax.cond``/
#: ``lax.while_loop`` carry matching).
DENSE_OCC = -1.0


def dense_occupancy() -> jnp.ndarray:
    """The dense-iteration occupancy sentinel as a jnp.float32 scalar."""
    return jnp.asarray(DENSE_OCC, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Monoid:
    """Commutative-associative reduction: the paper's ``op``.

    Commutativity+associativity is what lets DRFrlx reorder the update
    stream (relaxed atomics) — and what lets us legally re-schedule the
    reduction on TPU.
    """
    name: str  # 'sum' | 'min' | 'max'

    def identity(self, dtype) -> Any:
        dtype = jnp.dtype(dtype)
        if self.name == "sum":
            return jnp.zeros((), dtype)
        big = (jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer)
               else jnp.array(jnp.inf, dtype))
        small = (jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer)
                 else jnp.array(-jnp.inf, dtype))
        return big if self.name == "min" else small

    def combine(self, a, b):
        if self.name == "sum":
            return a + b
        return jnp.minimum(a, b) if self.name == "min" else jnp.maximum(a, b)


SUM = Monoid("sum")
MIN = Monoid("min")
MAX = Monoid("max")


@dataclasses.dataclass(frozen=True)
class EdgePhase:
    """One edge-propagated reduction (one kernel of Fig. 1).

    ``vprop(state, src_ids, edge_weight) -> [E] values`` — algorithmic
    information, reads *source-side* properties only (Fig. 1 line 4/8).
    ``spred(state, src_ids)`` / ``tpred(state, dst_ids)`` — algorithmic
    control.  Edges failing either predicate contribute the monoid
    identity (work elision happens at trace level per direction).

    ``frontier(state) -> [V] bool`` — optional frontier protocol: the
    source-side frontier mask driving this phase, fed to
    ``EdgeContext.choose_direction`` by dynamic (``PUSH_PULL``) configs
    to pick push vs. pull per iteration.  ``None`` marks a frontier-less
    phase, which dynamic configs run in the context's documented default
    direction.

    ``gatherable`` — structural opt-in to the sparse-gathered push path:
    set it True only if ``spred`` restricts contributing sources to
    (a subset of) the ``frontier`` mask, so reducing over only the
    frontier's gathered out-edges is equivalent to the dense masked
    scan.  A phase whose frontier merely steers the direction heuristic
    while every source contributes must leave it False, or sparse
    iterations would silently drop contributions.
    """
    monoid: Monoid
    vprop: Callable[[State, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    spred: Optional[Callable[[State, jnp.ndarray], jnp.ndarray]] = None
    tpred: Optional[Callable[[State, jnp.ndarray], jnp.ndarray]] = None
    frontier: Optional[Callable[[State], jnp.ndarray]] = None
    gatherable: bool = False


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """A graph algorithm: state init, per-iteration step, convergence.

    Frontier protocol (optional): traversal-flavoured programs set
    ``frontier_init`` (initial [V] bool mask from the graph) and
    ``frontier_update`` (current mask extracted from state) and record
    the direction their step chose under :data:`FRONTIER_DIR_KEY`.
    ``frontier_update is not None`` is how ``run`` recognises a
    frontier-aware program (gating the per-iteration direction trace it
    reads from :data:`FRONTIER_DIR_KEY`); both extractors give harnesses
    and tests mask access without knowing each program's state layout.
    The direction *choice* itself happens inside ``step`` — programs
    call ``ctx.choose_direction`` on their phase's ``frontier`` mask and
    pass the result to ``ctx.propagate_dynamic``.  Frontier-less
    programs leave everything ``None`` and execute dynamic configs in
    the context's default direction.

    Batching protocol (optional): ``state_pad`` maps state keys to the
    fill value the batch packer must use for that leaf's padding rows
    (default 0).  A program whose zero state is *not* inert — e.g. MIS,
    where status 0 means "undecided" and an all-zero padding row would
    never satisfy per-graph convergence — declares the inert value here
    (``{"status": 2}``).  ``randomized`` marks a program whose ``init``
    draws from a PRNG key; ``run_batch`` derives decorrelated per-graph
    keys (``fold_in`` on the batch index) for such programs when the
    caller passes no explicit keys.

    Resilience protocol (optional, consumed by
    :mod:`repro.core.resilience`): ``monotone`` maps state keys to
    ``"non_increasing"``/``"non_decreasing"`` — the exact reorderable-
    combine property MIN/MAX-monoid fixpoints rely on, checked between
    checkpoints (the relation is transitive, so a K-iteration segment
    boundary check is as strong as a per-iteration one).  ``sentinels``
    maps sentinel names to ``(prev_state, cur_state) -> bool`` invariant
    predicates (True = healthy) written in jnp so they run both inside
    the segmented fused dispatch and on host snapshots.  ``certificate``
    is ``(ctx, state) -> bool``: a one-shot O(E) fixpoint proof checked
    on *converged* states, which catches corruptions (e.g. dropped
    updates that revert a vertex to an older-but-plausible value) that
    boundary sentinels structurally cannot see.
    """
    name: str
    init: Callable[..., State]                     # (graph[, key]) -> state
    step: Callable[..., State]                     # (ctx, state, it) -> state
    converged: Callable[[State, State], jnp.ndarray]  # (prev, cur) -> bool
    extract: Callable[[State], Any]
    weighted: bool = False
    max_iters: int = 1024
    frontier_init: Optional[Callable[..., jnp.ndarray]] = None  # (graph)
    frontier_update: Optional[Callable[[State], jnp.ndarray]] = None
    state_pad: Optional[dict] = None               # key -> padding fill value
    randomized: bool = False                       # init consumes a PRNG key
    monotone: Optional[dict] = None                # key -> ordering direction
    sentinels: Optional[dict] = None               # name -> (prev, cur) -> ok
    certificate: Optional[Callable] = None         # (ctx, state) -> bool

    @property
    def properties(self) -> AlgorithmicProperties:
        return TABLE_III[self.name]
