"""Vertex-centric program abstraction (paper Fig. 1, typed).

A ``VertexProgram`` is written against the :class:`EdgeContext` API
(``ctx.propagate``) which hides the system configuration: update direction
(push/pull), coherence (LLC vs owned accumulation) and consistency schedule
(DRF0/DRF1/DRFrlx).  This is the paper's contract: the *algorithm* supplies
``spred``/``tpred`` (algorithmic control), ``vprop`` (algorithmic
information) and the reduction monoid ``op``; the *system* decides how
edge-propagated updates execute.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.core.properties import TABLE_III, AlgorithmicProperties

__all__ = ["Monoid", "SUM", "MIN", "MAX", "EdgePhase", "VertexProgram"]

State = dict  # str -> jnp.ndarray pytree


@dataclasses.dataclass(frozen=True)
class Monoid:
    """Commutative-associative reduction: the paper's ``op``.

    Commutativity+associativity is what lets DRFrlx reorder the update
    stream (relaxed atomics) — and what lets us legally re-schedule the
    reduction on TPU.
    """
    name: str  # 'sum' | 'min' | 'max'

    def identity(self, dtype) -> Any:
        dtype = jnp.dtype(dtype)
        if self.name == "sum":
            return jnp.zeros((), dtype)
        big = (jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer)
               else jnp.array(jnp.inf, dtype))
        small = (jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer)
                 else jnp.array(-jnp.inf, dtype))
        return big if self.name == "min" else small

    def combine(self, a, b):
        if self.name == "sum":
            return a + b
        return jnp.minimum(a, b) if self.name == "min" else jnp.maximum(a, b)


SUM = Monoid("sum")
MIN = Monoid("min")
MAX = Monoid("max")


@dataclasses.dataclass(frozen=True)
class EdgePhase:
    """One edge-propagated reduction (one kernel of Fig. 1).

    ``vprop(state, src_ids, edge_weight) -> [E] values`` — algorithmic
    information, reads *source-side* properties only (Fig. 1 line 4/8).
    ``spred(state, src_ids)`` / ``tpred(state, dst_ids)`` — algorithmic
    control.  Edges failing either predicate contribute the monoid
    identity (work elision happens at trace level per direction).
    """
    monoid: Monoid
    vprop: Callable[[State, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    spred: Optional[Callable[[State, jnp.ndarray], jnp.ndarray]] = None
    tpred: Optional[Callable[[State, jnp.ndarray], jnp.ndarray]] = None


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """A graph algorithm: state init, per-iteration step, convergence."""
    name: str
    init: Callable[..., State]                     # (graph[, key]) -> state
    step: Callable[..., State]                     # (ctx, state, it) -> state
    converged: Callable[[State, State], jnp.ndarray]  # (prev, cur) -> bool
    extract: Callable[[State], Any]
    weighted: bool = False
    max_iters: int = 1024

    @property
    def properties(self) -> AlgorithmicProperties:
        return TABLE_III[self.name]
