"""Workload-driven specialization model (paper Sec. IV, Fig. 4).

``specialize(props, profile)`` implements the full-design-space decision
tree; ``specialize_partial`` the restricted model of Sec. IV-B (no DRFrlx).

Reconstruction notes (the figure is described in prose; Sec. IV-A text and
Table V were cross-checked — the tree below reproduces Table V 36/36):

Full model:
  1. dynamic traversal             -> push+pull, DeNovo, DRF1 ("DD1")
  2. AC == source or AI == source  -> push (unconditional, Sec. IV-A1)
  3. else pull is *disqualified* when reuse in {M,L} or imbalance in {M,H}
     or volume == H                -> push; otherwise pull + GPU + DRF0
  4. push coherence: GPU if reuse in {M,L} or volume == H, else DeNovo
  5. push consistency: DRFrlx if imbalance == H or volume in {H,M}, else DRF1

Partial model (no DRFrlx; Sec. IV-B).  The prose is terse; the reading
below is self-consistent with every quoted constraint and with the Sec. VI
example (MIS x RAJ -> pull when DRFrlx is unavailable):
  - AC == source -> push.
  - AI == source -> push iff reuse in {M,L} or volume in {M,H}.
  - neither      -> push iff reuse in {M,L} or volume == H
    ("medium volume is no longer sufficient ... it must be high").
  Imbalance is dropped: its push benefit was exactly the DRFrlx MLP win.
  Push pairs with the full model's coherence rule and DRF1; pull -> TG0.
"""
from __future__ import annotations

from repro.core.config_space import (Coherence, Consistency, SystemConfig,
                                     UpdateProp)
from repro.core.properties import AlgorithmicProperties, Locus, Traversal
from repro.core.taxonomy import GraphProfile

__all__ = ["specialize", "specialize_partial"]


def _push_coherence(profile: GraphProfile) -> Coherence:
    if profile.reuse_class in ("M", "L") or profile.volume_class == "H":
        return Coherence.GPU
    return Coherence.DENOVO


def _push_consistency(profile: GraphProfile) -> Consistency:
    if profile.imbalance_class == "H" or profile.volume_class in ("H", "M"):
        return Consistency.DRFRLX
    return Consistency.DRF1


_PULL = SystemConfig(UpdateProp.PULL, Coherence.GPU, Consistency.DRF0)
_DYNAMIC = SystemConfig(UpdateProp.PUSH_PULL, Coherence.DENOVO,
                        Consistency.DRF1)


def specialize(props: AlgorithmicProperties,
               profile: GraphProfile) -> SystemConfig:
    """Full-design-space decision tree (Fig. 4)."""
    if props.traversal is Traversal.DYNAMIC:
        return _DYNAMIC
    prefers_source = (props.control is Locus.SOURCE
                      or props.information is Locus.SOURCE)
    pull_disqualified = (profile.reuse_class in ("M", "L")
                         or profile.imbalance_class in ("M", "H")
                         or profile.volume_class == "H")
    if not prefers_source and not pull_disqualified:
        return _PULL
    return SystemConfig(UpdateProp.PUSH, _push_coherence(profile),
                        _push_consistency(profile))


def specialize_partial(props: AlgorithmicProperties,
                       profile: GraphProfile) -> SystemConfig:
    """Restricted model when the system lacks DRFrlx (Sec. IV-B)."""
    if props.traversal is Traversal.DYNAMIC:
        return _DYNAMIC
    if props.control is Locus.SOURCE:
        push = True
    elif props.information is Locus.SOURCE:
        push = (profile.reuse_class in ("M", "L")
                or profile.volume_class in ("M", "H"))
    else:
        push = (profile.reuse_class in ("M", "L")
                or profile.volume_class == "H")
    if not push:
        return _PULL
    return SystemConfig(UpdateProp.PUSH, _push_coherence(profile),
                        Consistency.DRF1)
