"""Batched multi-graph serving execution (block-diagonal packing).

The paper's headline result — no single (coherence, consistency,
push/pull) configuration wins across workloads — means a serving system
must run *many* graphs under *many* configurations cheaply.  The frontier
executor binds exactly one graph per :class:`~repro.core.executor.
EdgeContext` and pays a full fused-loop dispatch per graph; for the
small graphs serving traffic is made of, that per-operation overhead
dominates (the effect Gunrock documents for small-graph GPU analytics,
and Besta et al. show is worst exactly when frontiers are tiny).

This module amortizes it by packing B structurally-compatible graphs
into **block-diagonal** CSR/CSC edge arrays and driving the whole batch
through **one** fused ``lax.while_loop`` dispatch:

- **Packing** (:func:`pack_graphs`).  Every graph in a batch is padded
  to the batch's bucket shape ``(n_q, m_q)`` (see :func:`bucket_shape`);
  graph *i* owns vertex rows ``[i*n_q, (i+1)*n_q)`` and edge rows
  ``[i*m_q, (i+1)*m_q)`` of the packed arrays.  Padding vertices carry
  only self-loop padding edges, so any influence they could have is
  confined to themselves; padding state rows are zero-filled and the
  padded segments are marked converged from iteration 0.  Because
  vertex ranges are disjoint, every destination segment of the packed
  edge list belongs to exactly one graph — the segment-reduce kernels
  (scatter, sorted-segment, owned-blocked, gathered) are reused
  *unchanged* on the packed arrays.

- **Per-graph semantics** (:class:`BatchedEdgeContext`).  Programs run
  against the same ``ctx`` API they use sequentially; direction choice
  (:meth:`~BatchedEdgeContext.choose_direction`) and sparse-gather
  occupancy are computed **per graph** from each graph's own frontier
  statistics and true ``(n, m)``, bit-identical to the scalar
  heuristic, while the *execution* realisation (which packed edge order
  to scan, whether to take the packed sparse gather) is a batch-level
  performance choice — sound for the order-independent monoids
  (min/max and exact integer sums) the traversal programs use.

- **Convergence masking** (:func:`run_fused_batch`).  The fused carry
  holds per-graph iteration counts and ``done`` flags plus
  ``[B, max_iters]`` direction/occupancy trace buffers; a graph's state
  freezes the iteration after it converges (so extra batch iterations
  cannot perturb it) and the loop exits once every graph's flag is set.
  Unbatching slices per-graph :class:`~repro.core.executor.RunResult`\\ s
  that are bit-identical to sequential ``run()`` — states, iteration
  counts, direction and occupancy traces.

Plan-cache integration: packed batches are cached under
``kind="batch_pack"`` keyed on the member graph identities (anchored on
the first graph, the rest pinned strongly so their ids cannot recycle),
and bound batch contexts under ``kind="batch_context"`` on the packed
graph — repeat serving traffic over the same graph set reuses the pack,
the context and the compiled batch runner outright.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from functools import partial
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config_space import SystemConfig, UpdateProp
from repro.core.executor import (EdgeContext, RunResult, STATS,
                                 _cached_exec_fn, _normalize_autotune,
                                 _trace_flags)
from repro.core.frontier import ALPHA, choose_direction_batch
from repro.core.plan_cache import PLAN_CACHE
from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       EdgePhase, VertexProgram,
                                       dense_occupancy)
from repro.graph.structure import Graph
from repro.kernels.segment_reduce import bin_edges_by_block

__all__ = ["bucket_shape", "bucket_key", "pack_graphs", "get_graph_batch",
           "GraphBatch", "BatchedEdgeContext", "run_fused_batch",
           "run_batch_slice"]

#: Smallest padded vertex/edge bucket: tiny graphs quantize up to these
#: so a bucket never degenerates to widths the [B, n_q] row views (and
#: the [B]-vs-[n_total] leaf classification) cannot distinguish.
MIN_BUCKET_N = 8
MIN_BUCKET_M = 16


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def bucket_shape(n_nodes: int, m_edges: int) -> Tuple[int, int]:
    """Quantized padded shape ``(n_q, m_q)`` for one graph.

    Power-of-two quantization bounds the distinct packed shapes (and
    therefore jit recompiles) at log-many buckets per decade while
    wasting at most 2x padding.  When edge padding is needed
    (``m_q > m``) the vertex quantum is bumped past ``n`` so at least
    one padding vertex exists to carry the padding self-loops — padding
    edges never touch real vertices.
    """
    n, m = int(n_nodes), int(m_edges)
    n_q = _next_pow2(max(n, MIN_BUCKET_N))
    m_q = _next_pow2(max(m, MIN_BUCKET_M))
    if m_q > m and n_q == n:
        n_q *= 2
    return n_q, m_q


def bucket_key(graph: Graph) -> Tuple[int, int, int]:
    """The padding-bucket a graph batches under: ``(n_q, m_q,
    block_size)``.  Graphs sharing a key are structurally compatible —
    they pack into one batch with bounded padding and identical packed
    shapes, so repeated traffic over a bucket reuses one compiled
    runner shape."""
    n_q, m_q = bucket_shape(graph.n_nodes, graph.n_edges)
    return (n_q, m_q, int(graph.block_size))


def _padded_local(g: Graph, n_q: int, m_q: int) -> dict:
    """One graph's arrays padded to ``(n_q, m_q)`` in local ids.

    Padding edges are self-loops spread over the padding vertices
    ``[n, n_q)`` (sorted, so both the CSR and CSC order of the padded
    graph remain sorted); padding rows extend both row-pointer arrays
    consistently.
    """
    n, m = g.n_nodes, g.n_edges
    pad_n, pad_m = n_q - n, m_q - m
    if pad_m and not pad_n:
        raise ValueError("padding edges need at least one padding vertex "
                         f"(n={n} == n_q={n_q} but m={m} < m_q={m_q})")
    a = lambda x: np.asarray(x)
    if pad_m:
        pv = np.sort(np.arange(pad_m, dtype=np.int64) % pad_n) + n
    else:
        pv = np.zeros(0, np.int64)
    counts = np.bincount(pv - n, minlength=pad_n) if pad_n \
        else np.zeros(0, np.int64)
    ones = np.ones(pad_m, np.float32)
    rp_pad = np.cumsum(counts)
    return {
        "src": np.concatenate([a(g.src), pv]),
        "dst": np.concatenate([a(g.dst), pv]),
        "weight": np.concatenate([a(g.weight), ones]),
        "row_ptr_out": np.concatenate([a(g.row_ptr_out), m + rp_pad]),
        "src_in": np.concatenate([a(g.src_in), pv]),
        "dst_in": np.concatenate([a(g.dst_in), pv]),
        "weight_in": np.concatenate([a(g.weight_in), ones]),
        "row_ptr_in": np.concatenate([a(g.row_ptr_in), m + rp_pad]),
        "out_degree": np.concatenate([a(g.out_degree), counts]),
        "in_degree": np.concatenate([a(g.in_degree), counts]),
    }


@dataclasses.dataclass
class GraphBatch:
    """B graphs packed block-diagonally into one padded :class:`Graph`.

    Graph *i* occupies vertices ``[i*n_q, i*n_q + n_i)`` (then padding
    to ``(i+1)*n_q``) and edges ``[i*m_q, i*m_q + m_i)`` of ``packed``.
    ``n_nodes_b``/``n_edges_b`` carry the **true** per-graph sizes the
    per-graph heuristics use.

    Lifecycle: the batch holds its packed graph and the member graphs
    ``1..B-1`` strongly (so their ids cannot recycle under the
    ``batch_pack`` cache entry) but the *anchor* graph ``0`` only
    weakly — the cache entry is keyed on the anchor's identity, so when
    the anchor is collected the entry is evicted and the whole chain
    (batch, packed graph, its contexts and compiled runners) dies with
    it instead of leaking.
    """
    packed: Graph
    n_q: int
    m_q: int
    n_nodes_b: np.ndarray
    n_edges_b: np.ndarray
    _anchor: Any = dataclasses.field(repr=False, default=None)
    _pinned: tuple = dataclasses.field(repr=False, default=())

    @property
    def size(self) -> int:
        return int(self.n_nodes_b.shape[0])

    @property
    def n_total(self) -> int:
        return self.size * self.n_q

    # ------------------------------------------------------------------
    def pack_state(self, states: Sequence[Any], pad: Optional[dict] = None):
        """Pack per-graph state pytrees into the block-diagonal layout.

        Per-graph ``[n_i, ...]`` vertex leaves become one
        ``[B*n_q, ...]`` leaf (padding rows zero-filled — inert, because
        padding vertices carry only self-loops and their segments are
        frozen from iteration 0); scalar leaves stack to ``[B]``.

        ``pad`` (a program's :attr:`~repro.core.vertex_program.
        VertexProgram.state_pad`) overrides the padding fill per state
        key, for programs whose zero value is *live* rather than inert
        — MIS pads ``status`` with 2 ("removed") because a padding row
        of undecided zeros would block per-graph convergence forever.
        """
        if len(states) != self.size:
            raise ValueError(f"expected {self.size} states, "
                             f"got {len(states)}")
        states = [jax.tree.map(jnp.asarray, s) for s in states]
        ns = [int(n) for n in self.n_nodes_b]

        def pack_leaf(fill, *ls):
            if ls[0].ndim == 0:
                return jnp.stack(ls)
            rows = []
            for leaf, n in zip(ls, ns):
                if leaf.shape[0] != n:
                    raise ValueError(
                        "state leaves must be per-vertex ([n, ...]) or "
                        f"scalar; got shape {leaf.shape} for a graph "
                        f"with {n} vertices")
                p = self.n_q - n
                if p:
                    leaf = jnp.concatenate(
                        [leaf, jnp.full((p,) + leaf.shape[1:], fill,
                                        leaf.dtype)])
                rows.append(leaf)
            return jnp.concatenate(rows)

        pad = pad or {}
        if pad and isinstance(states[0], dict):
            return {k: jax.tree.map(partial(pack_leaf, pad.get(k, 0)),
                                    *(s[k] for s in states))
                    for k in states[0]}
        return jax.tree.map(partial(pack_leaf, 0), *states)

    def unpack_state(self, packed_state) -> List[Any]:
        """Slice the packed state back into per-graph pytrees
        (``pack_state``'s inverse on the non-padding rows)."""
        n_total = self.n_total
        outs = []
        for i in range(self.size):
            n = int(self.n_nodes_b[i])

            def cut(a, i=i, n=n):
                if a.ndim and a.shape[0] == n_total:
                    return a[i * self.n_q: i * self.n_q + n]
                return a[i]

            outs.append(jax.tree.map(cut, packed_state))
        return outs

    # ------------------------------------------------------------------
    def pack_state_host(self, states: Sequence[Any],
                        pad: Optional[dict] = None):
        """:meth:`pack_state` on host (numpy) arrays — same layout,
        bit-identical values, no device dispatches.

        The serving gateway repacks a bucket every scheduling slice;
        doing the B-way concatenation with numpy keeps that per-slice
        host work out of the device dispatch queue (the packed leaves
        transfer once, at the jitted runner's call boundary).
        """
        if len(states) != self.size:
            raise ValueError(f"expected {self.size} states, "
                             f"got {len(states)}")
        ns = [int(n) for n in self.n_nodes_b]

        def pack_leaf(fill, *ls):
            ls = [np.asarray(l) for l in ls]
            if ls[0].ndim == 0:
                return np.stack(ls)
            rows = []
            for leaf, n in zip(ls, ns):
                if leaf.shape[0] != n:
                    raise ValueError(
                        "state leaves must be per-vertex ([n, ...]) or "
                        f"scalar; got shape {leaf.shape} for a graph "
                        f"with {n} vertices")
                p = self.n_q - n
                if p:
                    leaf = np.concatenate(
                        [leaf, np.full((p,) + leaf.shape[1:], fill,
                                       leaf.dtype)])
                rows.append(leaf)
            return np.concatenate(rows)

        pad = pad or {}
        if pad and isinstance(states[0], dict):
            return {k: jax.tree.map(partial(pack_leaf, pad.get(k, 0)),
                                    *(s[k] for s in states))
                    for k in states[0]}
        return jax.tree.map(partial(pack_leaf, 0), *states)

    def unpack_state_host(self, packed_state) -> List[Any]:
        """:meth:`unpack_state` to host (numpy) pytrees: one device
        sync per leaf, then per-graph numpy slices (copies, so the
        packed buffers are not pinned by the returned views)."""
        host = jax.tree.map(np.asarray, packed_state)
        n_total = self.n_total
        outs = []
        for i in range(self.size):
            n = int(self.n_nodes_b[i])

            def cut(a, i=i, n=n):
                if a.ndim and a.shape[0] == n_total:
                    return a[i * self.n_q: i * self.n_q + n].copy()
                return a[i]  # scalar indexing copies by construction

            outs.append(jax.tree.map(cut, host))
        return outs


def pack_graphs(graphs: Sequence[Graph]) -> GraphBatch:
    """Pack graphs into one block-diagonal padded :class:`Graph`.

    All graphs are padded to the batch bucket shape (the max of their
    per-graph :func:`bucket_shape`\\ s) so the packed arrays have shape
    ``[B*m_q]``/``[B*n_q]``; the by-src and by-dst orders are pure
    concatenations of the per-graph orders (vertex offsets are
    monotone), and the owned order is re-binned on the packed ids
    because per-graph vertex offsets need not align with block
    boundaries.
    """
    graphs = tuple(graphs)
    if not graphs:
        raise ValueError("pack_graphs needs at least one graph")
    block_size = graphs[0].block_size
    if any(g.block_size != block_size for g in graphs):
        raise ValueError("all graphs in a batch must share block_size")
    shapes = [bucket_shape(g.n_nodes, g.n_edges) for g in graphs]
    n_q = max(s[0] for s in shapes)
    m_q = max(s[1] for s in shapes)
    if any(m_q > g.n_edges and n_q == g.n_nodes for g in graphs):
        n_q *= 2  # room for the padding vertex the larger m_q now needs

    locs = [_padded_local(g, n_q, m_q) for g in graphs]
    b = len(graphs)

    def cat_edges(name, off):
        return np.concatenate([loc[name] + (i * off if off else 0)
                               for i, loc in enumerate(locs)])

    src = cat_edges("src", n_q)
    dst = cat_edges("dst", n_q)
    weight = np.concatenate([loc["weight"] for loc in locs])
    src_in = cat_edges("src_in", n_q)
    dst_in = cat_edges("dst_in", n_q)
    weight_in = np.concatenate([loc["weight_in"] for loc in locs])
    rp_out = np.concatenate(
        [loc["row_ptr_out"][:-1] + i * m_q for i, loc in enumerate(locs)]
        + [np.array([b * m_q], np.int64)])
    rp_in = np.concatenate(
        [loc["row_ptr_in"][:-1] + i * m_q for i, loc in enumerate(locs)]
        + [np.array([b * m_q], np.int64)])
    out_degree = np.concatenate([loc["out_degree"] for loc in locs])
    in_degree = np.concatenate([loc["in_degree"] for loc in locs])
    perm_owned, block_ptr = bin_edges_by_block(dst, b * n_q, block_size)

    i32 = lambda x: np.asarray(x, np.int32)
    packed = Graph(
        src=i32(src), dst=i32(dst), weight=np.float32(weight),
        row_ptr_out=i32(rp_out),
        src_in=i32(src_in), dst_in=i32(dst_in),
        weight_in=np.float32(weight_in), row_ptr_in=i32(rp_in),
        out_degree=i32(out_degree), in_degree=i32(in_degree),
        perm_owned=i32(perm_owned), block_ptr=i32(block_ptr),
        n_nodes=b * n_q, n_edges=b * m_q, block_size=int(block_size),
    )
    return GraphBatch(
        packed=packed, n_q=n_q, m_q=m_q,
        n_nodes_b=np.asarray([g.n_nodes for g in graphs], np.int64),
        n_edges_b=np.asarray([g.n_edges for g in graphs], np.int64),
        _anchor=weakref.ref(graphs[0]), _pinned=graphs[1:],
    )


def get_graph_batch(graphs: Sequence[Graph]) -> GraphBatch:
    """Cached :func:`pack_graphs`: one pack per (ordered) graph tuple.

    Keyed on the member identities and anchored on the first graph —
    see :class:`GraphBatch` for why that is safe against id recycling.
    """
    graphs = tuple(graphs)
    if not graphs:
        raise ValueError("get_graph_batch needs at least one graph")
    key = tuple(id(g) for g in graphs)
    return PLAN_CACHE.get(graphs[0], "batch_pack", key,
                          lambda: pack_graphs(graphs))


# ---------------------------------------------------------------------------
class BatchedEdgeContext:
    """A batch of graphs bound to one :class:`SystemConfig`.

    Drop-in for :class:`~repro.core.executor.EdgeContext` from a
    program's point of view — ``choose_direction`` returns ``[B]``
    per-graph flags computed from each graph's own frontier statistics
    (bit-identical to the sequential heuristic), ``propagate_sparse``
    returns ``[B]`` per-graph occupancies, and the reductions run once
    over the packed block-diagonal edge arrays through the wrapped
    packed-graph ``EdgeContext``.

    The packed *execution* direction (and the packed sparse-gather
    fallback) is a batch-level choice — the edge-weighted majority of
    the per-graph decisions — which is result-identical for the
    order-independent monoids (min/max, integer sums) the traversal
    programs reduce with; inexact float sums may differ in final ULPs
    from a sequential run, exactly like the dense-vs-gathered caveat on
    the sequential sparse path.
    """

    def __init__(self, batch: GraphBatch, config: SystemConfig,
                 use_pallas: bool = False,
                 sparse_edge_capacity: Optional[int] = None,
                 autotune=None):
        self.config = config
        self.use_pallas = use_pallas
        self.autotune = _normalize_autotune(autotune)
        self.B = batch.size
        self.n_q = batch.n_q
        self.m_q = batch.m_q
        self.n_total = batch.n_total
        #: user-level capacity knob (exec-fn cache key material): two
        #: contexts with different per-graph capacities trace different
        #: occupancy arithmetic even when the packed capacity collides.
        self.cap_key = (None if sparse_edge_capacity is None
                        else int(sparse_edge_capacity))
        n_b = batch.n_nodes_b
        m_b = batch.n_edges_b
        if sparse_edge_capacity is None:
            # per-graph sequential default: ceil(m/alpha), the same
            # formula as EdgeContext.default_sparse_capacity
            caps = np.minimum(m_b, np.maximum(16, -(-m_b // int(ALPHA))))
        else:
            caps = np.full(self.B, int(sparse_edge_capacity), np.int64)
        self._disabled = (sparse_edge_capacity is not None
                          and int(sparse_edge_capacity) == 0)
        if self._disabled:
            inner_cap: Optional[int] = 0
        elif sparse_edge_capacity is None:
            inner_cap = None  # packed default
        else:
            inner_cap = min(batch.packed.n_edges,
                            int(sparse_edge_capacity) * self.B)
        self.inner = EdgeContext.create(
            batch.packed, config, use_pallas=use_pallas,
            sparse_edge_capacity=inner_cap, autotune=self.autotune)
        self.n_nodes = batch.packed.n_nodes
        self.n_edges = batch.packed.n_edges
        self.n_nodes_b = jnp.asarray(n_b, jnp.int32)
        self.n_edges_b = jnp.asarray(m_b, jnp.int32)
        self.cap_b = jnp.asarray(caps, jnp.int32)
        self.vcap_b = jnp.asarray(
            np.maximum(1, np.minimum(n_b, caps)), jnp.int32)
        self._out_deg_rows = self.inner._out_degree.reshape(
            self.B, self.n_q)

    @classmethod
    def create(cls, batch: GraphBatch, config: SystemConfig,
               use_pallas: bool = False,
               sparse_edge_capacity: Optional[int] = None,
               autotune=None) -> "BatchedEdgeContext":
        """Cached constructor (``kind="batch_context"`` on the packed
        graph): a repeated (batch, config, knobs) cell reuses the bound
        context and, through it, the compiled batch runner."""
        cap = (None if sparse_edge_capacity is None
               else int(sparse_edge_capacity))
        mode = _normalize_autotune(autotune)
        return PLAN_CACHE.get(
            batch.packed, "batch_context",
            (config, bool(use_pallas), cap, mode),
            lambda: cls(batch, config, use_pallas=use_pallas,
                        sparse_edge_capacity=sparse_edge_capacity,
                        autotune=mode))

    # ------------------------------------------------------------------
    def resolve_direction(self, direction=None) -> UpdateProp:
        return self.inner.resolve_direction(direction)

    def choose_direction(self, frontier: jnp.ndarray, prev_pull,
                         unvisited: Optional[jnp.ndarray] = None
                         ) -> jnp.ndarray:
        """Per-graph traced direction flags ``[B]`` (True=pull).

        Each row reproduces the sequential heuristic on that graph's
        own frontier statistics and true ``(n, m)`` — the per-iteration
        direction trace of a batched run is bit-identical to the
        per-graph sequential traces.
        """
        prop = self.config.prop
        if prop is not UpdateProp.PUSH_PULL:
            return jnp.full((self.B,), prop is UpdateProp.PULL)
        rows = frontier.reshape(self.B, self.n_q)
        urows = (unvisited.reshape(self.B, self.n_q)
                 if unvisited is not None else None)
        return choose_direction_batch(rows, self._out_deg_rows,
                                      self.n_edges_b, self.n_nodes_b,
                                      prev_pull, unvisited=urows)

    def dynamic_direction(self, want_pull) -> jnp.ndarray:
        """``[B]`` per-graph flags for an algorithm-chosen direction
        (static configs: the config's constant direction, like the
        sequential context)."""
        prop = self.config.prop
        if prop is not UpdateProp.PUSH_PULL:
            return jnp.full((self.B,), prop is UpdateProp.PULL)
        return jnp.broadcast_to(jnp.asarray(want_pull, bool), (self.B,))

    # ------------------------------------------------------------------
    # Per-graph state helpers (the batched overrides of the sequential
    # trivia on EdgeContext): scalars become [B], reductions become
    # row-wise over each graph's own n_q columns.  Padding rows receive
    # their graph's broadcast value and padding columns contribute to
    # row reductions — callers keep padding inert by construction
    # (zero/state_pad fills and padding-false masks), exactly like the
    # frontier statistics.

    @property
    def true_n_nodes(self) -> jnp.ndarray:
        """``[B]`` true per-graph vertex counts (no padding rows)."""
        return self.n_nodes_b

    def per_vertex(self, x) -> jnp.ndarray:
        """``[B]`` per-graph values -> ``[B*n_q]``, each graph's rows
        (padding included) filled with that graph's value."""
        x = jnp.asarray(x)
        if x.ndim == 0:
            return jnp.broadcast_to(x, (self.n_total,))
        return jnp.repeat(x, self.n_q, total_repeat_length=self.n_total)

    def align_per_graph(self, x) -> jnp.ndarray:
        """Batched alignment must materialize: each packed row needs
        its own graph's value (the sequential version is the identity;
        see ``EdgeContext.align_per_graph``)."""
        return self.per_vertex(x)

    def per_graph_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.sum(x.reshape((self.B, self.n_q) + x.shape[1:]),
                       axis=1)

    def per_graph_any(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.any(x.reshape((self.B, self.n_q) + x.shape[1:]),
                       axis=1)

    def vertex_offsets(self) -> jnp.ndarray:
        """``[B*n_q]`` packed row base (``i*n_q``) of each vertex's
        graph — the shift that turns vertex-id-valued state (CC
        labels) into packed row indices."""
        return jnp.repeat(
            jnp.arange(self.B, dtype=jnp.int32) * jnp.int32(self.n_q),
            self.n_q, total_repeat_length=self.n_total)

    def cond_per_graph(self, pred, true_fn, false_fn, state):
        """Per-graph branch select: both branches execute on the packed
        arrays (graphs may disagree — lax.cond needs one predicate) and
        each graph's rows keep its own branch's result via the freeze
        selector."""
        return self.freeze(jnp.asarray(pred, bool),
                           true_fn(state), false_fn(state))

    # ------------------------------------------------------------------
    def _frontier_edges_b(self, mask: jnp.ndarray) -> jnp.ndarray:
        rows = mask.reshape(self.B, self.n_q)
        return jnp.sum(jnp.where(rows, self._out_deg_rows, 0), axis=1)

    def _exec_direction(self, state, phase: EdgePhase, pull_b) -> jnp.ndarray:
        """The batch's single packed execution direction: the
        edge-weighted majority of the per-graph choices (graphs with an
        empty frontier — converged ones included — vote with weight 0).
        A perf-only choice: results are direction-independent for the
        order-independent monoids the batch path serves."""
        pull_b = jnp.asarray(pull_b, bool)
        if pull_b.ndim == 0:
            return pull_b
        if phase.frontier is None:
            return jnp.sum(pull_b.astype(jnp.int32)) * 2 > self.B
        m_f = self._frontier_edges_b(phase.frontier(state))
        m_pull = jnp.sum(jnp.where(pull_b, m_f, 0))
        m_push = jnp.sum(jnp.where(pull_b, 0, m_f))
        return m_pull > m_push

    def propagate(self, state, phase: EdgePhase, direction=None,
                  dtype=jnp.float32) -> jnp.ndarray:
        return self.inner.propagate(state, phase, direction, dtype)

    def propagate_dynamic(self, state, phase: EdgePhase, pull,
                          dtype=jnp.float32) -> jnp.ndarray:
        if self.config.prop is not UpdateProp.PUSH_PULL:
            return self.inner.propagate_dynamic(state, phase, False, dtype)
        return self.inner.propagate_dynamic(
            state, phase, self._exec_direction(state, phase, pull), dtype)

    def propagate_sparse(self, state, phase: EdgePhase, pull,
                         dtype=jnp.float32):
        """Batched ``propagate_sparse``: ``(reduced [B*n_q], occ [B])``.

        The occupancy vector carries each graph's *sequential*
        semantics — ``m_f / cap`` against that graph's own capacity
        when its sequential run would have taken the gathered push
        path, -1.0 otherwise — so per-graph occupancy traces unbatch
        bit-identically.  The reduction itself runs once over the
        packed arrays (packed sparse gather when the whole batch
        frontier fits the packed capacity, dense otherwise).
        """
        dense_b = jnp.full((self.B,), dense_occupancy())
        if (self.config.prop is not UpdateProp.PUSH_PULL
                or phase.frontier is None or not phase.gatherable
                or self._disabled):
            return (self.propagate_dynamic(state, phase, pull, dtype),
                    dense_b)
        pull_b = jnp.asarray(pull, bool)
        if pull_b.ndim == 0:
            pull_b = jnp.broadcast_to(pull_b, (self.B,))
        mask = phase.frontier(state)
        rows = mask.reshape(self.B, self.n_q)
        m_f = jnp.sum(jnp.where(rows, self._out_deg_rows, 0), axis=1)
        n_f = jnp.sum(rows.astype(jnp.int32), axis=1)
        fits = (n_f <= self.vcap_b) & (m_f <= self.cap_b)
        occ = jnp.where(
            fits,
            m_f.astype(jnp.float32) / self.cap_b.astype(jnp.float32),
            dense_occupancy())
        occ = jnp.where(pull_b, dense_occupancy(), occ)
        m_pull = jnp.sum(jnp.where(pull_b, m_f, 0))
        m_push = jnp.sum(jnp.where(pull_b, 0, m_f))
        out, _ = self.inner.propagate_sparse(
            state, phase, m_pull > m_push, dtype)
        return out, occ

    # ------------------------------------------------------------------
    def per_graph_view(self, state):
        """Reshape packed leaves into per-graph rows: ``[B*n_q, ...]``
        -> ``[B, n_q, ...]``, ``[B]`` stays — the axis-0 view
        ``vmap``/``converged`` consume."""
        def rows(a):
            if a.ndim and a.shape[0] == self.n_total:
                return a.reshape((self.B, self.n_q) + a.shape[1:])
            return a
        return jax.tree.map(rows, state)

    def converged_per_graph(self, program: VertexProgram, prev,
                            new) -> jnp.ndarray:
        """``[B]`` per-graph convergence verdicts: the program's own
        ``converged`` vmapped over per-graph state rows.  Padding
        columns are zero-filled and frozen, so each row's verdict
        equals the sequential one."""
        return jax.vmap(program.converged)(self.per_graph_view(prev),
                                           self.per_graph_view(new))

    def freeze(self, done_b: jnp.ndarray, old, new):
        """Keep ``old`` state for graphs whose ``done`` flag is set.

        This is the convergence mask that makes extra batch iterations
        invisible to already-converged graphs: their unbatched state is
        exactly the state after their own final iteration.
        """
        def sel(o, n):
            if o.ndim and o.shape[0] == self.n_total:
                keep = jnp.repeat(done_b, self.n_q).reshape(
                    (self.n_total,) + (1,) * (o.ndim - 1))
            else:
                keep = done_b.reshape((self.B,) + (1,) * (o.ndim - 1))
            return jnp.where(keep, o, n)
        return jax.tree.map(sel, old, new)


# ---------------------------------------------------------------------------
def run_fused_batch(program: VertexProgram, batch: GraphBatch,
                    bctx: BatchedEdgeContext, state, limit: int,
                    warmup: bool) -> List[RunResult]:
    """One fused ``lax.while_loop`` dispatch for the whole batch.

    Carry layout: ``(state, it, it_b, done_b, dir_buf, occ_buf)`` —
    per-graph iteration counts ``it_b [B]`` advance while a graph's
    ``done_b`` flag is unset, the per-graph done flags mask state
    updates (:meth:`BatchedEdgeContext.freeze`) and fold into the
    single convergence predicate ``(it < limit) & ~all(done_b)``, and
    the ``[B, limit]`` trace buffers record each graph's per-iteration
    direction/occupancy exactly as the sequential fused engine does in
    its ``[limit]`` buffers.
    """
    B = bctx.B
    traced, occ_traced = _trace_flags(program, state)
    dir_buf = jnp.zeros((B, limit), bool) if traced else None
    occ_buf = (jnp.full((B, limit), dense_occupancy())
               if occ_traced else None)

    def fused(st, db, ob):
        def cond(carry):
            _, it, _, done_b, _, _ = carry
            return (it < limit) & ~jnp.all(done_b)

        def body(carry):
            st, it, it_b, done_b, db, ob = carry
            new = program.step(bctx, st, it)
            conv = bctx.converged_per_graph(program, st, new)
            merged = bctx.freeze(done_b, st, new)
            it_b = it_b + jnp.where(done_b, 0, 1).astype(jnp.int32)
            if traced:
                col = jnp.asarray(merged[FRONTIER_DIR_KEY], bool)
                db = jax.lax.dynamic_update_slice(db, col[:, None], (0, it))
            if occ_traced:
                col = jnp.asarray(merged[FRONTIER_OCC_KEY], jnp.float32)
                ob = jax.lax.dynamic_update_slice(ob, col[:, None], (0, it))
            return (merged, it + jnp.int32(1), it_b, done_b | conv,
                    db, ob)

        return jax.lax.while_loop(
            cond, body,
            (st, jnp.int32(0), jnp.zeros((B,), jnp.int32),
             jnp.zeros((B,), bool), db, ob))

    def build():
        fn = jax.jit(fused, donate_argnums=(0, 1, 2))
        if warmup:
            fn = fn.lower(state, dir_buf, occ_buf).compile()
        return program, fn

    fn = _cached_exec_fn(
        program, bctx.inner,
        ("batched", B, bctx.n_q, bctx.m_q, limit, traced, occ_traced,
         bctx.cap_key), build)
    t0 = time.perf_counter()
    STATS.dispatches += 1
    state, it_dev, it_b_dev, done_dev, db, ob = fn(state, dir_buf, occ_buf)
    jax.block_until_ready((state, it_dev, it_b_dev, done_dev, db, ob))
    dt = time.perf_counter() - t0
    return _decode_batch_results(batch, state, it_b_dev, done_dev, db, ob,
                                 traced, occ_traced, dt)


def _decode_batch_results(batch: GraphBatch, state, it_b_dev, done_dev,
                          db, ob, traced: bool, occ_traced: bool,
                          dt: float) -> List[RunResult]:
    # the batch's single host sync is above; everything below is decoding
    it_b = np.asarray(it_b_dev)
    done_b = np.asarray(done_dev)
    db_np = np.asarray(db) if traced else None
    ob_np = np.asarray(ob) if occ_traced else None
    states = batch.unpack_state(state)
    results = []
    for i in range(batch.size):
        k = int(it_b[i])
        trace = ("".join("T" if b else "S" for b in db_np[i, :k])
                 if traced else None)
        occs = ([float(o) for o in ob_np[i, :k]] if occ_traced else None)
        results.append(RunResult(
            state=states[i], iterations=k, seconds=dt / batch.size,
            converged=bool(done_b[i]), direction_trace=trace,
            occupancy_trace=occs, engine="batched", dispatches=1))
    return results


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchSlice:
    """One continuous-batching dispatch's outputs, decoded to host.

    ``advanced[i]`` is how many iterations graph *i* executed inside
    this slice; its per-iteration direction/occupancy columns are
    ``dir_cols[i, :advanced[i]]`` / ``occ_cols[i, :advanced[i]]``
    (``None`` when the program does not trace).  ``state`` stays a
    packed device pytree so the next slice can consume it without a
    host round-trip; ``converged_b`` reports per-graph convergence
    (reaching ``limit_b`` does *not* set it — callers distinguish
    "converged" from "out of budget" via ``it_b``).
    """
    state: Any
    it_b: np.ndarray
    converged_b: np.ndarray
    advanced: np.ndarray
    dir_cols: Optional[np.ndarray]
    occ_cols: Optional[np.ndarray]
    seconds: float


def run_batch_slice(program: VertexProgram, batch: GraphBatch,
                    bctx: BatchedEdgeContext, state,
                    it_b, done_b, limit_b, slice_len: int,
                    warmup: bool = True) -> BatchSlice:
    """Advance the packed batch by **up to** ``slice_len`` iterations.

    The continuous-batching engine under the serving gateway: unlike
    :func:`run_fused_batch` (every graph starts at iteration 0 and the
    loop runs to whole-batch convergence), this dispatch resumes each
    graph from its own carried ``it_b[i]`` and stops early at the slice
    boundary, where the scheduler can retire converged graphs and join
    newly admitted ones before the next dispatch.

    Per-graph semantics are exact across slicing and batch-composition
    churn:

    - ``program.step`` receives the **per-graph** iteration counters
      (``[B]`` int32) instead of a batch-level scalar — a graph that
      joined mid-stream sees its own 0, 1, 2, ... exactly as its
      sequential run would (CC's alternating hooking direction and
      CLR's round-numbered colors depend on this).
    - a graph stops advancing once it converges *or* reaches its own
      ``limit_b[i]`` (per-request ``max_iters``); its rows freeze, so
      cohabitating graphs see nothing.
    - ``done_b`` marks slots the scheduler parked (free slots between
      requests): their rows are frozen from the first iteration and
      their trace columns never read.

    One timed jitted dispatch per call; the compiled runner is cached
    per (program, packed graph, slice_len, capacities), so steady-state
    serving traffic over a stable bucket roster re-enters a compiled
    executable every slice.
    """
    B = bctx.B
    traced, occ_traced = _trace_flags(program, state)
    dir_buf = jnp.zeros((B, slice_len), bool) if traced else None
    occ_buf = (jnp.full((B, slice_len), dense_occupancy())
               if occ_traced else None)
    it_b = jnp.asarray(np.asarray(it_b, np.int32))
    done_b0 = jnp.asarray(np.asarray(done_b, bool))
    limit_b = jnp.asarray(np.asarray(limit_b, np.int32))

    def sliced(st, it_b, parked_b, limit_b, db, ob):
        def stopped(conv_b, it_b):
            return parked_b | conv_b | (it_b >= limit_b)

        def cond(carry):
            _, s, it_b, conv_b, _, _ = carry
            return (s < slice_len) & ~jnp.all(stopped(conv_b, it_b))

        def body(carry):
            st, s, it_b, conv_b, db, ob = carry
            frozen = stopped(conv_b, it_b)
            new = program.step(bctx, st, it_b)
            conv = bctx.converged_per_graph(program, st, new)
            merged = bctx.freeze(frozen, st, new)
            it_b = it_b + jnp.where(frozen, 0, 1).astype(jnp.int32)
            conv_b = conv_b | (conv & ~frozen)
            if traced:
                col = jnp.asarray(merged[FRONTIER_DIR_KEY], bool)
                db = jax.lax.dynamic_update_slice(db, col[:, None], (0, s))
            if occ_traced:
                col = jnp.asarray(merged[FRONTIER_OCC_KEY], jnp.float32)
                ob = jax.lax.dynamic_update_slice(ob, col[:, None], (0, s))
            return (merged, s + jnp.int32(1), it_b, conv_b, db, ob)

        return jax.lax.while_loop(
            cond, body,
            (st, jnp.int32(0), it_b, jnp.zeros((B,), bool), db, ob))

    def build():
        fn = jax.jit(sliced, donate_argnums=(0, 4, 5))
        if warmup:
            fn = fn.lower(state, it_b, done_b0, limit_b,
                          dir_buf, occ_buf).compile()
        return program, fn

    fn = _cached_exec_fn(
        program, bctx.inner,
        ("batched_slice", B, bctx.n_q, bctx.m_q, slice_len, traced,
         occ_traced, bctx.cap_key), build)
    t0 = time.perf_counter()
    STATS.dispatches += 1
    out_state, _, it_out, conv_out, db, ob = fn(
        state, it_b, done_b0, limit_b, dir_buf, occ_buf)
    jax.block_until_ready((out_state, it_out, conv_out, db, ob))
    dt = time.perf_counter() - t0
    it_in = np.asarray(it_b)
    it_np = np.asarray(it_out)
    return BatchSlice(
        state=out_state,
        it_b=it_np,
        converged_b=np.asarray(conv_out),
        advanced=it_np - it_in,
        dir_cols=np.asarray(db) if traced else None,
        occ_cols=np.asarray(ob) if occ_traced else None,
        seconds=dt,
    )
