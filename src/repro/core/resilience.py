"""Execution-core resilience: checkpointed runs, sentinels, recovery.

The fused engine (PR 3) buys its speed by putting the *entire*
convergence loop inside one ``lax.while_loop`` dispatch — which also
means a NaN, a divergent iteration or a runner exception loses the
whole run.  This module segments that loop into bounded fused
dispatches and wraps them in the recovery machinery the ROADMAP's
"handles as many scenarios as you can imagine" leg asks for:

- **Checkpointed execution** — ``run(..., checkpoint_every=K)`` drives
  the *same* compiled loop body in K-iteration fused segments (the
  segment end is a traced operand, so ONE compiled executable serves
  every segment) and snapshots the carry into a bounded host-side
  :class:`CheckpointRing` at each boundary.  Segmenting never changes
  the per-iteration math, so checkpointed runs are bit-identical to
  the unsegmented fused engine.
- **Invariant sentinels** — evaluated on-device inside the segment
  dispatch, comparing the segment's end state against its start
  (= the last checkpoint): a NaN guard over float state, monotonicity
  monitors for MIN/MAX-monoid fixpoints (the exact property DRFrlx's
  reorderable combine relies on — and transitive, so a K-iteration
  boundary check is as strong as per-iteration), program-declared
  custom sentinels (:attr:`VertexProgram.sentinels`), and a
  frontier-occupancy sanity check over the segment's trace window.
  ``max_iters`` exhaustion becomes the structured ``"iter_limit"``
  outcome rather than a silent non-answer.
- **Fixpoint certificates** — a converged state is additionally proved
  with one O(E) :attr:`VertexProgram.certificate` propagate.  This is
  what catches dropped-update staleness: a vertex reverted to the
  value it already had at the last checkpoint is invisible to every
  boundary sentinel, but cannot satisfy the fixpoint equations.
- **Recovery** — :class:`RetryPolicy` rolls back to a clean checkpoint
  and re-executes; each retry rolls back one checkpoint deeper (a
  corruption that slipped past the boundary checks is healed by
  resuming from an older snapshot) and walks a degradation chain:
  retry-as-is → autotuned tiling → default plans → sparse frontier →
  dense → fused engine → host engine.  Exhausted attempts return a
  structured ``outcome="faulted"`` :class:`~repro.core.executor.
  RunResult` carrying the fault history — never a silently wrong
  state.

The gateway (:mod:`repro.launch.serve`) reuses the host-side pieces:
:func:`check_state_host` between scheduling slices and
:func:`check_certificate` at convergence, quarantining only the
offending slot.  :mod:`repro.testing.faults` subclasses
:class:`FaultInjector` to drive all of this under seeded fault
injection.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config_space import SystemConfig, UpdateProp
from repro.core.executor import (EdgeContext, RunResult, STATS,
                                 _cached_exec_fn, _normalize_autotune,
                                 _trace_flags)
from repro.core.vertex_program import (DENSE_OCC, FRONTIER_DIR_KEY,
                                       FRONTIER_OCC_KEY, VertexProgram,
                                       dense_occupancy)
from repro.graph.structure import Graph

__all__ = ["Checkpoint", "CheckpointRing", "RetryPolicy", "ExecutionFault",
           "FaultInjector", "run_resilient", "build_sentinels",
           "check_state_host", "check_certificate",
           "DEFAULT_CHECKPOINT_EVERY", "DEFAULT_RING_CAPACITY"]

#: Default segment length for ``checkpoint_every=True``-style callers
#: (benchmarks, gateway).  Most pinned workloads converge in a couple
#: of segments at this interval, so the boundary cost (one host
#: snapshot + one sentinel reduction per segment) stays <5% of run
#: time while still bounding the work a fault can lose.
DEFAULT_CHECKPOINT_EVERY = 32

#: Default :class:`CheckpointRing` capacity: the pinned initial
#: snapshot plus the three newest boundaries.
DEFAULT_RING_CAPACITY = 4


class ExecutionFault(RuntimeError):
    """Structured execution failure: ``code`` plus a detail dict.

    Raised from :meth:`repro.launch.serve.Ticket.result` for
    quarantined gateway slots and carried in ``RunResult.fault`` for
    ``outcome="faulted"`` runs.
    """

    def __init__(self, code: str, detail: Optional[dict] = None):
        self.code = code
        self.detail = dict(detail or {})
        super().__init__(f"{code}: {self.detail}" if self.detail else code)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Recovery policy for :func:`run_resilient`.

    ``max_attempts`` counts total executions (the first try included);
    ``backoff_s`` sleeps ``backoff_s * attempt`` seconds before retry
    ``attempt`` (0 disables).  Retry ``a`` rolls back ``a`` checkpoints
    (clamped to the ring's pinned initial snapshot) and runs the
    ``a``-th rung of the degradation chain, so repeated failures both
    resume from progressively older clean state *and* shed the
    specializations most likely to be implicated.
    """
    max_attempts: int = 3
    backoff_s: float = 0.0


@dataclasses.dataclass
class Checkpoint:
    """One carry snapshot: host-side state plus loop/trace position."""
    it: int
    done: bool
    state: Any                          # host numpy pytree
    dir_buf: Optional[np.ndarray]       # [limit] bool, traced programs
    occ_buf: Optional[np.ndarray]       # [limit] float32, occ-traced


class CheckpointRing:
    """Bounded checkpoint store: the pinned *initial* snapshot plus the
    ``capacity - 1`` newest segment boundaries.

    Pinning the first snapshot means recovery can always fall back to a
    full restart even after the ring has wrapped — ``capacity=1``
    degenerates to exactly cold-restart semantics (the benchmark's
    recovery baseline).
    """

    def __init__(self, capacity: int = DEFAULT_RING_CAPACITY):
        if capacity < 1:
            raise ValueError(
                f"CheckpointRing capacity must be >= 1, got "
                f"capacity={capacity}")
        self.capacity = capacity
        self._first: Optional[Checkpoint] = None
        self._ring: deque = deque(maxlen=capacity - 1)

    def push(self, cp: Checkpoint) -> None:
        if self._first is None:
            self._first = cp
        else:
            self._ring.append(cp)

    def latest(self) -> Checkpoint:
        if self._first is None:
            raise IndexError("empty CheckpointRing")
        return self._ring[-1] if self._ring else self._first

    def rollback(self, depth: int) -> Checkpoint:
        """Discard the ``depth`` newest snapshots (they are suspect) and
        return the new latest; clamps at the pinned initial snapshot."""
        for _ in range(depth):
            if self._ring:
                self._ring.pop()
        return self.latest()

    def __len__(self) -> int:
        return (0 if self._first is None else 1) + len(self._ring)


class FaultInjector:
    """Injection points :func:`run_resilient` exposes for the seeded
    fault harness (:mod:`repro.testing.faults`).  The base class is a
    no-op; ``knob_overrides`` lets a mode force execution knobs (e.g.
    a one-element sparse capacity to force gather overflow).
    """
    knob_overrides: dict = {}

    def on_compile(self, knobs: dict) -> None:
        """Before an attempt builds/fetches its compiled runner."""

    def before_segment(self, it: int) -> None:
        """Before each segment dispatch; raise to emulate a runner
        exception."""

    def perturb(self, it: int, state, checkpoint_state) -> Optional[Any]:
        """After a segment: return a corrupted copy of the host state
        (or None to leave it alone)."""
        return None

    # gateway-side hooks (see repro.launch.serve)
    def before_slice(self, ticket_ids: List[str]) -> None:
        """Before a gateway slice dispatch; raise to fail the slice."""

    def perturb_slot(self, ticket_id: str, state) -> Optional[Any]:
        """After a gateway slice: corrupt one slot's unpacked host
        state (or None)."""
        return None


# ----------------------------------------------------------------------
# sentinels


def build_sentinels(program: VertexProgram) -> List[tuple]:
    """The program's sentinel battery as ``[(name, (prev, cur) -> ok)]``.

    Always includes the NaN guard over float state leaves (NaN only —
    +inf is legitimate state, e.g. SSSP's unreached distance), then the
    declared monotonicity monitors, then the program's custom
    sentinels.  Every predicate is written in jnp so the same callable
    runs inside the segmented fused dispatch and eagerly on host
    snapshots.
    """
    fns: List[tuple] = []

    def nan_guard(prev, cur):
        bad = [jnp.any(jnp.isnan(leaf)) for leaf in jax.tree.leaves(cur)
               if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating)]
        if not bad:
            return jnp.asarray(True)
        return ~jnp.any(jnp.stack(bad))

    fns.append(("nan", nan_guard))
    for key, order in sorted((program.monotone or {}).items()):
        if order == "non_increasing":
            fn = lambda p, c, k=key: jnp.all(c[k] <= p[k])
        elif order == "non_decreasing":
            fn = lambda p, c, k=key: jnp.all(c[k] >= p[k])
        else:
            raise ValueError(f"unknown monotone order {order!r} for "
                             f"state key {key!r}")
        fns.append((f"monotone:{key}", fn))
    for name in sorted(program.sentinels or {}):
        fns.append((name, program.sentinels[name]))
    return fns


def _sentinel_flags(sentinel_fns, prev_st, cur_st, ob, lo, hi, limit,
                    occ_traced):
    """Stacked per-sentinel health flags (True = healthy), including the
    occupancy-window check when the program traces occupancy."""
    flags = [jnp.asarray(fn(prev_st, cur_st), bool).reshape(())
             for _, fn in sentinel_fns]
    if occ_traced and ob is not None:
        idx = jnp.arange(limit)
        window = (idx >= lo) & (idx < hi)
        # a traced occupancy is either the dense sentinel or a gather
        # fill fraction in [0, 1]; NaN fails both comparisons
        valid = (ob == DENSE_OCC) | ((ob >= 0.0) & (ob <= 1.0 + 1e-5))
        flags.append(jnp.all(jnp.where(window, valid, True)))
    if not flags:
        return jnp.ones((0,), bool)
    return jnp.stack(flags)


def _sentinel_names(sentinel_fns, occ_traced) -> List[str]:
    return [n for n, _ in sentinel_fns] + (["occupancy"] if occ_traced
                                           else [])


def check_state_host(program: VertexProgram, prev, cur) -> List[str]:
    """Pure-numpy evaluation of the built-in guards (NaN + declared
    monotonicity) on host state snapshots; returns tripped names.

    This is the gateway's per-slice fast path — no device dispatch, so
    it can run per slot per slice without perturbing serving latency.
    Custom jnp sentinels and certificates run at segment boundaries /
    convergence instead.
    """
    tripped: List[str] = []
    leaves = (list(cur.values()) if isinstance(cur, dict)
              else jax.tree.leaves(cur))
    for leaf in leaves:
        a = np.asarray(leaf)
        if np.issubdtype(a.dtype, np.floating) and np.isnan(a).any():
            tripped.append("nan")
            break
    for key, order in sorted((program.monotone or {}).items()):
        p, c = np.asarray(prev[key]), np.asarray(cur[key])
        if order == "non_increasing":
            if np.any(c > p):
                tripped.append(f"monotone:{key}")
        elif np.any(c < p):
            tripped.append(f"monotone:{key}")
    return tripped


def check_certificate(program: VertexProgram, ctx: EdgeContext,
                      state) -> Optional[bool]:
    """Evaluate the program's converged-state fixpoint certificate.

    Returns None when the program declares no certificate, else the
    proof's verdict.  The jitted evaluator is plan-cached per
    (program, context) like every other compiled runner.
    """
    if program.certificate is None:
        return None

    def build():
        fn = jax.jit(lambda st: jnp.asarray(
            program.certificate(ctx, st), bool).reshape(()))
        return program, fn

    fn = _cached_exec_fn(program, ctx, ("certificate",), build)
    return bool(fn(jax.tree.map(jnp.asarray, state)))


# ----------------------------------------------------------------------
# segmented execution


class _SentinelTrip(Exception):
    """Internal: a sentinel (or certificate) rejected a segment."""

    def __init__(self, sentinels: List[str], lo: int, hi: int,
                 attempt: int, engine: str):
        self.detail = {"kind": "sentinel", "sentinels": list(sentinels),
                       "segment": [int(lo), int(hi)], "iteration": int(hi),
                       "attempt": int(attempt), "engine": engine}
        super().__init__(f"sentinel trip {sentinels} in segment "
                         f"[{lo}, {hi})")


@dataclasses.dataclass
class _Accounting:
    seconds: float = 0.0
    dispatches: int = 0


def _to_host(state):
    """Deep-copied host snapshot of a device pytree.  The explicit copy
    matters: the segment dispatch donates its carry, and a zero-copy
    numpy view of a donated buffer would be corrupted by the next
    segment."""
    return jax.tree.map(lambda x: np.asarray(x).copy(), state)


def _fused_segment_fn(program, ctx, state, limit, traced, occ_traced,
                      sentinel_fns, warmup, dir_buf, occ_buf):
    """The compiled K-iteration fused segment.

    Identical loop body to the unsegmented fused engine — only the
    ``cond`` bound changes, and the segment end is a *traced* operand,
    so one compiled executable serves every segment of every attempt
    (and the per-iteration math, hence the results, are bit-identical
    to ``engine="fused"``).  Sentinel flags are computed inside the
    same dispatch against the carry the segment started from (= the
    last checkpoint), costing no extra host round trip.
    """

    def fused_seg(st, it0, done0, db, ob, seg_end):
        def cond(carry):
            _, it, done, _, _ = carry
            return (it < seg_end) & ~done

        def body(carry):
            st, it, done, db, ob = carry
            new = program.step(ctx, st, it)
            done = program.converged(st, new)
            if traced:
                db = jax.lax.dynamic_update_index_in_dim(
                    db, jnp.asarray(new[FRONTIER_DIR_KEY], bool), it, 0)
            if occ_traced:
                ob = jax.lax.dynamic_update_index_in_dim(
                    ob, jnp.asarray(new[FRONTIER_OCC_KEY], jnp.float32),
                    it, 0)
            return new, it + jnp.int32(1), done, db, ob

        st2, it2, done2, db2, ob2 = jax.lax.while_loop(
            cond, body, (st, it0, done0, db, ob))
        flags = _sentinel_flags(sentinel_fns, st, st2, ob2, it0, it2,
                                limit, occ_traced)
        return st2, it2, done2, db2, ob2, flags

    def build():
        fn = jax.jit(fused_seg, donate_argnums=(0, 3, 4))
        if warmup:
            fn = fn.lower(state, jnp.int32(0), jnp.asarray(False),
                          dir_buf, occ_buf, jnp.int32(0)).compile()
        return program, fn

    names = tuple(n for n, _ in sentinel_fns)
    return _cached_exec_fn(
        program, ctx, ("fused_seg", limit, traced, occ_traced, names),
        build)


def _sentinel_eval_fn(program, ctx, limit, occ_traced, sentinel_fns):
    """Standalone jitted sentinel evaluation — used by the host engine's
    segment boundaries and to re-check fault-injected (perturbed)
    states, whose in-dispatch flags describe the pre-perturbation
    carry."""

    def eval_(prev, cur, ob, lo, hi):
        return _sentinel_flags(sentinel_fns, prev, cur, ob, lo, hi,
                               limit, occ_traced)

    def build():
        return program, jax.jit(eval_)

    names = tuple(n for n, _ in sentinel_fns)
    return _cached_exec_fn(
        program, ctx, ("sentinel_eval", limit, occ_traced, names), build)


def _host_step_fn(program, ctx, state, warmup):
    """The host engine's cached per-iteration step (same cache entry as
    :func:`repro.core.executor._run_host` builds)."""
    from functools import partial

    def build():
        @partial(jax.jit, donate_argnums=(0,))
        def step(st, it):
            new = program.step(ctx, st, it)
            done = program.converged(st, new)
            return new, done
        if warmup:
            copy = jax.tree.map(lambda x: x.copy(), state)
            jax.block_until_ready(step(copy, jnp.int32(0)))
        return program, step

    return _cached_exec_fn(program, ctx, ("host",), build)


def _tripped(names: List[str], flags) -> List[str]:
    arr = np.asarray(flags)
    return [names[i] for i in np.where(~arr)[0]]


def _degradation_chain(knobs0: dict, config: SystemConfig) -> List[dict]:
    """Rung ``a`` of the chain is the knob set retry attempt ``a+1``
    runs: retry-as-is first, then shed autotuned tiling, then the
    sparse frontier path (dynamic configs), then the fused engine
    itself.  Rungs that would not change anything are skipped."""
    chain = [dict(knobs0)]

    def add(**delta):
        cand = {**chain[-1], **delta}
        if cand not in chain:
            chain.append(cand)

    if knobs0["autotune"] != "off":
        add(autotune="off")
    if (config.prop is UpdateProp.PUSH_PULL
            and knobs0["sparse_edge_capacity"] != 0):
        add(sparse_edge_capacity=0)
    if chain[-1]["engine"] == "fused":
        add(engine="host")
    return chain


def _decode_traces(db, ob, it, traced, occ_traced):
    trace = None
    occ_trace = None
    if traced and db is not None:
        trace = "".join("T" if b else "S" for b in np.asarray(db)[:it])
    if occ_traced and ob is not None:
        occ_trace = [float(o) for o in np.asarray(ob)[:it]]
    return trace, occ_trace


def _segment_loop(program, ctx, cp, limit, K, ring, sentinel_fns, injector,
                  warmup, acct, attempt, traced, occ_traced, engine,
                  store=None):
    """Drive segments from checkpoint ``cp`` to convergence/limit,
    snapshotting each boundary into ``ring`` (and, when ``store`` is a
    :class:`~repro.core.durability.CheckpointStore`, spilling it to
    disk so a process death resumes from here); raises
    :class:`_SentinelTrip` (or whatever the injector raises) on
    failure."""
    names = _sentinel_names(sentinel_fns, occ_traced)
    check = bool(names)
    state = jax.tree.map(jnp.asarray, cp.state)
    it, done = cp.it, cp.done
    prev_host = cp.state
    eval_fn = (_sentinel_eval_fn(program, ctx, limit, occ_traced,
                                 sentinel_fns) if check else None)
    if engine == "fused":
        db = jnp.asarray(cp.dir_buf) if traced else None
        ob = jnp.asarray(cp.occ_buf) if occ_traced else None
        seg_fn = _fused_segment_fn(program, ctx, state, limit, traced,
                                   occ_traced, sentinel_fns, warmup, db, ob)
    else:
        db = cp.dir_buf.copy() if traced else None
        ob = cp.occ_buf.copy() if occ_traced else None
        step = _host_step_fn(program, ctx, state, warmup)

    while it < limit and not done:
        lo = it
        seg_end = min(it + K, limit)
        if injector is not None:
            injector.before_segment(it)
        t0 = time.perf_counter()
        if engine == "fused":
            STATS.dispatches += 1
            acct.dispatches += 1
            state, it_dev, done_dev, db, ob, flags = seg_fn(
                state, jnp.int32(it), jnp.asarray(done), db, ob,
                jnp.int32(seg_end))
            jax.block_until_ready((state, it_dev, done_dev, flags))
            acct.seconds += time.perf_counter() - t0
            it, done = int(it_dev), bool(done_dev)
        else:
            flags = None
            while it < seg_end:
                STATS.dispatches += 1
                acct.dispatches += 1
                state, done_dev = step(state, jnp.int32(it))
                it += 1
                if traced:
                    db[it - 1] = bool(state[FRONTIER_DIR_KEY])
                if occ_traced:
                    ob[it - 1] = float(state[FRONTIER_OCC_KEY])
                done = bool(done_dev)
                if done:
                    break
            jax.block_until_ready(state)
            acct.seconds += time.perf_counter() - t0

        host_state = _to_host(state)
        if injector is not None:
            p = injector.perturb(it, host_state, prev_host)
            if p is not None:
                host_state = p
                state = jax.tree.map(jnp.asarray, host_state)
                flags = None  # in-dispatch flags predate the perturbation
        if check and flags is None and eval_fn is not None:
            ob_dev = ob if engine == "fused" else (
                jnp.asarray(ob) if occ_traced else None)
            flags = eval_fn(jax.tree.map(jnp.asarray, prev_host), state,
                            ob_dev, jnp.int32(lo), jnp.int32(it))
        if check:
            bad = _tripped(names, flags)
            if bad:
                raise _SentinelTrip(bad, lo, it, attempt, engine)
        boundary = Checkpoint(
            it=it, done=done, state=host_state,
            dir_buf=(np.asarray(db).copy() if traced else None),
            occ_buf=(np.asarray(ob).copy() if occ_traced else None))
        ring.push(boundary)
        if store is not None:
            store.save(boundary)
        prev_host = host_state

    if done and check and program.certificate is not None:
        if check_certificate(program, ctx, state) is False:
            raise _SentinelTrip(["certificate"], it, it, attempt, engine)
    trace, occ_trace = _decode_traces(db, ob, it, traced, occ_traced)
    return RunResult(state=state, iterations=it, seconds=acct.seconds,
                     converged=done, direction_trace=trace,
                     occupancy_trace=occ_trace, engine=engine,
                     dispatches=acct.dispatches, attempts=attempt + 1)


def run_resilient(program: VertexProgram, graph: Graph,
                  config: SystemConfig,
                  key: Optional[jax.Array] = None,
                  max_iters: Optional[int] = None,
                  use_pallas: bool = False, warmup: bool = True,
                  sparse_edge_capacity: Optional[int] = None,
                  engine: str = "fused", autotune=None,
                  checkpoint_every: int = 0,
                  retry: Optional[RetryPolicy] = None,
                  sentinels: bool = True,
                  ring_capacity: Optional[int] = None,
                  fault_injector: Optional[FaultInjector] = None,
                  checkpoint_dir: Optional[str] = None
                  ) -> RunResult:
    """Checkpointed, sentinel-guarded, retrying counterpart of
    :func:`repro.core.executor.run` (which delegates here whenever any
    resilience knob is set).  Results are bit-identical to the plain
    engines; ``RunResult.outcome`` reports ``"converged"``,
    ``"iter_limit"`` or ``"faulted"`` (with the fault history attached
    under ``RunResult.fault``).

    ``checkpoint_dir`` makes the run *crash-durable*: every ring
    boundary is also spilled to a :class:`~repro.core.durability.
    CheckpointStore` under that directory, and a fresh call pointed at
    the same directory resumes from the newest intact on-disk boundary
    instead of iteration 0 — bit-identical to an uninterrupted run,
    since segment boundaries fall on the same iteration multiples
    either way.  Corrupt or foreign generations are rejected at load
    (structured ``corrupt_checkpoint`` / ``checkpoint_mismatch``
    records in the fault history) and recovery falls back generation by
    generation, ultimately to a cold restart."""
    if engine not in ("fused", "host"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "expected 'fused' or 'host'")
    limit = max_iters or program.max_iters
    K = int(checkpoint_every) if checkpoint_every else \
        DEFAULT_CHECKPOINT_EVERY
    if K < 1:
        raise ValueError(f"checkpoint_every must be >= 1, got {K}")
    knobs0 = {"engine": engine,
              "autotune": _normalize_autotune(autotune),
              "sparse_edge_capacity": sparse_edge_capacity,
              "use_pallas": bool(use_pallas)}
    injector = fault_injector
    if injector is not None and getattr(injector, "knob_overrides", None):
        knobs0.update(injector.knob_overrides)
    chain = _degradation_chain(knobs0, config)
    max_attempts = retry.max_attempts if retry is not None else 1
    if max_attempts < 1:
        raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")

    capacity = ring_capacity or DEFAULT_RING_CAPACITY
    store = None
    faults: List[dict] = []
    ring = CheckpointRing(capacity)
    if checkpoint_dir is not None:
        from repro.core.durability import CheckpointStore
        from repro.launch.journal import _serialize_key, graph_fingerprint
        # the fingerprint must pin everything the resumed state depends
        # on: names and shapes alone let a same-shape graph with
        # different edges/weights (or a rerun under a different PRNG
        # key) silently adopt the wrong run's checkpoints, so the graph
        # is identified by content hash and the key rides along verbatim
        store = CheckpointStore(
            checkpoint_dir, keep=capacity,
            fingerprint={"program": program.name, "config": config.name,
                         "n_nodes": int(graph.n_nodes),
                         "n_edges": int(graph.n_edges),
                         "graph_sha256": graph_fingerprint(graph),
                         "key": _serialize_key(key),
                         "limit": int(limit), "k": int(K)})
        disk_cps, disk_faults = store.load_all()
        faults.extend(disk_faults)
        for disk_cp in disk_cps:
            ring.push(disk_cp)
    if len(ring):
        # resumed: the newest intact on-disk boundary replaces
        # program.init — segment boundaries are deterministic multiples
        # of K, so the remaining segments are bit-identical to what the
        # killed run would have executed
        seed_cp = ring.latest()
        traced = seed_cp.dir_buf is not None
        occ_traced = seed_cp.occ_buf is not None
    else:
        state0 = program.init(graph, key) if key is not None \
            else program.init(graph)
        state0 = jax.tree.map(jnp.asarray, state0)
        traced, occ_traced = _trace_flags(program, state0)
        initial = Checkpoint(
            it=0, done=False, state=_to_host(state0),
            dir_buf=np.zeros((limit,), bool) if traced else None,
            occ_buf=(np.full((limit,), DENSE_OCC, np.float32)
                     if occ_traced else None))
        ring.push(initial)
        if store is not None:
            store.save(initial)
    sentinel_fns = build_sentinels(program) if sentinels else []
    acct = _Accounting()
    attempt = 0
    while True:
        knobs = knobs0 if attempt == 0 \
            else chain[min(attempt - 1, len(chain) - 1)]
        # each retry rolls back one checkpoint deeper: snapshots taken
        # during the failed attempt passed the boundary checks but may
        # still carry a corruption only the certificate would see
        cp = ring.rollback(attempt) if attempt else ring.latest()
        try:
            ctx = EdgeContext.create(
                graph, config, use_pallas=knobs["use_pallas"],
                sparse_edge_capacity=knobs["sparse_edge_capacity"],
                autotune=knobs["autotune"])
            if injector is not None:
                injector.on_compile(knobs)
            res = _segment_loop(program, ctx, cp, limit, K, ring,
                                sentinel_fns, injector, warmup, acct,
                                attempt, traced, occ_traced,
                                knobs["engine"], store=store)
            if faults:
                res.fault = {"history": faults, "recovered": True}
            return res
        except _SentinelTrip as trip:
            faults.append(trip.detail)
        except Exception as err:  # noqa: BLE001 — recovery is the point
            faults.append({"kind": "exception", "error": repr(err),
                           "attempt": attempt,
                           "engine": knobs["engine"]})
        attempt += 1
        if attempt >= max_attempts:
            cp = ring.latest()
            trace, occ_trace = _decode_traces(
                cp.dir_buf, cp.occ_buf, cp.it, traced, occ_traced)
            return RunResult(
                state=jax.tree.map(jnp.asarray, cp.state),
                iterations=cp.it, seconds=acct.seconds, converged=False,
                direction_trace=trace, occupancy_trace=occ_trace,
                engine=knobs["engine"], dispatches=acct.dispatches,
                outcome="faulted",
                fault={"history": faults, "final": faults[-1],
                       "recovered": False},
                attempts=attempt)
        if retry is not None and retry.backoff_s:
            time.sleep(retry.backoff_s * attempt)
