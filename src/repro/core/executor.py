"""Configuration-specialized execution of vertex programs (paper Sec. II).

:class:`EdgeContext` binds a graph to a :class:`SystemConfig` and exposes
``propagate`` — the single entry point through which an algorithm's
edge-propagated updates execute.  The config picks:

- edge order + reduction flavour (push: by-src order, unsorted scatter;
  pull: by-dst order, sorted segmented reduce; owned: dst-block-binned),
- the accumulation locality (coherence: LLC vs owned/VMEM-blocked),
- the chunking/overlap schedule (consistency: DRF0/DRF1/DRFrlx).

``run`` drives a program to convergence with a jitted, donated step.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coherence import segment_reduce, segment_reduce_owned
from repro.core.config_space import (Coherence, Consistency, SystemConfig,
                                     UpdateProp)
from repro.core.consistency import scheduled_reduce
from repro.core.vertex_program import EdgePhase, Monoid, VertexProgram
from repro.graph.structure import Graph

__all__ = ["EdgeContext", "RunResult", "run"]


def _pad_reshape(arr, n_chunks, fill):
    e = arr.shape[0]
    ec = -(-e // n_chunks)  # ceil
    pad = ec * n_chunks - e
    if pad:
        arr = jnp.concatenate([arr, jnp.full((pad,), fill, arr.dtype)])
    return arr.reshape(n_chunks, ec)


class EdgeContext:
    """Graph + SystemConfig bound together; reusable across iterations."""

    def __init__(self, graph: Graph, config: SystemConfig,
                 use_pallas: bool = False):
        self.graph = graph
        self.config = config
        self.use_pallas = use_pallas
        self.n_nodes = graph.n_nodes
        g = graph.device_put()
        n_chunks = 1 if config.consistency is Consistency.DRF0 \
            else config.n_chunks
        v = graph.n_nodes
        # Pre-chunked edge arrays per direction.  Padding edges carry the
        # sentinel id V on both endpoints; they reduce into the extra
        # segment V and contribute the identity regardless.
        def chunked(src, dst, w):
            return (_pad_reshape(src, n_chunks, v),
                    _pad_reshape(dst, n_chunks, v),
                    _pad_reshape(w, n_chunks, 0.0))

        self._reducer = None
        if config.coherence is Coherence.DENOVO:
            so, do, wo = g.edges_owned()
            self._push_edges = chunked(so, do, wo)
            if use_pallas:
                from repro.kernels.segment_reduce import \
                    BlockedSegmentReducer
                self._owned_raw = (so, do, wo)
                self._reducer = BlockedSegmentReducer(
                    np.asarray(do), np.asarray(graph.block_ptr),
                    num_segments=v, block_size=graph.block_size)
        else:
            self._push_edges = chunked(g.src, g.dst, g.weight)
        self._pull_edges = chunked(g.src_in, g.dst_in, g.weight_in)
        self.n_chunks = n_chunks

    # ------------------------------------------------------------------
    def propagate(self, state, phase: EdgePhase,
                  direction: Optional[UpdateProp] = None,
                  dtype=jnp.float32) -> jnp.ndarray:
        """Execute one edge-propagated reduction; returns [V] reduced."""
        cfg = self.config
        direction = direction or cfg.prop
        if direction is UpdateProp.PUSH_PULL:
            direction = UpdateProp.PUSH  # dynamic apps pick per call-site
        pull = direction is UpdateProp.PULL
        src_c, dst_c, w_c = self._pull_edges if pull else self._push_edges
        v = self.n_nodes
        monoid = phase.monoid
        ident = monoid.identity(dtype)

        if self._reducer is not None and not pull:
            # Pallas owned-block kernel: the whole (unpadded) edge set in
            # owned order; masked edges contribute the monoid identity,
            # kernel-internal DMA pipelining plays the consistency role.
            so, do, wo = self._owned_raw
            mask = jnp.ones(so.shape, bool)
            if phase.spred is not None:
                mask &= phase.spred(state, so)
            if phase.tpred is not None:
                mask &= phase.tpred(state, do)
            msg = phase.vprop(state, so, wo).astype(dtype)
            msg = jnp.where(mask, msg, ident)
            return self._reducer.reduce(msg, monoid.name)

        def chunk_reduce(i):
            src = jax.lax.dynamic_index_in_dim(src_c, i, keepdims=False)
            dst = jax.lax.dynamic_index_in_dim(dst_c, i, keepdims=False)
            w = jax.lax.dynamic_index_in_dim(w_c, i, keepdims=False)
            sv = jnp.minimum(src, v - 1)
            tv = jnp.minimum(dst, v - 1)
            mask = (src < v) & (dst < v)
            if phase.spred is not None:
                mask &= phase.spred(state, sv)
            if phase.tpred is not None:
                mask &= phase.tpred(state, tv)
            msg = phase.vprop(state, sv, w).astype(dtype)
            msg = jnp.where(mask, msg, ident)
            ids = jnp.where(mask, dst, v)
            if pull:
                # by-dst order: sorted ids -> dense local (non-atomic)
                # update (chunks of a sorted array stay sorted)
                return segment_reduce(msg, ids, v + 1, monoid,
                                      indices_are_sorted=True)
            if cfg.coherence is Coherence.DENOVO:
                return segment_reduce_owned(msg, ids, v + 1, monoid)
            return segment_reduce(msg, ids, v + 1, monoid)

        out = scheduled_reduce(chunk_reduce, self.n_chunks,
                               cfg.consistency, monoid)
        return out[:v]


@dataclasses.dataclass
class RunResult:
    state: Any
    iterations: int
    seconds: float
    converged: bool

    def extract(self, program: VertexProgram):
        return program.extract(self.state)


def run(program: VertexProgram, graph: Graph, config: SystemConfig,
        key: Optional[jax.Array] = None, max_iters: Optional[int] = None,
        use_pallas: bool = False, warmup: bool = True) -> RunResult:
    """Iterate ``program`` on ``graph`` under ``config`` to convergence."""
    ctx = EdgeContext(graph, config, use_pallas=use_pallas)
    state = program.init(graph, key) if key is not None else program.init(graph)
    state = jax.tree.map(jnp.asarray, state)

    @partial(jax.jit, donate_argnums=(0,))
    def step(st, it):
        new = program.step(ctx, st, it)
        done = program.converged(st, new)
        return new, done

    limit = max_iters or program.max_iters
    if warmup:  # compile outside the timed region (paper times kernels only)
        # `step` donates its input, so warm the jit cache on a copy.
        copy = jax.tree.map(lambda x: x.copy(), state)
        jax.block_until_ready(step(copy, jnp.int32(0)))
    t0 = time.perf_counter()
    it, done = 0, False
    while it < limit:
        state, done_dev = step(state, jnp.int32(it))
        it += 1
        done = bool(done_dev)
        if done:
            break
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0
    return RunResult(state=state, iterations=it, seconds=dt, converged=done)
