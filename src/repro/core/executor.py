"""Configuration-specialized execution of vertex programs (paper Sec. II).

:class:`EdgeContext` binds a graph to a :class:`SystemConfig` and exposes
``propagate`` — the single entry point through which an algorithm's
edge-propagated updates execute.  The config picks:

- edge order + reduction flavour (push: by-src order, unsorted scatter;
  pull: by-dst order, sorted segmented reduce; owned: dst-block-binned),
- the accumulation locality (coherence: LLC vs owned/VMEM-blocked),
- the chunking/overlap schedule (consistency: DRF0/DRF1/DRFrlx).

Dynamic (``PUSH_PULL``) configs keep **both** pre-chunked edge orders live
and resolve the direction per call: frontier-aware programs pass a traced
boolean to :meth:`EdgeContext.propagate_dynamic` (typically computed by
:meth:`EdgeContext.choose_direction` from the current frontier), which
``lax.cond``s between the push and pull realisations inside jit.
Frontier-less programs fall back to the documented
:data:`EdgeContext.DEFAULT_DYNAMIC_DIRECTION`.

:meth:`EdgeContext.propagate_sparse` is the sparse-frontier upgrade of
``propagate_dynamic``: when the dynamic heuristic picked push *and* the
frontier's edge list fits the static gather capacity, the iteration
gathers exactly the frontier's out-edges from the CSR order
(:func:`repro.core.frontier.gather_frontier_edges`) and reduces over the
``[cap_e]`` slice (:func:`repro.kernels.segment_reduce.
gathered_segment_reduce`) — O(m_f) gathered work instead of the O(E)
masked scan.  Capacity overflow (detected via the true counts the sparse
containers carry) falls back to the dense pre-chunked path, never
dropping edges.

``run`` drives a program to convergence and records the per-iteration
direction and sparse-occupancy traces of frontier-aware programs.  Two
execution engines share the same program contract:

- ``engine="fused"`` (default): the whole convergence loop runs inside
  **one** jitted ``jax.lax.while_loop`` dispatch.  The carry holds the
  state, the iteration counter, the done flag and fixed-size
  ``[max_iters]`` device trace buffers that the loop body writes with
  ``lax.dynamic_update_index_in_dim``; the host syncs exactly once, at
  the end, and decodes the buffers into ``RunResult.direction_trace`` /
  ``occupancy_trace``.  ``RunResult.seconds`` therefore measures kernel
  work only — no per-iteration jit dispatch, no blocking convergence
  read.
- ``engine="host"``: the debugging oracle — one jitted, donated step
  per iteration with a blocking convergence read in between, the shape
  GPU frameworks call "kernel-per-iteration".  Trace scalars are
  carried off as async device copies and decoded after the timer
  stops, so host-vs-fused timing deltas are dominated by the
  per-iteration dispatch + sync cost the fused engine exists to
  remove (plus, for traced programs, two tiny async scalar-copy
  enqueues per iteration).

Construction cost is amortized by :data:`repro.core.plan_cache.
PLAN_CACHE`: the device graph, pre-chunked edge orders and blocked-
reducer tiling plans are cached per graph and shared across configs,
and whole bound contexts are reused via :meth:`EdgeContext.create`.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from functools import partial
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coherence import segment_reduce, segment_reduce_owned
from repro.core.config_space import (Coherence, Consistency, SystemConfig,
                                     UpdateProp)
from repro.core.consistency import scheduled_reduce
from repro.core.frontier import (ALPHA, choose_direction, dense_to_sparse,
                                 gather_frontier_edges)
from repro.core.plan_cache import PLAN_CACHE
from repro.core.vertex_program import (FRONTIER_DIR_KEY, FRONTIER_OCC_KEY,
                                       EdgePhase, Monoid, VertexProgram,
                                       dense_occupancy)
from repro.kernels.autotune import autotune_plan, build_reducer
from repro.kernels.segment_reduce import (DEFAULT_PLAN,
                                          gathered_segment_reduce)
from repro.graph.structure import Graph

__all__ = ["EdgeContext", "RunResult", "run", "run_batch",
           "ExecutorStats", "STATS"]


@dataclasses.dataclass
class ExecutorStats:
    """Process-wide device-dispatch counter (tests and benchmarks).

    ``dispatches`` counts *timed* jitted invocations issued by ``run``:
    the host engine increments once per iteration step, the fused
    engine exactly once per run.  Warmup compilation is not counted —
    it happens outside the timed region on both engines.
    """
    dispatches: int = 0

    def reset(self) -> None:
        self.dispatches = 0

    @staticmethod
    def plan_cache() -> dict:
        """Plan-cache counters, global and per kind.

        ``plan_cache()["by_kind"]["tuned_tiling"]`` is how autotune
        cache effectiveness (tunes vs recalls) is observed without
        reaching into :data:`~repro.core.plan_cache.PLAN_CACHE`
        directly.
        """
        return PLAN_CACHE.stats()


STATS = ExecutorStats()


def _normalize_autotune(autotune) -> str:
    """Canonicalize the ``autotune=`` knob to 'off'|'heuristic'|'measure'."""
    if autotune in (None, False, "off"):
        return "off"
    if autotune is True:
        return "measure"
    if autotune in ("heuristic", "measure"):
        return autotune
    raise ValueError(f"unknown autotune mode {autotune!r}; expected "
                     "'off', 'heuristic', 'measure' or a bool")

#: Max compiled runner executables retained per graph (LRU): generous
#: for design-space sweeps (18 cells x 2 engines fits), bounded for
#: program-per-root loops.
_EXEC_FN_CAPACITY = 64


def _pad_reshape(arr, n_chunks, fill):
    e = arr.shape[0]
    ec = -(-e // n_chunks)  # ceil
    pad = ec * n_chunks - e
    if pad:
        arr = jnp.concatenate([arr, jnp.full((pad,), fill, arr.dtype)])
    return arr.reshape(n_chunks, ec)


class EdgeContext:
    """Graph + SystemConfig bound together; reusable across iterations."""

    #: Direction used when a ``PUSH_PULL`` config meets a phase that did
    #: not resolve one (no frontier, no explicit ``direction=``).  PUSH is
    #: the safe default: the dynamic configs exist for traversal apps
    #: whose frontiers start sparse, and source-outer iteration with
    #: ``spred`` elision does no worse than pull on a sparse frontier
    #: while avoiding pull's full destination scan.  Frontier-aware
    #: programs should instead call :meth:`propagate_dynamic`.
    DEFAULT_DYNAMIC_DIRECTION = UpdateProp.PUSH

    @staticmethod
    def default_sparse_capacity(graph: Graph) -> int:
        """Default sparse-gather edge capacity: ``ceil(E/alpha)``.

        The push->pull trigger fires once ``m_f*alpha > E``, so a
        dynamic push frontier rarely carries more out-edges than that;
        anything larger falls back to the dense path via the overflow
        flags.
        """
        return min(graph.n_edges,
                   max(16, -(-graph.n_edges // int(ALPHA))))

    @classmethod
    def create(cls, graph: Graph, config: SystemConfig,
               use_pallas: bool = False,
               sparse_edge_capacity: Optional[int] = None,
               autotune=None) -> "EdgeContext":
        """Cached constructor: reuse the bound context for a repeated
        (graph, config, use_pallas, capacity, autotune) cell.

        Contexts are immutable after construction, so sharing one across
        ``run`` calls is safe; the underlying artifacts are additionally
        shared *across* configs through :data:`PLAN_CACHE` regardless of
        which constructor built them.
        """
        if sparse_edge_capacity is None:
            sparse_edge_capacity = cls.default_sparse_capacity(graph)
        cap = int(sparse_edge_capacity)
        mode = _normalize_autotune(autotune)

        def build():
            ctx = cls(graph, config, use_pallas=use_pallas,
                      sparse_edge_capacity=cap, autotune=mode)
            # a cache-owned context must not pin its graph, or the
            # cache's eviction-on-collection could never fire (cache ->
            # context -> graph would keep the graph alive forever)
            ctx._graph_strong = None
            return ctx

        return PLAN_CACHE.get(
            graph, "context", (config, bool(use_pallas), cap, mode), build)

    def __init__(self, graph: Graph, config: SystemConfig,
                 use_pallas: bool = False,
                 sparse_edge_capacity: Optional[int] = None,
                 autotune=None):
        # directly constructed contexts keep their graph alive like any
        # object would; :meth:`create` clears the strong reference on
        # cache-owned contexts so eviction can fire (see build() there)
        self._graph_strong: Optional[Graph] = graph
        self._graph_ref = weakref.ref(graph)
        self.config = config
        self.use_pallas = use_pallas
        self.autotune = _normalize_autotune(autotune)
        self.n_nodes = graph.n_nodes
        self.n_edges = graph.n_edges
        cache = PLAN_CACHE
        g = cache.get(graph, "device", (), graph.device_put)
        # Sparse-gather capacities (static: jit needs fixed shapes).
        # See :meth:`default_sparse_capacity` for the edge-capacity
        # rationale.  The vertex capacity rides along at the same size:
        # on the symmetric inputs the paper uses, every reachable
        # frontier vertex has >= 1 out-edge, so n_f <= m_f.  Pass 0 to
        # disable the sparse path.
        if sparse_edge_capacity is None:
            sparse_edge_capacity = self.default_sparse_capacity(graph)
        self.sparse_edge_capacity = int(sparse_edge_capacity)
        self._sparse_vertex_capacity = max(
            1, min(self.n_nodes, self.sparse_edge_capacity))
        self._row_ptr_out = g.row_ptr_out
        self._csr_raw = (g.src, g.dst, g.weight)
        n_chunks = 1 if config.consistency is Consistency.DRF0 \
            else config.n_chunks
        v = graph.n_nodes
        self._out_degree = g.out_degree

        # Pre-chunked edge arrays per direction.  Padding edges carry the
        # sentinel id V on both endpoints; they reduce into the extra
        # segment V and contribute the identity regardless.  Chunked
        # orders depend only on (edge order, n_chunks), never on the
        # full config, so the cache shares them across cells — a 12-cell
        # sweep builds each (order, n_chunks) pair once.
        def chunked(edges):
            src, dst, w = edges
            return (_pad_reshape(src, n_chunks, v),
                    _pad_reshape(dst, n_chunks, v),
                    _pad_reshape(w, n_chunks, 0.0))

        self._reducer = None
        self._pull_reducer = None
        # Reducer tiling plans: the static DEFAULT_PLAN unless the
        # autotune knob asks the degree-aware tuner for this graph's
        # plan (heuristic: zero-measurement suggest_plan; measure:
        # empirical candidate sweep, process- and disk-cached).  The
        # tuner times the "mixed" objective (one MXU sum + one VPU min
        # per call) because one bound reducer instance serves whatever
        # monoids the program's phases use.
        self._gather_plan = None
        if (config.prop is UpdateProp.PUSH_PULL
                and self.sparse_edge_capacity > 0):
            self._gather_plan = self._resolve_plan(
                graph, "gathered", cap_e=self.sparse_edge_capacity)
        if config.coherence is Coherence.DENOVO:
            owned = cache.get(graph, "edges_owned", (), g.edges_owned)
            self._push_edges = cache.get(graph, "chunked",
                                         ("owned", n_chunks),
                                         lambda: chunked(owned))
            if use_pallas and config.prop is not UpdateProp.PULL:
                self._owned_raw = owned
                plan = self._resolve_plan(graph, "owned")
                self._reducer = cache.get(
                    graph, "owned_reducer", plan,
                    lambda: build_reducer(graph, "owned", plan))
        else:
            self._push_edges = cache.get(
                graph, "chunked", ("csr", n_chunks),
                lambda: chunked((g.src, g.dst, g.weight)))
        self._pull_edges = cache.get(
            graph, "chunked", ("csc", n_chunks),
            lambda: chunked((g.src_in, g.dst_in, g.weight_in)))
        # each reducer's host-side tiling plan walks the full edge set, so
        # only build the directions this config can actually execute
        if use_pallas and config.prop is not UpdateProp.PUSH:
            self._pull_raw = (g.src_in, g.dst_in, g.weight_in)
            plan = self._resolve_plan(graph, "pull")
            self._pull_reducer = cache.get(
                graph, "pull_reducer", plan,
                lambda: build_reducer(graph, "pull", plan))
        self.n_chunks = n_chunks

    def _resolve_plan(self, graph: Graph, order: str,
                      cap_e: Optional[int] = None):
        """This context's tiling plan for one edge order."""
        if self.autotune == "off":
            return DEFAULT_PLAN
        if order == "gathered" and self.autotune == "heuristic":
            # the degree heuristic has no model of the scatter split;
            # the gathered path keeps its single-scatter default
            return DEFAULT_PLAN
        return autotune_plan(graph, order=order, kind="mixed",
                             mode=self.autotune, cap_e=cap_e)

    @property
    def plan_signature(self) -> tuple:
        """Identity of the resolved tiling plans (exec-fn cache key
        material): two contexts that differ only in tuned plans must
        not share a compiled runner."""
        def sig(red):
            return red.plan.astuple() if red is not None else None
        return (sig(self._reducer), sig(self._pull_reducer),
                self._gather_plan.astuple()
                if self._gather_plan is not None else None)

    @property
    def graph(self) -> Optional[Graph]:
        """The host graph this context was built from.

        Directly constructed contexts hold it strongly (always
        available); cache-owned contexts hold it weakly, so this is
        ``None`` once such a graph has been garbage-collected.
        """
        return self._graph_strong or self._graph_ref()

    # ------------------------------------------------------------------
    def resolve_direction(self,
                          direction: Optional[UpdateProp] = None) -> UpdateProp:
        """Resolve a per-phase direction to a concrete PUSH or PULL.

        Precedence: explicit ``direction`` argument > the config's static
        direction > :data:`DEFAULT_DYNAMIC_DIRECTION` for ``PUSH_PULL``
        configs whose caller resolved nothing.
        """
        direction = direction or self.config.prop
        if direction is UpdateProp.PUSH_PULL:
            direction = self.DEFAULT_DYNAMIC_DIRECTION
        return direction

    def choose_direction(self, frontier: jnp.ndarray, prev_pull,
                         unvisited: Optional[jnp.ndarray] = None
                         ) -> jnp.ndarray:
        """Traced bool (True=pull) for this iteration's edge direction.

        Static configs return their fixed direction as a constant, so
        frontier-aware programs can call this unconditionally and stay
        correct (and recompile-free) across the whole design space.
        """
        prop = self.config.prop
        if prop is not UpdateProp.PUSH_PULL:
            return jnp.asarray(prop is UpdateProp.PULL)
        return choose_direction(frontier, self._out_degree, self.n_edges,
                                self.n_nodes, prev_pull, unvisited=unvisited)

    def dynamic_direction(self, want_pull) -> jnp.ndarray:
        """An algorithm-chosen direction as this context's traced flag.

        For programs whose per-iteration direction is *algorithmic*
        rather than frontier-driven (CC's alternating hooking rounds):
        under a static config the config's direction wins (a constant,
        so only that branch compiles); under ``PUSH_PULL`` the wish is
        honoured as a traced bool.  Always returns something safe to
        record under :data:`FRONTIER_DIR_KEY` — the trace reports the
        direction that actually executed.
        """
        prop = self.config.prop
        if prop is not UpdateProp.PUSH_PULL:
            return jnp.asarray(prop is UpdateProp.PULL)
        return jnp.asarray(want_pull, bool)

    # ------------------------------------------------------------------
    # Per-graph state helpers.  Sequentially these are trivial; their
    # :class:`~repro.core.batch.BatchedEdgeContext` overrides give the
    # same program text per-graph semantics on packed [B*n_q] arrays —
    # the contract that lets normalizing programs (PageRank's 1/V
    # terms, BC's per-root level counter) run batched without baking
    # packed totals into their arithmetic.

    @property
    def true_n_nodes(self):
        """True vertex count(s): an int here, ``[B]`` when batched —
        never counts the batch packer's inert padding vertices."""
        return self.n_nodes

    def per_vertex(self, x) -> jnp.ndarray:
        """Broadcast a per-graph scalar (``[B]`` when batched) to a
        per-vertex ``[V]`` array, each vertex receiving its own graph's
        value."""
        return jnp.broadcast_to(jnp.asarray(x), (self.n_nodes,))

    def align_per_graph(self, x) -> jnp.ndarray:
        """Align a per-graph scalar for elementwise use against
        per-vertex arrays.  Sequentially this is the identity — the
        scalar participates via normal broadcasting, keeping the step's
        HLO in the scalar*vector shape whose rounding is stable across
        the host and fused compilations (materializing a ``[V]``
        operand invites fma contraction differences between the two
        engines).  Batched it expands ``[B]`` to packed rows.  Use
        ``per_vertex`` instead when the result itself must be a ``[V]``
        array (e.g. to index with ``[src]``)."""
        return jnp.asarray(x)

    def per_graph_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        """Sum a per-vertex array within each graph: scalar here,
        ``[B]`` when batched."""
        return jnp.sum(x)

    def per_graph_any(self, x: jnp.ndarray) -> jnp.ndarray:
        """Any-reduce a per-vertex bool array within each graph: scalar
        here, ``[B]`` when batched."""
        return jnp.any(x)

    def vertex_offsets(self) -> jnp.ndarray:
        """Each vertex's graph base offset into the vertex id space.

        Sequentially every vertex lives at its local id, so this is a
        scalar 0; batched it is the ``[B*n_q]`` array of packed row
        bases (``i*n_q`` for graph i's rows).  Programs that index
        state by *vertex-id-valued state* (CC's pointer jumping,
        ``label[label]``) must add it first — local label values only
        address the right rows of a packed array after the shift.
        """
        return jnp.int32(0)

    def cond_per_graph(self, pred, true_fn, false_fn, state):
        """Per-graph two-way branch over full state pytrees.

        Sequentially ``pred`` is a scalar and this is ``lax.cond``
        (one branch executes).  Batched, graphs may disagree — BC's
        forward/backward phases flip at per-graph times — so both
        branches execute on the packed arrays and each graph's rows
        select its own branch's result.  Both branches must return
        pytrees of identical structure/shapes.
        """
        return jax.lax.cond(jnp.asarray(pred, bool).reshape(()),
                            true_fn, false_fn, state)

    # ------------------------------------------------------------------
    def propagate(self, state, phase: EdgePhase,
                  direction: Optional[UpdateProp] = None,
                  dtype=jnp.float32) -> jnp.ndarray:
        """Execute one edge-propagated reduction; returns [V] reduced."""
        return self._propagate(state, phase, self.resolve_direction(direction),
                               dtype)

    def propagate_dynamic(self, state, phase: EdgePhase, pull,
                          dtype=jnp.float32) -> jnp.ndarray:
        """Like ``propagate`` but direction is a traced bool (True=pull).

        Under a static config the flag is ignored (the config's direction
        wins and only one branch is compiled); under ``PUSH_PULL`` both
        pre-chunked edge orders are traced and ``lax.cond`` executes
        exactly one per iteration — the paper's dynamic mode.
        """
        if self.config.prop is not UpdateProp.PUSH_PULL:
            return self._propagate(state, phase,
                                   self.resolve_direction(None), dtype)
        return jax.lax.cond(
            jnp.asarray(pull, bool),
            lambda st: self._propagate(st, phase, UpdateProp.PULL, dtype),
            lambda st: self._propagate(st, phase, UpdateProp.PUSH, dtype),
            state)

    def propagate_sparse(self, state, phase: EdgePhase, pull,
                         dtype=jnp.float32):
        """``propagate_dynamic`` with an O(m_f) sparse-gather fast path.

        Returns ``(reduced [V], occupancy)``.  ``occupancy`` is a traced
        float scalar: ``m_f / sparse_edge_capacity`` when this iteration
        ran the sparse-gathered path, -1.0 when it ran a dense O(E) scan
        (programs record it under :data:`FRONTIER_OCC_KEY` so ``run``
        can trace sparse-vs-dense residency per iteration).

        The sparse path fires only when *all* of: the config is dynamic
        (static cells keep their specialized dense realisations), the
        phase declares itself ``gatherable`` (see below), the heuristic
        chose push (pull's full destination scan is inherently dense),
        and the frontier's vertex *and* edge lists fit their static
        capacities.  Overflow of either capacity falls back to the
        dense pre-chunked path — slower, never wrong.  Pull iterations
        never pay the gather: the push/pull branch is the outer
        ``lax.cond``, so the gather is traced only inside the push
        branch.

        Soundness precondition: gathering reduces *only* the frontier's
        out-edges, so every edge contributing a non-identity message on
        the dense push path must have a frontier source.  A phase
        asserts that structurally via ``EdgePhase.gatherable`` — the
        BFS/SSSP/BC phases set it because their ``spred`` restricts
        sources to exactly the frontier mask.  A phase whose frontier
        only steers the direction heuristic (every source contributes)
        leaves it False and always runs the dense path.
        """
        # One constant for every dense-marked branch: the early return,
        # the pull branch and the overflow arm of the push branch all
        # return this same jnp.float32 scalar (dtype/weak-type symmetry
        # is what lets the fused while_loop carry the occupancy).
        dense_occ = dense_occupancy()
        if (self.config.prop is not UpdateProp.PUSH_PULL
                or phase.frontier is None or not phase.gatherable
                or self.sparse_edge_capacity == 0):
            return self.propagate_dynamic(state, phase, pull, dtype), dense_occ

        def dense_pull(st):
            return self._propagate(st, phase, UpdateProp.PULL, dtype), \
                dense_occ

        def push(st):
            front = dense_to_sparse(phase.frontier(st),
                                    self._sparse_vertex_capacity)
            edges = gather_frontier_edges(front.ids, self._row_ptr_out,
                                          self.sparse_edge_capacity)
            fits = ~front.overflowed & ~edges.overflowed
            occ = jnp.where(
                fits,
                edges.count.astype(jnp.float32) / self.sparse_edge_capacity,
                dense_occ)
            out = jax.lax.cond(
                fits,
                lambda s: self._propagate_gathered(s, phase, edges.edge_ids,
                                                   dtype),
                lambda s: self._propagate(s, phase, UpdateProp.PUSH, dtype),
                st)
            return out, occ

        return jax.lax.cond(jnp.asarray(pull, bool), dense_pull, push, state)

    def _propagate_gathered(self, state, phase: EdgePhase,
                            edge_ids: jnp.ndarray, dtype) -> jnp.ndarray:
        """Push-direction reduction over a gathered [cap_e] edge subset.

        ``edge_ids`` indexes the CSR (by-src) edge arrays; -1 marks
        padding.  Padding and predicate-failing edges are routed to the
        reducer's trash segment, which contributes the monoid identity —
        the same convention as the dense path's masked scan.  For
        min/max and exact (integer) sums the result is bit-identical to
        the dense path; inexact float sums may differ in final ULPs
        because the gathered order sums edges differently than the
        chunked schedule.
        """
        src, dst, w = self._csr_raw
        valid = edge_ids >= 0
        at = jnp.where(valid, edge_ids, 0)
        sv, tv, wv = src[at], dst[at], w[at]
        keep = valid
        if phase.spred is not None:
            keep &= phase.spred(state, sv)
        if phase.tpred is not None:
            keep &= phase.tpred(state, tv)
        msg = phase.vprop(state, sv, wv).astype(dtype)
        ids = jnp.where(keep, tv, -1)
        return gathered_segment_reduce(msg, ids, self.n_nodes,
                                       phase.monoid.name,
                                       plan=self._gather_plan)

    def _propagate(self, state, phase: EdgePhase, direction: UpdateProp,
                   dtype) -> jnp.ndarray:
        cfg = self.config
        pull = direction is UpdateProp.PULL
        src_c, dst_c, w_c = self._pull_edges if pull else self._push_edges
        v = self.n_nodes
        monoid = phase.monoid
        ident = monoid.identity(dtype)

        reducer = self._pull_reducer if pull else self._reducer
        if reducer is not None:
            # Pallas blocked kernel over the whole (unpadded) edge set in
            # block-binned order (owned order for push, CSC order for
            # pull); masked edges contribute the monoid identity,
            # kernel-internal DMA pipelining plays the consistency role.
            so, do, wo = self._pull_raw if pull else self._owned_raw
            mask = jnp.ones(so.shape, bool)
            if phase.spred is not None:
                mask &= phase.spred(state, so)
            if phase.tpred is not None:
                mask &= phase.tpred(state, do)
            msg = phase.vprop(state, so, wo).astype(dtype)
            return reducer.masked(msg, mask, monoid.name, ident=ident)

        def chunk_reduce(i):
            src = jax.lax.dynamic_index_in_dim(src_c, i, keepdims=False)
            dst = jax.lax.dynamic_index_in_dim(dst_c, i, keepdims=False)
            w = jax.lax.dynamic_index_in_dim(w_c, i, keepdims=False)
            sv = jnp.minimum(src, v - 1)
            tv = jnp.minimum(dst, v - 1)
            mask = (src < v) & (dst < v)
            if phase.spred is not None:
                mask &= phase.spred(state, sv)
            if phase.tpred is not None:
                mask &= phase.tpred(state, tv)
            msg = phase.vprop(state, sv, w).astype(dtype)
            msg = jnp.where(mask, msg, ident)
            if pull:
                # by-dst order: sorted ids -> dense local (non-atomic)
                # update (chunks of a sorted array stay sorted).  Keep
                # ids = dst — rewriting masked ids to the sentinel would
                # break the sorted invariant the flag asserts; masked
                # edges already carry the identity, which no-ops in the
                # combine, and padding edges carry dst = v themselves.
                return segment_reduce(msg, dst, v + 1, monoid,
                                      indices_are_sorted=True)
            ids = jnp.where(mask, dst, v)
            if cfg.coherence is Coherence.DENOVO:
                return segment_reduce_owned(msg, ids, v + 1, monoid)
            return segment_reduce(msg, ids, v + 1, monoid)

        out = scheduled_reduce(chunk_reduce, self.n_chunks,
                               cfg.consistency, monoid)
        return out[:v]


@dataclasses.dataclass
class RunResult:
    state: Any
    iterations: int
    seconds: float
    converged: bool
    #: per-iteration edge-direction letters ("S"=push, "T"=pull) for
    #: frontier-aware programs; None for programs without the protocol.
    direction_trace: Optional[str] = None
    #: per-iteration sparse-gather occupancy (m_f / cap_e; -1.0 for a
    #: dense iteration) for programs recording FRONTIER_OCC_KEY; None
    #: for programs without the protocol.
    occupancy_trace: Optional[List[float]] = None
    #: which execution engine produced this result ("fused" | "host").
    engine: str = "fused"
    #: timed jitted invocations this run issued: 1 for the fused engine,
    #: ``iterations`` for the host engine (warmup compiles excluded).
    dispatches: int = 0
    #: True when a serving-gateway per-request deadline expired before
    #: convergence: ``state`` then holds the partial-iteration state
    #: after the last completed scheduling slice (and ``converged`` is
    #: False).  Always False for direct ``run()``/``run_batch`` runs.
    timed_out: bool = False
    #: Structured run outcome: "converged" | "iter_limit" | "timed_out"
    #: | "faulted".  Derived from the flags when not set explicitly;
    #: "faulted" is produced only by the resilience layer
    #: (:mod:`repro.core.resilience`) when recovery is exhausted.
    outcome: Optional[str] = None
    #: Fault record for resilient runs: the per-attempt fault history
    #: (sentinel trips, exceptions) plus whether recovery succeeded.
    #: None for runs that never faulted.
    fault: Optional[dict] = None
    #: Executions this result took: 1 for a clean run, >1 when
    #: :class:`~repro.core.resilience.RetryPolicy` re-executed.
    attempts: int = 1
    #: Name of the :class:`SystemConfig` this run actually executed
    #: under (e.g. "DD1"); None for paths that never stamp it.
    config_name: Optional[str] = None
    #: How that config was chosen: "caller" (the config argument as
    #: passed), "static" / "static_partial" (the prose decision trees)
    #: or "learned" (the trained model) — see
    #: :func:`repro.core.specialize_learned.resolve_config`.
    config_source: str = "caller"

    def __post_init__(self):
        if self.outcome is None:
            self.outcome = ("converged" if self.converged else
                            "timed_out" if self.timed_out else
                            "iter_limit")

    @property
    def sparse_iterations(self) -> Optional[int]:
        """How many iterations ran the O(m_f) gathered path."""
        if self.occupancy_trace is None:
            return None
        return sum(1 for o in self.occupancy_trace if o >= 0.0)

    @property
    def mean_sparse_occupancy(self) -> Optional[float]:
        """Mean m_f/cap_e over the sparse-gathered iterations."""
        occ = [o for o in (self.occupancy_trace or []) if o >= 0.0]
        return sum(occ) / len(occ) if occ else None

    def extract(self, program: VertexProgram):
        return program.extract(self.state)


def _trace_flags(program: VertexProgram, state) -> tuple:
    # direction tracing is part of the frontier protocol: the program
    # declares itself frontier-aware via frontier_update and records its
    # per-iteration choice under FRONTIER_DIR_KEY
    traced = (program.frontier_update is not None
              and isinstance(state, dict) and FRONTIER_DIR_KEY in state)
    occ_traced = traced and FRONTIER_OCC_KEY in state
    return traced, occ_traced


def _cached_exec_fn(program: VertexProgram, ctx: EdgeContext,
                    params: tuple, build):
    """Fetch a jitted/compiled runner callable through the plan cache.

    A fresh ``jax.jit`` closure per ``run`` call would miss jax's jit
    cache every time, recompiling the step (host) or the entire fused
    while_loop per repeat of a sweep — usually the dominant sweep cost.
    Entries are keyed on ``id(program)`` plus the context/engine params
    and hold the program strongly, so a program id can never be
    recycled while its entry is alive; entries die with the graph, and
    the bucket is LRU-bounded so a stream of distinct program instances
    on one long-lived graph (e.g. exact BC looping over roots) cannot
    accumulate unbounded compiled executables.
    """
    g = ctx.graph
    key = (id(program), ctx.config, ctx.use_pallas,
           ctx.sparse_edge_capacity, ctx.plan_signature) + params
    if g is None:  # graph already collected; nothing to key on
        return build()[1]
    return PLAN_CACHE.get(g, "exec_fn", key, build,
                          capacity=_EXEC_FN_CAPACITY)[1]


def _run_host(program: VertexProgram, ctx: EdgeContext, state,
              limit: int, warmup: bool) -> RunResult:
    """Kernel-per-iteration oracle engine: one jitted dispatch per step
    plus a blocking convergence read between steps."""

    def build():
        @partial(jax.jit, donate_argnums=(0,))
        def step(st, it):
            new = program.step(ctx, st, it)
            done = program.converged(st, new)
            return new, done
        if warmup:  # compile outside the timed region (paper times
            # kernels only).  `step` donates its input, so warm the jit
            # cache on a copy.  Inside build(): a cached step is already
            # compiled, so repeats skip the warmup execution too.
            copy = jax.tree.map(lambda x: x.copy(), state)
            jax.block_until_ready(step(copy, jnp.int32(0)))
        return program, step

    step = _cached_exec_fn(program, ctx, ("host",), build)
    traced, occ_traced = _trace_flags(program, state)
    # Per-iteration trace scalars are carried off as *async* device
    # copies (the originals are donated to the next step) and decoded
    # into host bools/floats only after the timer stops — the timed
    # region contains no host-blocking trace reads.  Host-vs-fused
    # timing deltas are then dominated by the per-iteration dispatch +
    # convergence-sync cost (traced programs additionally enqueue two
    # scalar copies per iteration here, a second-order effect).
    dir_raw: List[jax.Array] = []
    occ_raw: List[jax.Array] = []
    t0 = time.perf_counter()
    it, done = 0, False
    while it < limit:
        STATS.dispatches += 1
        state, done_dev = step(state, jnp.int32(it))
        it += 1
        if traced:
            dir_raw.append(state[FRONTIER_DIR_KEY].copy())
        if occ_traced:
            occ_raw.append(state[FRONTIER_OCC_KEY].copy())
        done = bool(done_dev)  # the host engine's inherent per-step sync
        if done:
            break
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0
    trace = "".join("T" if bool(d) else "S" for d in dir_raw)
    occ_trace = [float(o) for o in occ_raw]
    return RunResult(state=state, iterations=it, seconds=dt, converged=done,
                     direction_trace=trace if traced else None,
                     occupancy_trace=occ_trace if occ_traced else None,
                     engine="host", dispatches=it)


def _run_fused(program: VertexProgram, ctx: EdgeContext, state,
               limit: int, warmup: bool) -> RunResult:
    """Device-resident engine: the whole convergence loop is one jitted
    ``lax.while_loop`` dispatch with one host sync at the end.

    Carry layout: ``(state, it, done, dir_buf, occ_buf)``.  The trace
    buffers are preallocated ``[limit]`` device arrays the body writes
    at index ``it`` via ``lax.dynamic_update_index_in_dim``; after the
    loop the first ``it`` entries decode to the same
    ``direction_trace``/``occupancy_trace`` strings/lists the host
    engine produces, preserving the frontier protocol bit for bit.
    """
    traced, occ_traced = _trace_flags(program, state)
    dir_buf = jnp.zeros((limit,), bool) if traced else None
    occ_buf = (jnp.full((limit,), dense_occupancy())
               if occ_traced else None)

    def fused(st, db, ob):
        def cond(carry):
            _, it, done, _, _ = carry
            return (it < limit) & ~done

        def body(carry):
            st, it, done, db, ob = carry
            new = program.step(ctx, st, it)
            done = program.converged(st, new)
            if traced:
                db = jax.lax.dynamic_update_index_in_dim(
                    db, jnp.asarray(new[FRONTIER_DIR_KEY], bool), it, 0)
            if occ_traced:
                ob = jax.lax.dynamic_update_index_in_dim(
                    ob, jnp.asarray(new[FRONTIER_OCC_KEY], jnp.float32),
                    it, 0)
            return new, it + jnp.int32(1), done, db, ob

        return jax.lax.while_loop(
            cond, body,
            (st, jnp.int32(0), jnp.asarray(False), db, ob))

    def build():
        fn = jax.jit(fused, donate_argnums=(0, 1, 2))
        if warmup:
            # AOT-compile outside the timed region; unlike the host
            # engine's run-one-step warmup this executes nothing on
            # device.  The compiled executable is cached per (program,
            # context, limit) so sweep repeats skip the while_loop
            # compile entirely.
            fn = fn.lower(state, dir_buf, occ_buf).compile()
        return program, fn

    fn = _cached_exec_fn(program, ctx,
                         ("fused", limit, traced, occ_traced), build)
    t0 = time.perf_counter()
    STATS.dispatches += 1
    state, it_dev, done_dev, dir_buf, occ_buf = fn(state, dir_buf, occ_buf)
    jax.block_until_ready((state, it_dev, done_dev, dir_buf, occ_buf))
    dt = time.perf_counter() - t0
    # the run's single host sync is above; everything below is decoding
    it = int(it_dev)
    done = bool(done_dev)
    trace = None
    occ_trace = None
    if traced:
        trace = "".join("T" if b else "S"
                        for b in np.asarray(dir_buf)[:it])
    if occ_traced:
        occ_trace = [float(o) for o in np.asarray(occ_buf)[:it]]
    return RunResult(state=state, iterations=it, seconds=dt, converged=done,
                     direction_trace=trace, occupancy_trace=occ_trace,
                     engine="fused", dispatches=1)


def run(program: VertexProgram, graph: Graph, config: SystemConfig,
        key: Optional[jax.Array] = None, max_iters: Optional[int] = None,
        use_pallas: bool = False, warmup: bool = True,
        sparse_edge_capacity: Optional[int] = None,
        engine: str = "fused", autotune=None,
        checkpoint_every: int = 0, retry=None, sentinels: bool = True,
        ring_capacity: Optional[int] = None,
        fault_injector=None,
        checkpoint_dir: Optional[str] = None,
        specialize=None) -> RunResult:
    """Iterate ``program`` on ``graph`` under ``config`` to convergence.

    ``engine`` picks the convergence loop: ``"fused"`` (default) runs
    the whole loop on device as one ``lax.while_loop`` dispatch;
    ``"host"`` is the kernel-per-iteration debugging oracle the fused
    engine is tested against.  Both produce identical states,
    iteration counts and traces.

    ``autotune`` picks the Pallas reducer tiling plans: ``"off"``
    (default, also ``None``/``False``) keeps the static default tiling;
    ``"heuristic"`` derives a plan from the graph's degree features
    with zero measurement; ``"measure"`` (also ``True``) runs the
    empirical candidate sweep, cached per graph in ``PLAN_CACHE`` and
    persisted to ``results/autotune_cache.json`` keyed by degree
    signature, so sweeps and repeat traffic never re-tune.  Tiling is a
    performance choice only — results are unaffected.

    Resilience knobs (any of them set delegates to
    :func:`repro.core.resilience.run_resilient`, whose results are
    bit-identical to the plain engines): ``checkpoint_every=K``
    segments the convergence loop into K-iteration dispatches whose
    carry snapshots into a bounded host-side checkpoint ring and whose
    boundaries evaluate the program's invariant sentinels;
    ``retry=RetryPolicy(...)`` rolls back to a clean checkpoint and
    re-executes on failure, walking a degradation chain (autotuned →
    default tiling, sparse → dense frontier, fused → host engine);
    ``sentinels=False`` disables the sentinel battery and the
    converged-state certificate; ``ring_capacity`` bounds the ring;
    ``fault_injector`` is the seeded fault harness's hook
    (:mod:`repro.testing.faults`); ``checkpoint_dir`` spills every
    checkpoint boundary to a durable on-disk
    :class:`~repro.core.durability.CheckpointStore` and resumes a
    killed run from the newest intact generation, bit-identical to an
    uninterrupted run.

    ``specialize`` resolves which config actually runs: ``"off"``
    (default, also ``None``/``False``) executes the ``config`` argument
    as passed; ``"static"`` applies the paper's full decision tree to
    (program properties, graph taxonomy profile); ``"learned"``
    consults the trained model at
    :data:`repro.core.specialize_learned.DEFAULT_MODEL_PATH`, falling
    back learned -> static partial -> caller with a structured
    :class:`~repro.core.specialize_learned.SpecializeFallbackWarning`
    when a tier is unavailable.  The resolved config (inheriting the
    caller's ``n_chunks``) and its source are stamped on
    ``RunResult.config_name`` / ``config_source``.
    """
    if engine not in ("fused", "host"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "expected 'fused' or 'host'")
    config_source = "caller"
    if specialize not in (None, False, "off"):
        from repro.core.specialize_learned import resolve_config
        config, config_source = resolve_config(program, graph, config,
                                               specialize)
    if (checkpoint_every or retry is not None or fault_injector is not None
            or checkpoint_dir is not None):
        from repro.core.resilience import run_resilient
        res = run_resilient(
            program, graph, config, key=key, max_iters=max_iters,
            use_pallas=use_pallas, warmup=warmup,
            sparse_edge_capacity=sparse_edge_capacity, engine=engine,
            autotune=autotune, checkpoint_every=checkpoint_every,
            retry=retry, sentinels=sentinels,
            ring_capacity=ring_capacity, fault_injector=fault_injector,
            checkpoint_dir=checkpoint_dir)
    else:
        ctx = EdgeContext.create(graph, config, use_pallas=use_pallas,
                                 sparse_edge_capacity=sparse_edge_capacity,
                                 autotune=autotune)
        state = program.init(graph, key) if key is not None \
            else program.init(graph)
        state = jax.tree.map(jnp.asarray, state)
        limit = max_iters or program.max_iters
        runner = _run_fused if engine == "fused" else _run_host
        res = runner(program, ctx, state, limit, warmup)
    res.config_name = config.name
    res.config_source = config_source
    return res


def run_batch(program: VertexProgram, graphs, config: SystemConfig,
              keys: Optional[list] = None,
              max_iters: Optional[int] = None, use_pallas: bool = False,
              warmup: bool = True,
              sparse_edge_capacity: Optional[int] = None,
              autotune=None,
              max_batch: Optional[int] = None,
              specialize=None) -> List[RunResult]:
    """Run ``program`` on many graphs as block-diagonal packed batches.

    The serving-path counterpart of :func:`run`: graphs are grouped
    into padding buckets (quantized ``(n, m)`` plus ``block_size`` —
    see :func:`repro.core.batch.bucket_key`), each bucket is packed
    into one block-diagonal graph (cached in :data:`PLAN_CACHE` per
    graph tuple) and driven to convergence by **one** fused
    ``lax.while_loop`` dispatch with per-graph convergence masking —
    B graphs cost one dispatch instead of B.  Results come back in
    input order, one :class:`RunResult` per graph, with
    ``engine="batched"`` and per-graph states, iteration counts and
    direction/occupancy traces **bit-identical** to per-graph
    sequential ``run(...)`` for programs whose reductions use
    order-independent monoids (min/max or exact integer sums — BFS,
    SSSP); inexact float sums may differ in final ULPs because the
    packed schedule reduces edges in a different order.  Each result's
    ``seconds`` is its batch's wall time divided by the batch size.

    ``keys`` optionally supplies one PRNG key per graph for programs
    with randomized init.  When omitted for a program that declares
    ``randomized=True`` (coloring, MIS), per-graph keys are derived as
    ``fold_in(key(0), batch_index)`` — every graph draws *independent*
    priorities; the old shared-default-key behavior correlated
    tie-breaks across supposedly independent batch members.  To
    reproduce one graph's batched result sequentially, pass the same
    ``fold_in(key(0), i)`` to :func:`run`.  ``max_batch`` caps how many
    graphs pack into one dispatch (a bucket with more graphs is
    split).  The remaining knobs mean what they mean on :func:`run`;
    ``sparse_edge_capacity`` is applied per graph (0 disables the
    sparse path batch-wide).

    ``specialize`` resolves each graph's config independently (see
    :func:`run`): grouping then keys on *(padding bucket, resolved
    config)*, so graphs whose predicted configs differ never share a
    packed dispatch, and every result carries its own
    ``config_name``/``config_source``.
    """
    from repro.core.batch import (BatchedEdgeContext, bucket_key,
                                  get_graph_batch, run_fused_batch)
    graphs = list(graphs)
    if keys is None and program.randomized:
        base = jax.random.key(0)
        keys = [jax.random.fold_in(base, i) for i in range(len(graphs))]
    if keys is not None and len(keys) != len(graphs):
        raise ValueError(f"{len(keys)} keys for {len(graphs)} graphs")
    if max_batch is not None and max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if specialize in (None, False, "off"):
        resolved = [(config, "caller")] * len(graphs)
    else:
        from repro.core.specialize_learned import resolve_config
        resolved = [resolve_config(program, g, config, specialize)
                    for g in graphs]
    limit = max_iters or program.max_iters
    groups: dict = {}
    for i, g in enumerate(graphs):
        groups.setdefault((bucket_key(g), resolved[i][0]), []).append(i)
    results: List[Optional[RunResult]] = [None] * len(graphs)
    for (_, group_config), idxs in groups.items():
        step = max_batch or len(idxs)
        for lo in range(0, len(idxs), step):
            part = idxs[lo:lo + step]
            batch = get_graph_batch(tuple(graphs[i] for i in part))
            bctx = BatchedEdgeContext.create(
                batch, group_config, use_pallas=use_pallas,
                sparse_edge_capacity=sparse_edge_capacity,
                autotune=autotune)
            states = [program.init(graphs[i]) if keys is None
                      else program.init(graphs[i], keys[i])
                      for i in part]
            packed = batch.pack_state(states, pad=program.state_pad)
            for i, r in zip(part, run_fused_batch(program, batch, bctx,
                                                  packed, limit, warmup)):
                r.config_name = group_config.name
                r.config_source = resolved[i][1]
                results[i] = r
    return results
