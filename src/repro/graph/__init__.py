from repro.graph.structure import Graph, GraphStats, graph_stats
from repro.graph.generators import (grid_graph, powerlaw_graph, random_graph,
                                    regular_graph, rmat_batch, rmat_graph)
from repro.graph.datasets import PAPER_GRAPHS, PAPER_STATS, paper_graph

__all__ = [
    "Graph", "GraphStats", "graph_stats",
    "grid_graph", "powerlaw_graph", "random_graph", "regular_graph",
    "rmat_batch", "rmat_graph",
    "PAPER_GRAPHS", "PAPER_STATS", "paper_graph",
]
