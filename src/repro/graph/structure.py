"""Graph container for push/pull vertex-centric execution.

The paper's design space needs *both* edge orderings of the same graph:

- **by-src (CSR) order** — push: iterating edges grouped by source gives the
  paper's "dense local reads" of source properties and "sparse remote
  atomics" to targets (here: an unsorted scatter-reduction over ``dst``).
- **by-dst (CSC) order** — pull: iterating edges grouped by target gives
  "sparse remote reads" of sources and "dense local updates" (a segmented
  reduction over already-sorted ``dst`` — the non-atomic path).

For the DeNovo-analogue ("owned") accumulation we additionally keep a
permutation of the by-src order that bins edges by *target block* of
``block_size`` vertices: all updates to one VMEM-resident block are grouped
so a kernel can accumulate them locally ("ownership") and write back once.
``block_size`` plays the role of the paper's thread-block size |TB| in the
Reuse/Imbalance metrics (Eqs. 2-7).
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_reduce.ops import bin_edges_by_block

__all__ = ["Graph", "graph_stats", "GraphStats", "validate_graph"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed (symmetric, per the paper's input format) graph.

    All arrays may be numpy (host) or jax (device); construction is numpy.
    """

    # --- by-src (CSR / push) order -------------------------------------
    src: jax.Array          # [E] int32, non-decreasing
    dst: jax.Array          # [E] int32
    weight: jax.Array       # [E] float32
    row_ptr_out: jax.Array  # [V+1] int32
    # --- by-dst (CSC / pull) order -------------------------------------
    src_in: jax.Array       # [E] int32
    dst_in: jax.Array       # [E] int32, non-decreasing
    weight_in: jax.Array    # [E] float32
    row_ptr_in: jax.Array   # [V+1] int32
    # --- degrees --------------------------------------------------------
    out_degree: jax.Array   # [V] int32
    in_degree: jax.Array    # [V] int32
    # --- owned (DeNovo-analogue) target-block binned by-src order -------
    perm_owned: jax.Array   # [E] int32: indices into by-src arrays
    block_ptr: jax.Array    # [n_blocks+1] int32: edge offsets per dst block
    # --- static metadata -------------------------------------------------
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return (self.n_nodes + self.block_size - 1) // self.block_size

    @classmethod
    def from_coo(
        cls,
        src,
        dst,
        n_nodes: int,
        weight=None,
        block_size: int = 256,
        symmetrize: bool = False,
        remove_self_loops: bool = True,
    ) -> "Graph":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if weight is None:
            weight = np.ones(src.shape[0], dtype=np.float32)
        weight = np.asarray(weight, dtype=np.float32)

        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            weight = np.concatenate([weight, weight])
        if remove_self_loops:
            keep = src != dst
            src, dst, weight = src[keep], dst[keep], weight[keep]
        # de-duplicate (keep min weight — matches SSSP semantics, harmless
        # for unweighted graphs where all weights coincide)
        key = src * n_nodes + dst
        order = np.lexsort((weight, key))
        key_s = key[order]
        first = np.ones(key_s.shape[0], dtype=bool)
        first[1:] = key_s[1:] != key_s[:-1]
        order = order[first]
        src, dst, weight = src[order], dst[order], weight[order]

        e = src.shape[0]
        # by-src order (the lexsort above already sorted by src-major key)
        perm_src = np.lexsort((dst, src))
        s_src, d_src, w_src = src[perm_src], dst[perm_src], weight[perm_src]
        row_ptr_out = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(row_ptr_out, s_src + 1, 1)
        row_ptr_out = np.cumsum(row_ptr_out)
        # by-dst order
        perm_dst = np.lexsort((src, dst))
        s_dst, d_dst, w_dst = src[perm_dst], dst[perm_dst], weight[perm_dst]
        row_ptr_in = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(row_ptr_in, d_dst + 1, 1)
        row_ptr_in = np.cumsum(row_ptr_in)

        out_degree = np.diff(row_ptr_out)
        in_degree = np.diff(row_ptr_in)

        # owned order: stable-sort by dst block, preserving by-src order
        # inside each block (keeps push's dense source reads) — the
        # same binning the batched packer applies to packed edge lists
        perm_owned, block_ptr = bin_edges_by_block(d_src, n_nodes,
                                                   block_size)

        i32 = lambda a: np.asarray(a, dtype=np.int32)
        return cls(
            src=i32(s_src), dst=i32(d_src), weight=np.float32(w_src),
            row_ptr_out=i32(row_ptr_out),
            src_in=i32(s_dst), dst_in=i32(d_dst), weight_in=np.float32(w_dst),
            row_ptr_in=i32(row_ptr_in),
            out_degree=i32(out_degree), in_degree=i32(in_degree),
            perm_owned=i32(perm_owned), block_ptr=i32(block_ptr),
            n_nodes=int(n_nodes), n_edges=int(e), block_size=int(block_size),
        )

    def device_put(self) -> "Graph":
        arrays = {
            f.name: jnp.asarray(getattr(self, f.name))
            for f in dataclasses.fields(self)
            if not f.metadata.get("static", False)
        }
        return dataclasses.replace(self, **arrays)

    # Convenience views -------------------------------------------------
    def edges_owned(self):
        """Edges permuted into target-block-binned order (numpy or jax)."""
        take = jnp.take if isinstance(self.src, jax.Array) else (
            lambda a, i: np.asarray(a)[np.asarray(i)]
        )
        return (take(self.src, self.perm_owned),
                take(self.dst, self.perm_owned),
                take(self.weight, self.perm_owned))


@dataclasses.dataclass(frozen=True)
class GraphStats:
    n_nodes: int
    n_edges: int
    max_degree: int
    avg_degree: float
    std_degree: float

    @cached_property
    def as_dict(self):
        return dataclasses.asdict(self)


def validate_graph(g: Graph) -> list:
    """Structural-soundness check for externally supplied graphs.

    Returns a list of human-readable defect descriptions (empty when
    the graph is well-formed).  The serving gateway runs this at
    admission so a malformed query — negative row offsets, a dangling
    edge endpoint, NaN/inf weights, inconsistent array lengths — is
    rejected with a structured error *before* it can join (and poison)
    an in-flight packed batch.  Pure host-side numpy; never dispatches.
    """
    errors: list = []
    n, m = int(g.n_nodes), int(g.n_edges)
    if n < 0 or m < 0:
        return [f"negative graph size (n={n}, m={m})"]

    def arr(name):
        try:
            return np.asarray(getattr(g, name))
        except Exception as e:  # device array in a broken state, etc.
            errors.append(f"{name}: not convertible to a host array ({e})")
            return None

    sides = [("row_ptr_out", "src", "dst", "weight", "out_degree"),
             ("row_ptr_in", "src_in", "dst_in", "weight_in", "in_degree")]
    for rp_name, s_name, d_name, w_name, deg_name in sides:
        rp, s, d, w, deg = (arr(rp_name), arr(s_name), arr(d_name),
                            arr(w_name), arr(deg_name))
        if any(a is None for a in (rp, s, d, w, deg)):
            continue
        for name, a, want in ((rp_name, rp, n + 1), (s_name, s, m),
                              (d_name, d, m), (w_name, w, m),
                              (deg_name, deg, n)):
            if a.shape[:1] != (want,):
                errors.append(f"{name}: length {a.shape[0] if a.ndim else 0}"
                              f" != expected {want}")
        if rp.shape[:1] != (n + 1,) or s.shape[:1] != (m,):
            continue  # length errors above make index checks misleading
        if rp.size and int(rp[0]) != 0:
            errors.append(f"{rp_name}[0] = {int(rp[0])} != 0")
        # negative and decreasing offsets are distinct defects (a
        # decreasing run means a *negative-length* adjacency row, the
        # classic off-by-one CSR construction bug) — report which one
        bad_rp = False
        if np.any(rp < 0):
            errors.append(f"{rp_name}: negative offsets")
            bad_rp = True
        if np.any(np.diff(rp) < 0):
            drop = int(np.argmax(np.diff(rp) < 0))
            errors.append(
                f"{rp_name}: offsets decrease at row {drop} "
                f"({int(rp[drop])} -> {int(rp[drop + 1])}); row offsets "
                "must be monotone non-decreasing")
            bad_rp = True
        if not bad_rp and rp.size and int(rp[-1]) != m:
            errors.append(f"{rp_name}[-1] = {int(rp[-1])} != n_edges {m}")
        for name, ids in ((s_name, s), (d_name, d)):
            if ids.size and (ids.min() < 0 or ids.max() >= n):
                errors.append(f"{name}: endpoint ids outside [0, {n}) "
                              "(dangling edge)")
        if not np.all(np.isfinite(w)):
            errors.append(f"{w_name}: non-finite weights (NaN/inf)")
        if (rp.shape[:1] == (n + 1,) and deg.shape[:1] == (n,)
                and not np.any(np.diff(rp) < 0)
                and not np.array_equal(np.diff(rp), deg)):
            errors.append(f"{deg_name} inconsistent with {rp_name} diffs")
    return errors


def graph_stats(g: Graph) -> GraphStats:
    deg = np.asarray(g.out_degree)
    return GraphStats(
        n_nodes=g.n_nodes,
        n_edges=g.n_edges,
        max_degree=int(deg.max()) if deg.size else 0,
        avg_degree=float(deg.mean()) if deg.size else 0.0,
        std_degree=float(deg.std()) if deg.size else 0.0,
    )
