"""Fanout neighbor sampler (GraphSAGE-style) for the ``minibatch_lg`` shape.

Host-side (numpy) sampling over CSR, producing fixed-shape padded blocks the
jitted model consumes — the standard TPU-friendly contract: ragged sampling
on host, rectangular tensors on device.  The sampler *is* part of the
system (JAX has no native neighbor sampling).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structure import Graph

__all__ = ["SampledBlock", "NeighborSampler"]


@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One hop: for each of B seed nodes, up to `fanout` sampled in-edges.

    Padded with sentinel node id == n_nodes; `edge_mask` marks real edges.
    Layout matches the push executor: edges listed target-major so the
    aggregation is a segment reduction over `dst_local`.
    """
    seeds: np.ndarray        # [B] global node ids of this hop's targets
    src_global: np.ndarray   # [B*fanout] sampled source ids (global)
    dst_local: np.ndarray    # [B*fanout] target index in [0, B)
    edge_mask: np.ndarray    # [B*fanout] bool
    fanout: int


class NeighborSampler:
    def __init__(self, g: Graph, fanouts: tuple[int, ...], seed: int = 0):
        self.row_ptr = np.asarray(g.row_ptr_in, dtype=np.int64)
        self.col = np.asarray(g.src_in, dtype=np.int64)
        self.n_nodes = g.n_nodes
        self.fanouts = tuple(fanouts)
        self._rng = np.random.default_rng(seed)

    def sample_hop(self, seeds: np.ndarray, fanout: int) -> SampledBlock:
        b = seeds.shape[0]
        starts = self.row_ptr[seeds]
        degs = self.row_ptr[seeds + 1] - starts
        # uniform with replacement (standard GraphSAGE), vectorised
        offs = self._rng.integers(0, 2**62, size=(b, fanout))
        offs = np.where(degs[:, None] > 0, offs % np.maximum(degs, 1)[:, None], 0)
        idx = starts[:, None] + offs
        src = self.col[np.minimum(idx, self.col.shape[0] - 1)]
        mask = (degs[:, None] > 0) & (np.arange(fanout)[None, :] <
                                      np.maximum(degs, fanout)[:, None])
        mask &= degs[:, None] > 0
        src = np.where(mask, src, self.n_nodes)
        dst_local = np.repeat(np.arange(b, dtype=np.int64), fanout)
        return SampledBlock(
            seeds=seeds.astype(np.int64),
            src_global=src.reshape(-1),
            dst_local=dst_local,
            edge_mask=mask.reshape(-1),
            fanout=fanout,
        )

    def sample(self, seeds: np.ndarray) -> list[SampledBlock]:
        """Multi-hop: returns blocks outermost-hop-first.  Each hop's
        frontier is the (padded) union of sampled sources."""
        blocks: list[SampledBlock] = []
        frontier = np.asarray(seeds, dtype=np.int64)
        for f in self.fanouts:
            blk = self.sample_hop(frontier, f)
            blocks.append(blk)
            nxt = blk.src_global[blk.edge_mask]
            frontier = np.unique(np.concatenate([frontier, nxt]))
        return blocks
