"""The paper's six graph inputs (Table II), recreated synthetically.

Published statistics (Table II of the paper):

| Graph | Vertices | Edges   | MaxDeg | AvgDeg | Volume(KB) | Reuse     | Imbal.   |
|-------|----------|---------|--------|--------|------------|-----------|----------|
| AMZ   | 410236   | 6713648 | 2770   | 16.265 | 1855 (H)   | 0.160 (M) | 0.00 (L) |
| DCT   | 52652    | 178076  | 38     | 3.382  | 60 (M)     | 0.359 (M) | 0.08 (M) |
| EML   | 265214   | 837912  | 7636   | 3.159  | 287 (H)    | 0.053 (L) | 1.00 (H) |
| OLS   | 88263    | 683186  | 10     | 7.740  | 201 (M)    | 0.445 (H) | 0.00 (L) |
| RAJ   | 20640    | 163178  | 3469   | 7.906  | 48 (L)     | 0.594 (H) | 0.62 (H) |
| WNG   | 61032    | 243088  | 4      | 3.919  | 79 (M)     | ~0.005(L) | 0.00 (L) |

(Note: Table II prints WNG's Reuse as "0.594" but classifies it L; Eq. 6
with AN_L=0.020, AN_R=3.899, avg-deg 3.919 gives 0.0051 -> the printed value
is a typesetting duplication of RAJ's; we reproduce the class, L.)

``paper_graph(name)`` materialises a synthetic graph whose generator knobs
were tuned so the taxonomy classification (H/M/L for Volume/Reuse/Imbalance)
matches Table II.  ``paper_graph(name, scale=k)`` divides vertex/edge counts
by ``k`` for CPU-friendly benchmarks while preserving Reuse/Imbalance classes
(Volume is recomputed from the true reduced size, so benchmark tables always
report the classification actually measured).

``PAPER_STATS`` carries the published numbers for metric-faithfulness tests
that must be independent of synthesis (Volume classification is a pure
function of |V|, |E|).
"""
from __future__ import annotations

from functools import lru_cache

from repro.graph.generators import powerlaw_graph, regular_graph
from repro.graph.structure import Graph

__all__ = ["PAPER_GRAPHS", "PAPER_STATS", "paper_graph"]

PAPER_GRAPHS = ("AMZ", "DCT", "EML", "OLS", "RAJ", "WNG")

# name -> (vertices, edges, max_deg, avg_deg, volume_kb, reuse, imbalance,
#          vol_class, reuse_class, imb_class) from Table II.
PAPER_STATS = {
    "AMZ": (410236, 6713648, 2770, 16.265, 1855.178, 0.160, 0.000, "H", "M", "L"),
    "DCT": (52652, 178076, 38, 3.382, 60.078, 0.359, 0.083, "M", "M", "M"),
    "EML": (265214, 837912, 7636, 3.159, 287.272, 0.053, 1.000, "H", "L", "H"),
    "OLS": (88263, 683186, 10, 7.740, 200.898, 0.445, 0.000, "M", "H", "L"),
    "RAJ": (20640, 163178, 3469, 7.906, 47.869, 0.594, 0.617, "L", "H", "H"),
    "WNG": (61032, 243088, 4, 3.919, 79.458, 0.0051, 0.000, "M", "L", "L"),
}

# Published AN_L / AN_R (Table II) for Reuse-metric regression tests.
PAPER_AN = {
    "AMZ": (2.616, 13.749),
    "DCT": (1.215, 2.167),
    "EML": (0.167, 2.992),
    "OLS": (3.446, 4.295),
    "RAJ": (4.697, 3.209),
    "WNG": (0.020, 3.899),
}


@lru_cache(maxsize=None)
def paper_graph(name: str, scale: int = 1, weighted: bool = False,
                block_size: int = 256) -> Graph:
    """Synthetic recreation of a Table II input (optionally scaled down)."""
    if name not in PAPER_STATS:
        raise KeyError(f"unknown paper graph {name!r}; one of {PAPER_GRAPHS}")
    v, e, max_deg, avg_deg = PAPER_STATS[name][:4]
    n = max(4 * block_size, v // scale)
    ne = max(n * 2, e // scale)
    seed = hash(name) % (2**31)
    if name == "AMZ":      # skewed but degree-ordered ids -> warp maxes
        # homogeneous within each tile -> Imbalance L (like the real input)
        return powerlaw_graph(n, ne // 2, alpha=1.2, max_degree=max_deg,
                              locality=0.21, degree_order="sorted", seed=seed,
                              weighted=weighted, block_size=block_size)
    if name == "DCT":      # light skew, moderate locality, mild imbalance
        return powerlaw_graph(n, ne // 2, alpha=0.7, max_degree=max_deg,
                              locality=0.31, hub_fraction=0.12, seed=seed,
                              weighted=weighted, block_size=block_size)
    if name == "EML":      # heavy power law, low locality, hubs everywhere
        return powerlaw_graph(n, ne // 2, alpha=1.6, max_degree=max_deg,
                              locality=0.05, hub_fraction=1.0, seed=seed,
                              weighted=weighted, block_size=block_size)
    if name == "OLS":      # near-regular, high locality
        return regular_graph(n, degree=max(2, int(avg_deg / 2)), locality=0.56,
                             seed=seed, weighted=weighted,
                             block_size=block_size)
    if name == "RAJ":      # small, skewed, high locality
        return powerlaw_graph(n, ne // 2, alpha=1.1, max_degree=max_deg,
                              locality=0.62, hub_fraction=0.7, seed=seed,
                              weighted=weighted, block_size=block_size)
    # WNG: degree ~4, almost perfectly regular, no locality
    return regular_graph(n, degree=2, locality=0.005, seed=seed,
                         weighted=weighted, block_size=block_size)
