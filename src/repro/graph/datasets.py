"""The paper's six graph inputs (Table II), recreated synthetically.

Published statistics (Table II of the paper):

| Graph | Vertices | Edges   | MaxDeg | AvgDeg | Volume(KB) | Reuse     | Imbal.   |
|-------|----------|---------|--------|--------|------------|-----------|----------|
| AMZ   | 410236   | 6713648 | 2770   | 16.265 | 1855 (H)   | 0.160 (M) | 0.00 (L) |
| DCT   | 52652    | 178076  | 38     | 3.382  | 60 (M)     | 0.359 (M) | 0.08 (M) |
| EML   | 265214   | 837912  | 7636   | 3.159  | 287 (H)    | 0.053 (L) | 1.00 (H) |
| OLS   | 88263    | 683186  | 10     | 7.740  | 201 (M)    | 0.445 (H) | 0.00 (L) |
| RAJ   | 20640    | 163178  | 3469   | 7.906  | 48 (L)     | 0.594 (H) | 0.62 (H) |
| WNG   | 61032    | 243088  | 4      | 3.919  | 79 (M)     | ~0.005(L) | 0.00 (L) |

(Note: Table II prints WNG's Reuse as "0.594" but classifies it L; Eq. 6
with AN_L=0.020, AN_R=3.899, avg-deg 3.919 gives 0.0051 -> the printed value
is a typesetting duplication of RAJ's; we reproduce the class, L.)

``paper_graph(name)`` materialises a synthetic graph whose generator knobs
were tuned so the taxonomy classification (H/M/L for Volume/Reuse/Imbalance)
matches Table II.  ``paper_graph(name, scale=k)`` divides vertex/edge counts
by ``k`` for CPU-friendly benchmarks while preserving Reuse/Imbalance classes
(Volume is recomputed from the true reduced size, so benchmark tables always
report the classification actually measured).

``PAPER_STATS`` carries the published numbers for metric-faithfulness tests
that must be independent of synthesis (Volume classification is a pure
function of |V|, |E|).

Real inputs: ``dataset_graph(name)`` loads the actual SuiteSparse /
SNAP edge list when a local copy exists under ``$REPRO_DATA_DIR`` (or
``./data``) and otherwise falls back to the synthetic stand-in with a
matched degree signature — downloads are never attempted at import or
benchmark time.  ``fetch_instructions()`` prints the exact URLs and
shell commands to place the real files; ``degree_profile(graph)``
reports which profile class (near-regular / road-like, social
power-law, web-crawl hub-heavy) a loaded graph actually lands in so
the stand-in <-> real swap is auditable.
"""
from __future__ import annotations

import os
from functools import lru_cache
from pathlib import Path

import numpy as np

from repro.graph.generators import powerlaw_graph, regular_graph
from repro.graph.structure import Graph

__all__ = ["PAPER_GRAPHS", "PAPER_STATS", "PAPER_SOURCES",
           "DEGREE_PROFILES", "paper_graph", "dataset_graph",
           "load_real_graph", "real_graph_path", "degree_profile",
           "fetch_instructions"]

PAPER_GRAPHS = ("AMZ", "DCT", "EML", "OLS", "RAJ", "WNG")

# name -> (vertices, edges, max_deg, avg_deg, volume_kb, reuse, imbalance,
#          vol_class, reuse_class, imb_class) from Table II.
PAPER_STATS = {
    "AMZ": (410236, 6713648, 2770, 16.265, 1855.178, 0.160, 0.000, "H", "M", "L"),
    "DCT": (52652, 178076, 38, 3.382, 60.078, 0.359, 0.083, "M", "M", "M"),
    "EML": (265214, 837912, 7636, 3.159, 287.272, 0.053, 1.000, "H", "L", "H"),
    "OLS": (88263, 683186, 10, 7.740, 200.898, 0.445, 0.000, "M", "H", "L"),
    "RAJ": (20640, 163178, 3469, 7.906, 47.869, 0.594, 0.617, "L", "H", "H"),
    "WNG": (61032, 243088, 4, 3.919, 79.458, 0.0051, 0.000, "M", "L", "L"),
}

# Published AN_L / AN_R (Table II) for Reuse-metric regression tests.
PAPER_AN = {
    "AMZ": (2.616, 13.749),
    "DCT": (1.215, 2.167),
    "EML": (0.167, 2.992),
    "OLS": (3.446, 4.295),
    "RAJ": (4.697, 3.209),
    "WNG": (0.020, 3.899),
}


# name -> (degree-profile class, upstream dataset, fetch URL).  The
# profile classes are the ISSUE's taxonomy: how the degree distribution
# shapes push/pull and tiling behavior, independent of raw size.
#   near-regular : tight degree band, no hubs (road-network-like)
#   social       : power-law tail, moderate hubs
#   web-crawl    : heavy power-law, extreme hubs dominate edge mass
PAPER_SOURCES = {
    "AMZ": ("social", "SNAP com-Amazon (co-purchase)",
            "https://snap.stanford.edu/data/bigdata/communities/com-amazon.ungraph.txt.gz"),
    "DCT": ("near-regular", "SuiteSparse Pajek/dictionary28",
            "https://suitesparse-collection-website.herokuapp.com/MM/Pajek/dictionary28.tar.gz"),
    "EML": ("web-crawl", "SNAP email-EuAll",
            "https://snap.stanford.edu/data/email-EuAll.txt.gz"),
    "OLS": ("near-regular", "SuiteSparse olesnik0",
            "https://suitesparse-collection-website.herokuapp.com/MM/GHS_indef/olesnik0.tar.gz"),
    "RAJ": ("social", "SuiteSparse raj1 (circuit)",
            "https://suitesparse-collection-website.herokuapp.com/MM/Rajat/rajat01.tar.gz"),
    "WNG": ("near-regular", "SuiteSparse wing (FE mesh)",
            "https://suitesparse-collection-website.herokuapp.com/MM/DIMACS10/wing.tar.gz"),
}

# profile class -> the degree-feature bands a member should land in
# (checked against ``kernels.autotune.degree_features``; ``degree_skew``
# is the coefficient of variation of out-degree).
DEGREE_PROFILES = {
    "near-regular": {"degree_skew": (0.0, 0.6)},
    "social": {"degree_skew": (0.6, 3.0)},
    "web-crawl": {"degree_skew": (3.0, float("inf"))},
}


@lru_cache(maxsize=None)
def paper_graph(name: str, scale: int = 1, weighted: bool = False,
                block_size: int = 256) -> Graph:
    """Synthetic recreation of a Table II input (optionally scaled down)."""
    if name not in PAPER_STATS:
        raise KeyError(f"unknown paper graph {name!r}; one of {PAPER_GRAPHS}")
    v, e, max_deg, avg_deg = PAPER_STATS[name][:4]
    n = max(4 * block_size, v // scale)
    ne = max(n * 2, e // scale)
    seed = hash(name) % (2**31)
    if name == "AMZ":      # skewed but degree-ordered ids -> warp maxes
        # homogeneous within each tile -> Imbalance L (like the real input)
        return powerlaw_graph(n, ne // 2, alpha=1.2, max_degree=max_deg,
                              locality=0.21, degree_order="sorted", seed=seed,
                              weighted=weighted, block_size=block_size)
    if name == "DCT":      # light skew, moderate locality, mild imbalance
        return powerlaw_graph(n, ne // 2, alpha=0.7, max_degree=max_deg,
                              locality=0.31, hub_fraction=0.12, seed=seed,
                              weighted=weighted, block_size=block_size)
    if name == "EML":      # heavy power law, low locality, hubs everywhere
        return powerlaw_graph(n, ne // 2, alpha=1.6, max_degree=max_deg,
                              locality=0.05, hub_fraction=1.0, seed=seed,
                              weighted=weighted, block_size=block_size)
    if name == "OLS":      # near-regular, high locality
        return regular_graph(n, degree=max(2, int(avg_deg / 2)), locality=0.56,
                             seed=seed, weighted=weighted,
                             block_size=block_size)
    if name == "RAJ":      # small, skewed, high locality
        return powerlaw_graph(n, ne // 2, alpha=1.1, max_degree=max_deg,
                              locality=0.62, hub_fraction=0.7, seed=seed,
                              weighted=weighted, block_size=block_size)
    # WNG: degree ~4, almost perfectly regular, no locality
    return regular_graph(n, degree=2, locality=0.005, seed=seed,
                         weighted=weighted, block_size=block_size)


# ---------------------------------------------------------------------------
# real inputs: local edge lists with synthetic fallback
# ---------------------------------------------------------------------------
def _data_dir() -> Path:
    return Path(os.environ.get("REPRO_DATA_DIR", "data"))


def real_graph_path(name: str) -> Path | None:
    """Path of a locally fetched edge list for ``name``, or None.

    Accepted layouts under ``$REPRO_DATA_DIR`` (default ``./data``):
    ``<NAME>.txt``/``<NAME>.edges`` (whitespace ``src dst [weight]``
    rows, ``#``/``%`` comments) or ``<NAME>.mtx`` (MatrixMarket
    coordinate, 1-based).  Gzip variants (``.gz``) are accepted too.
    """
    base = _data_dir()
    for ext in (".txt", ".edges", ".mtx", ".txt.gz", ".edges.gz",
                ".mtx.gz"):
        p = base / f"{name}{ext}"
        if p.is_file():
            return p
    return None


def load_real_graph(path, weighted: bool = False,
                    block_size: int = 256) -> Graph:
    """Parse a local edge-list / MatrixMarket file into a :class:`Graph`.

    The paper's universal input format is symmetric, so edges are
    symmetrized; self loops and duplicates are dropped by
    ``Graph.from_coo``.  Vertex ids are compacted to ``0..V-1``.
    """
    path = Path(path)
    opener = __import__("gzip").open if path.suffix == ".gz" else open
    is_mtx = ".mtx" in path.suffixes or path.suffix == ".mtx"
    rows = []
    with opener(path, "rt") as fh:
        header_skipped = False
        for line in fh:
            line = line.strip()
            if not line or line[0] in "#%":
                continue
            if is_mtx and not header_skipped:
                header_skipped = True  # dimensions line
                continue
            parts = line.split()
            s, d = int(float(parts[0])), int(float(parts[1]))
            w = float(parts[2]) if weighted and len(parts) > 2 else 1.0
            rows.append((s, d, w))
    if not rows:
        raise ValueError(f"no edges parsed from {path}")
    arr = np.asarray(rows, np.float64)
    src, dst = arr[:, 0].astype(np.int64), arr[:, 1].astype(np.int64)
    if is_mtx:  # MatrixMarket is 1-based
        src, dst = src - 1, dst - 1
    # compact ids (SNAP lists are sparse in id space)
    ids, inv = np.unique(np.concatenate([src, dst]), return_inverse=True)
    src, dst = inv[:src.size], inv[src.size:]
    weight = arr[:, 2].astype(np.float32) if weighted else None
    return Graph.from_coo(src, dst, n_nodes=int(ids.size), weight=weight,
                          block_size=block_size, symmetrize=True)


def dataset_graph(name: str, scale: int = 1, weighted: bool = False,
                  block_size: int = 256, prefer_real: bool = True):
    """A Table II input: the real graph when fetched locally, else the
    synthetic stand-in.  Returns ``(graph, source)`` where ``source``
    is ``"real"`` or ``"synthetic"`` — benchmark tables record it so a
    run against stand-ins is never mistaken for one against the real
    inputs.  ``scale`` only applies to the synthetic path (the real
    file is whatever was fetched)."""
    if prefer_real:
        p = real_graph_path(name)
        if p is not None:
            return (load_real_graph(p, weighted=weighted,
                                    block_size=block_size), "real")
    return (paper_graph(name, scale=scale, weighted=weighted,
                        block_size=block_size), "synthetic")


def degree_profile(graph) -> dict:
    """Classify a graph into the :data:`DEGREE_PROFILES` taxonomy.

    Returns the ``kernels.autotune.degree_features`` dict extended with
    ``profile`` (the matched class) and ``signature`` (the quantized
    cache key) — the audit trail that a synthetic stand-in actually
    matches its real input's degree shape.
    """
    from repro.kernels.autotune import degree_features, degree_signature
    feats = degree_features(graph)
    skew = feats["degree_skew"]
    profile = next((cls for cls, bands in DEGREE_PROFILES.items()
                    if bands["degree_skew"][0] <= skew
                    < bands["degree_skew"][1]), "near-regular")
    return {**feats, "profile": profile,
            "signature": degree_signature(feats)}


def fetch_instructions(name: str | None = None) -> str:
    """Shell commands that place the real inputs where
    :func:`dataset_graph` finds them.  Never executed by this package —
    the container has no network; run them yourself where you do."""
    names = [name] if name else list(PAPER_GRAPHS)
    lines = [f"mkdir -p {_data_dir()}"]
    for n in names:
        profile, source, url = PAPER_SOURCES[n]
        lines.append(f"# {n}: {source} ({profile})")
        tgt = f"{_data_dir()}/{n}.txt.gz"
        if url.endswith(".tar.gz"):
            lines.append(f"curl -L {url} | tar -xzO '*.mtx' "
                         f"| gzip > {_data_dir()}/{n}.mtx.gz")
        else:
            lines.append(f"curl -L -o {tgt} {url}")
    return "\n".join(lines)
