"""Graph partitioning for distributed (multi-device) execution.

Two layouts, mirroring the paper's coherence dimension at cluster scale:

- ``partition_edges_1d``: edges are sharded round-robin-by-block across
  devices; vertex state is replicated or sharded by vertex range.  With the
  *owned* (DeNovo-analogue) schedule each device accumulates a local partial
  vertex array over its edges and a single ``reduce-scatter``/``all-reduce``
  combines them — remote reuse is captured locally before communication.
- ``partition_vertices``: contiguous vertex ranges per device ("owner
  computes"); the *llc* (GPU-coherence-analogue) schedule sends every edge
  message to the target's owner via ``all-to-all`` and reduces remotely.

Both produce padded, rectangular per-device arrays (SPMD-friendly).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structure import Graph

__all__ = ["EdgePartition", "VertexPartition", "partition_edges_1d",
           "partition_vertices"]


@dataclasses.dataclass(frozen=True)
class EdgePartition:
    """[D, Ep] edge arrays padded with a sentinel target ``n_nodes``."""
    src: np.ndarray      # [D, Ep] int32
    dst: np.ndarray      # [D, Ep] int32
    weight: np.ndarray   # [D, Ep] float32
    n_devices: int
    n_nodes: int
    edges_per_device: int


@dataclasses.dataclass(frozen=True)
class VertexPartition:
    """Contiguous vertex ranges; per-device edge lists grouped by owner of
    ``dst`` (so each device receives exactly the updates it owns)."""
    vertex_offsets: np.ndarray   # [D+1]
    src: np.ndarray              # [D, Ep]
    dst: np.ndarray              # [D, Ep] (global ids)
    weight: np.ndarray           # [D, Ep]
    n_devices: int
    n_nodes: int
    edges_per_device: int


def _pad_groups(groups, sentinel_dst, n_devices):
    ep = max(1, max(g[0].shape[0] for g in groups))
    # round up to a multiple of 8 lanes for friendlier layouts
    ep = (ep + 7) // 8 * 8
    src = np.zeros((n_devices, ep), dtype=np.int32)
    dst = np.full((n_devices, ep), sentinel_dst, dtype=np.int32)
    w = np.zeros((n_devices, ep), dtype=np.float32)
    for d, (s, t, ww) in enumerate(groups):
        k = s.shape[0]
        src[d, :k], dst[d, :k], w[d, :k] = s, t, ww
    return src, dst, w, ep


def partition_edges_1d(g: Graph, n_devices: int) -> EdgePartition:
    s = np.asarray(g.src)
    t = np.asarray(g.dst)
    w = np.asarray(g.weight)
    groups = [(s[d::n_devices], t[d::n_devices], w[d::n_devices])
              for d in range(n_devices)]
    src, dst, ww, ep = _pad_groups(groups, g.n_nodes, n_devices)
    return EdgePartition(src, dst, ww, n_devices, g.n_nodes, ep)


def partition_vertices(g: Graph, n_devices: int) -> VertexPartition:
    s = np.asarray(g.src_in)
    t = np.asarray(g.dst_in)
    w = np.asarray(g.weight_in)
    per = (g.n_nodes + n_devices - 1) // n_devices
    offsets = np.minimum(np.arange(n_devices + 1) * per, g.n_nodes)
    owner = np.minimum(t // per, n_devices - 1)
    groups = []
    for d in range(n_devices):
        m = owner == d
        groups.append((s[m], t[m], w[m]))
    src, dst, ww, ep = _pad_groups(groups, g.n_nodes, n_devices)
    return VertexPartition(offsets.astype(np.int32), src, dst, ww,
                           n_devices, g.n_nodes, ep)
