"""Synthetic graph generators.

SuiteSparse is unavailable offline, so the paper's six inputs (Table II) are
recreated synthetically with matched *taxonomy-relevant* statistics: vertex
and edge counts, average/max degree shape (regular vs. power-law), locality
(drives the Reuse metric, Eq. 6 — controlled by the probability that an edge
lands inside the source's thread-block/vertex-tile), and degree skew
concentration (drives the Imbalance metric, Eq. 7).

All generators return directed symmetric graphs with self-loops removed,
matching the paper's universal input format (Sec. V-A).
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph

__all__ = [
    "regular_graph",
    "powerlaw_graph",
    "grid_graph",
    "random_graph",
    "rmat_graph",
    "rmat_batch",
]


def _finish(src, dst, n, rng, weighted, block_size):
    w = None
    if weighted:
        w = rng.uniform(1.0, 16.0, size=src.shape[0]).astype(np.float32)
    return Graph.from_coo(src, dst, n, weight=w, symmetrize=True,
                          block_size=block_size)


def _draw_targets(src, n, locality, rng, block_size):
    """Pick edge targets: with prob `locality` inside the source's block
    (local neighbor, Eq. 4), else uniform over all vertices (remote, Eq. 5).
    """
    e = src.shape[0]
    local = rng.random(e) < locality
    blk = src // block_size
    lo = blk * block_size
    hi = np.minimum(lo + block_size, n)
    t_local = lo + rng.integers(0, block_size, size=e) % np.maximum(hi - lo, 1)
    t_remote = rng.integers(0, n, size=e)
    return np.where(local, t_local, t_remote)


def regular_graph(n: int, degree: int, locality: float = 0.5,
                  seed: int = 0, weighted: bool = False,
                  block_size: int = 256) -> Graph:
    """Near-regular graph: every vertex has ~`degree` out-edges.

    Low degree variance -> low Imbalance.  `locality` tunes Reuse.
    """
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n, dtype=np.int64), degree)
    dst = _draw_targets(src, n, locality, rng, block_size)
    return _finish(src, dst, n, rng, weighted, block_size)


def powerlaw_graph(n: int, n_edges: int, alpha: float = 2.1,
                   max_degree: int | None = None, locality: float = 0.2,
                   hub_fraction: float = 1.0, degree_order: str = "shuffled",
                   seed: int = 0, weighted: bool = False,
                   block_size: int = 256) -> Graph:
    """Power-law (Zipf) degree sequence + configuration-model wiring.

    `alpha` is the Zipf exponent, `max_degree` caps hubs, `hub_fraction`
    controls how concentrated the hubs are across vertex tiles: 1.0 spreads
    hubs uniformly (imbalance touches many tiles -> high Imbalance metric),
    smaller values pack hubs into the first tiles (fewer imbalanced tiles).
    `degree_order='sorted'` keeps the degree sequence rank-ordered by vertex
    id: neighbors in id space have near-equal degree, so per-warp max
    degrees are homogeneous and Imbalance (Eq. 7) stays low even for very
    skewed sequences — the regime of crawl-ordered inputs like AMZ.
    """
    rng = np.random.default_rng(seed)
    # Zipf-ish degree sequence normalised to ~n_edges total
    ranks = np.arange(1, n + 1, dtype=np.float64)
    weights = ranks ** (-alpha)
    deg = weights / weights.sum() * n_edges
    if max_degree is not None:
        deg = np.minimum(deg, max_degree)
    deg = np.maximum(deg, 1).astype(np.int64)
    if degree_order == "shuffled":
        # place hub vertices
        n_hot = max(1, int(n * hub_fraction))
        perm = np.concatenate([
            rng.permutation(n_hot),
            n_hot + rng.permutation(n - n_hot),
        ]) if hub_fraction < 1.0 else rng.permutation(n)
        deg = deg[np.argsort(perm, kind="stable")]
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    dst = _draw_targets(src, n, locality, rng, block_size)
    return _finish(src, dst, n, rng, weighted, block_size)


def grid_graph(side: int, seed: int = 0, weighted: bool = False,
               block_size: int = 256) -> Graph:
    """2D grid/mesh (MeshGraphNet-style connectivity): degree<=4, very
    regular, high locality along one axis."""
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n, dtype=np.int64)
    right = idx[(idx % side) != side - 1]
    down = idx[idx < n - side]
    src = np.concatenate([right, down])
    dst = np.concatenate([right + 1, down + side])
    return _finish(src, dst, n, rng, weighted, block_size)


def random_graph(n: int, n_edges: int, seed: int = 0, weighted: bool = False,
                 block_size: int = 256) -> Graph:
    """Erdos-Renyi-ish uniform random graph."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=n_edges)
    dst = rng.integers(0, n, size=n_edges)
    return _finish(src, dst, n, rng, weighted, block_size)


def rmat_graph(scale: int, edge_factor: int = 8,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               seed: int = 0, weighted: bool = False,
               block_size: int = 256) -> Graph:
    """Graph500-style Recursive-MATrix (R-MAT) graph: 2**scale vertices,
    ~edge_factor * 2**scale edges before symmetrization/dedup.

    Each edge picks one quadrant of the adjacency matrix per bit level
    with probabilities (a, b, c, 1-a-b-c); the default Graph500
    parameters give the skewed, community-structured degree
    distribution GPU graph benchmarks standardize on — the pinned
    workload of ``benchmarks/dispatch.py``.
    """
    n = 1 << scale
    e = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(e, np.int64)
    dst = np.zeros(e, np.int64)
    for _ in range(scale):
        r = rng.random(e)
        # quadrants in row-major order: (0,0)=a, (0,1)=b, (1,0)=c, (1,1)=d
        src_bit = (r >= a + b).astype(np.int64)
        dst_bit = (((r >= a) & (r < a + b))
                   | (r >= a + b + c)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return _finish(src, dst, n, rng, weighted, block_size)


def rmat_batch(count: int, scale: int, edge_factor: int = 8,
               seed: int = 0, scale_spread: int = 0,
               weighted: bool = False, block_size: int = 256) -> list:
    """A serving-style batch workload: ``count`` independent R-MAT
    graphs with per-graph seeds (distinct edge sets, matched degree
    shape) — the input :func:`repro.core.run_batch` and
    ``benchmarks/batch.py`` consume.

    ``scale_spread > 0`` draws each graph's scale uniformly from
    ``[scale, scale + scale_spread]``, producing the *ragged* batches
    the padding buckets exist for; the default 0 keeps every graph in
    one bucket so a batch is a single packed dispatch.
    """
    rng = np.random.default_rng(seed)
    scales = (scale + rng.integers(0, scale_spread + 1, size=count)
              if scale_spread else np.full(count, scale, np.int64))
    return [rmat_graph(int(s), edge_factor, seed=seed + 1000 + i,
                       weighted=weighted, block_size=block_size)
            for i, s in enumerate(scales)]
