"""Write-ahead admission journal for the serving gateway.

The gateway's containment story (PR 8) is in-process: sentinel trips,
runner exceptions and stale-update corruption are healed from host-side
pre-slice states.  A gateway *process death* loses all of it — queues,
rosters, parked states, tickets.  :class:`WriteAheadJournal` is the
durable half: every admission-lifecycle transition is appended to an
on-disk journal **before** the in-memory step it describes completes,
so :meth:`~repro.launch.serve.ContinuousScheduler.recover` can rebuild
the unfinished ticket set of a killed gateway and re-admit each ticket
from its newest persisted slice boundary — producing results
bit-identical to the uninterrupted gateway (per-slot iteration
counters make cohort composition irrelevant; PR 8's fixpoint
certificate still proves every resumed convergence).

Layout under ``journal_dir``::

    journal.waj          append-only JSONL, one record per line:
                         ``<crc32 hex> <json body>``
    graphs/<fp>.npz      each distinct submitted graph, persisted once
                         verbatim (every Graph array bit-for-bit, keyed
                         by content SHA-256) — replay rebuilds the exact
                         graph, not a re-derivation
    tickets/<jid>/       a per-ticket :class:`~repro.core.durability.
                         CheckpointStore` holding its slice-boundary
                         states

Record types (all carry ``jid``, the journal-scoped ticket id —
``Ticket.id`` is a process-local counter and dies with the process):

- ``submit``: program name, config name, graph fingerprint, knobs,
  ``max_iters`` / ``deadline_s`` / serialized PRNG key.
- ``admit``: the ticket claimed a roster slot.
- ``commit``: one slice boundary committed — iteration counter plus the
  ticket's cumulative direction/occupancy traces and dispatch count
  (the checkpoint store holds the state itself; trace metadata lives
  here and is matched to a checkpoint by iteration, so a corrupt newest
  generation falls back to an older state *with* its matching traces).
- ``retire``: terminal outcome; the ticket's checkpoint store is
  deleted (a retired ticket is never re-admitted).

Each line's CRC makes torn writes self-describing: a crash can leave at
most one partial final line, which replay skips as an expected crash
artifact (counted, not fatal); any *interior* corruption is likewise
skipped and surfaced in :meth:`replay`'s report.  Replay itself appends
nothing — recovering twice from the same journal is idempotent by
construction.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from hashlib import sha256
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.durability import CheckpointStore
from repro.core.resilience import Checkpoint
from repro.graph.structure import Graph

__all__ = ["WriteAheadJournal", "JOURNAL_FILE"]

JOURNAL_FILE = "journal.waj"

#: Graph array fields persisted verbatim (order matters: it defines the
#: content fingerprint) plus the static ints.
_GRAPH_ARRAYS = ("src", "dst", "weight", "row_ptr_out", "src_in", "dst_in",
                 "weight_in", "row_ptr_in", "out_degree", "in_degree",
                 "perm_owned", "block_ptr")
_GRAPH_STATICS = ("n_nodes", "n_edges", "block_size")


def graph_fingerprint(graph: Graph) -> str:
    """Content SHA-256 over every array (values + dtype + shape) and
    static field — two bit-identical graphs share one persisted copy."""
    h = sha256()
    for name in _GRAPH_ARRAYS:
        a = np.asarray(getattr(graph, name))
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    for name in _GRAPH_STATICS:
        h.update(f"{name}={getattr(graph, name)}".encode())
    return h.hexdigest()


def _serialize_key(key) -> Optional[dict]:
    """A PRNG key as JSON (None when the key is not a plain array —
    replay then relies on the ticket's persisted checkpoints)."""
    if key is None:
        return None
    try:
        a = np.asarray(key)
        return {"dtype": str(a.dtype), "data": a.tolist()}
    except Exception:  # noqa: BLE001 — typed/opaque keys
        return None


def _deserialize_key(rec: Optional[dict]):
    if rec is None:
        return None
    return np.asarray(rec["data"], dtype=np.dtype(rec["dtype"]))


class WriteAheadJournal:
    """Append-only gateway journal plus its graph and checkpoint stores.

    One instance is owned by a scheduler; :meth:`replay` is the
    read-side used by recovery (it never writes).
    """

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        (self.root / "graphs").mkdir(exist_ok=True)
        (self.root / "tickets").mkdir(exist_ok=True)
        self.path = self.root / JOURNAL_FILE
        self.torn_lines = 0
        self._graph_cache: Dict[str, Graph] = {}
        records, _ = self.replay()
        self._next_jid = 1 + max(
            (int(j.split("-")[1]) for j in records), default=-1)

    # -- write side ------------------------------------------------------
    def _append(self, body: Dict[str, Any]) -> None:
        line = json.dumps(body, sort_keys=True)
        crc = zlib.crc32(line.encode()) & 0xFFFFFFFF
        with open(self.path, "a") as f:
            f.write(f"{crc:08x} {line}\n")
            f.flush()
            os.fsync(f.fileno())

    def record_submit(self, program, graph: Graph, config, *, key,
                      max_iters, deadline_s, knobs: Dict[str, Any]) -> str:
        """Persist the graph (once) and append the submit record;
        returns the journal-scoped ticket id."""
        jid = f"jid-{self._next_jid:08d}"
        self._next_jid += 1
        self._append({
            "type": "submit", "jid": jid,
            "program": program.name, "config": config.name,
            "graph": self.persist_graph(graph),
            "key": _serialize_key(key),
            "max_iters": max_iters, "deadline_s": deadline_s,
            "knobs": dict(knobs),
        })
        return jid

    def record_admit(self, jid: str) -> None:
        self._append({"type": "admit", "jid": jid})

    def record_commit(self, jid: str, it: int, state,
                      dispatches: int, trace: Optional[str],
                      occs: Optional[List[float]]) -> None:
        """One committed slice boundary: the record first (so every
        persisted checkpoint has its matching trace metadata even if
        the process dies between the two writes), then the state into
        the ticket's checkpoint store."""
        self._append({"type": "commit", "jid": jid, "it": int(it),
                      "dispatches": int(dispatches), "trace": trace,
                      "occs": occs})
        self.store_for(jid).save(Checkpoint(
            it=int(it), done=False, state=state,
            dir_buf=None, occ_buf=None))

    def record_retire(self, jid: str, outcome: str) -> None:
        self._append({"type": "retire", "jid": jid, "outcome": outcome})
        shutil.rmtree(self.root / "tickets" / jid, ignore_errors=True)

    # -- graph persistence ----------------------------------------------
    def persist_graph(self, graph: Graph) -> str:
        fp = graph_fingerprint(graph)
        path = self.root / "graphs" / f"{fp}.npz"
        if not path.exists():
            arrays = {n: np.asarray(getattr(graph, n))
                      for n in _GRAPH_ARRAYS}
            arrays["__static__"] = np.array(
                [int(getattr(graph, n)) for n in _GRAPH_STATICS], np.int64)
            tmp = path.with_name(f".tmp-{path.name}")
            with open(tmp, "wb") as f:
                np.savez(f, **arrays)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        self._graph_cache.setdefault(fp, graph)
        return fp

    def load_graph(self, fp: str) -> Graph:
        """Rebuild the persisted graph field-by-field (bit-identical to
        the submitted one — no ``from_coo`` re-derivation).  Cached per
        fingerprint so every replayed ticket over one graph shares a
        single instance (lane packing and the plan cache key on graph
        identity)."""
        if fp in self._graph_cache:
            return self._graph_cache[fp]
        path = self.root / "graphs" / f"{fp}.npz"
        with np.load(path, allow_pickle=False) as z:
            statics = z["__static__"]
            graph = Graph(
                **{n: z[n].copy() for n in _GRAPH_ARRAYS},
                **{n: int(statics[i])
                   for i, n in enumerate(_GRAPH_STATICS)})
        self._graph_cache[fp] = graph
        return graph

    def store_for(self, jid: str) -> CheckpointStore:
        return CheckpointStore(self.root / "tickets" / jid,
                               fingerprint={"jid": jid})

    # -- read side -------------------------------------------------------
    def replay(self) -> Tuple[Dict[str, Dict[str, Any]], Dict[str, int]]:
        """Fold the journal into per-ticket lifecycle state.

        Returns ``(tickets, report)``: ``tickets[jid]`` has the submit
        record under ``"submit"``, ``"admitted"``, the list of
        ``"commits"`` (ordered), and ``"retired"`` (outcome or None).
        ``report`` counts skipped lines — ``torn`` (bad CRC / partial
        line, the expected crash artifact) and ``orphan`` (a record for
        a jid with no surviving submit).
        """
        tickets: Dict[str, Dict[str, Any]] = {}
        report = {"lines": 0, "torn": 0, "orphan": 0}
        if not self.path.exists():
            self.torn_lines = 0
            return tickets, report
        for raw in self.path.read_text().splitlines():
            report["lines"] += 1
            try:
                crc_hex, line = raw.split(" ", 1)
                if (zlib.crc32(line.encode()) & 0xFFFFFFFF) != int(
                        crc_hex, 16):
                    raise ValueError("crc mismatch")
                body = json.loads(line)
            except Exception:  # noqa: BLE001 — torn/corrupt line
                report["torn"] += 1
                continue
            jid = body.get("jid")
            if body["type"] == "submit":
                tickets[jid] = {"submit": body, "admitted": False,
                                "commits": [], "retired": None}
                continue
            if jid not in tickets:
                report["orphan"] += 1
                continue
            if body["type"] == "admit":
                tickets[jid]["admitted"] = True
            elif body["type"] == "commit":
                tickets[jid]["commits"].append(body)
            elif body["type"] == "retire":
                tickets[jid]["retired"] = body["outcome"]
        self.torn_lines = report["torn"]
        return tickets, report

    def unfinished(self) -> Dict[str, Dict[str, Any]]:
        """The replayed tickets that never retired — the re-admission
        set for recovery, in submit order."""
        tickets, _ = self.replay()
        return {jid: rec for jid, rec in tickets.items()
                if rec["retired"] is None}
