"""Production mesh definitions (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    kwargs = {}
    # AxisType was added in jax 0.5; Auto is the default there, so
    # omitting axis_types on older jax preserves the same semantics.
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_local_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    n_data = n_data if n_data is not None else n // n_model
    return jax.make_mesh((n_data, n_model), ("data", "model"))
