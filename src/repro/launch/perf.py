import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Perf-iteration driver (EXPERIMENTS.md §Perf): lower ONE cell and report
# memory breakdown, cost, and the top collectives attributed to their HLO
# computation (while-loop bodies flagged: XLA counts them once; scanned
# models repeat them n_layers times).
#
#   PYTHONPATH=src python -m repro.launch.perf --arch command-r-plus-104b \
#       --shape train_4k [--multi-pod] [--top 15]

import argparse
import collections
import json
import re

from repro.launch.dryrun import DTYPE_BYTES, SHAPE_RE, run_cell, shape_bytes

COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")


def split_computations(hlo: str):
    """Yield (computation_name, body_text) blocks from HLO text."""
    blocks = []
    cur_name, cur = None, []
    for line in hlo.splitlines():
        m = re.match(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*(?:/\*.*)?$",
                     line)
        if m and ("{" in line) and ("=" not in line.split("{")[0]):
            if cur_name is not None:
                blocks.append((cur_name, "\n".join(cur)))
            cur_name, cur = m.group(1), []
        else:
            cur.append(line)
    if cur_name is not None:
        blocks.append((cur_name, "\n".join(cur)))
    return blocks


def while_bodies(hlo: str):
    """Names of computations used as while-loop bodies/conds."""
    names = set()
    for m in re.finditer(r"(body|condition)=%?([\w\.\-]+)", hlo):
        names.add(m.group(2))
    return names


def top_collectives(hlo: str, top: int = 15):
    bodies = while_bodies(hlo)
    rows = []
    for comp, text in split_computations(hlo):
        in_loop = comp.lstrip("%") in bodies
        for line in text.splitlines():
            for kind in COLL_KINDS:
                if re.search(rf"= [^=]*{kind}(-start)?\(", line):
                    lhs = line.split("(")[0]
                    b = shape_bytes(lhs)
                    if b:
                        rows.append({
                            "kind": kind, "bytes": b, "comp": comp,
                            "in_while_body": in_loop,
                            "shape": SHAPE_RE.search(lhs).group(0)
                            if SHAPE_RE.search(lhs) else "?",
                        })
                    break
    rows.sort(key=lambda r: -r["bytes"])
    agg = collections.Counter()
    loop_agg = collections.Counter()
    for r in rows:
        agg[r["kind"]] += r["bytes"]
        if r["in_while_body"]:
            loop_agg[r["kind"]] += r["bytes"]
    return rows[:top], dict(agg), dict(loop_agg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args()

    import repro.launch.dryrun as dr
    # capture the hlo text by re-running the cell with a hook
    orig = dr.collective_stats
    captured = {}

    def hook(hlo):
        captured["hlo"] = hlo
        return orig(hlo)

    dr.collective_stats = hook
    res = run_cell(args.arch, args.shape, args.multi_pod, verbose=False)
    dr.collective_stats = orig

    print(json.dumps({k: v for k, v in res.items()
                      if k in ("memory", "cost", "compile_s")}, indent=2))
    hlo = captured.get("hlo", "")
    if args.dump_hlo and hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(hlo)
    rows, agg, loop_agg = top_collectives(hlo, args.top)
    print("\n== collective totals (per device, while-bodies counted once)")
    for k, v in sorted(agg.items(), key=lambda kv: -kv[1]):
        inl = loop_agg.get(k, 0)
        print(f"  {k:<20} {v/1e9:8.3f} GB   (of which in-scan: "
              f"{inl/1e9:.3f} GB -> x n_layers at runtime)")
    print("\n== top collectives")
    for r in rows:
        tag = "[SCAN]" if r["in_while_body"] else "      "
        print(f"  {tag} {r['kind']:<18} {r['bytes']/1e9:8.3f} GB  "
              f"{r['shape']}  in {r['comp'][:40]}")


if __name__ == "__main__":
    main()
