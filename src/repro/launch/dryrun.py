import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count on first init) — hence no `from __future__` in this module.

DOC = """Multi-pod dry-run: lower + compile every (arch x input-shape) cell
on the production meshes and record memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-7b \
        --shape train_4k --mesh both --out results/dryrun

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
fails the cell.  512 host devices exist ONLY in this process (the env var
above must precede any jax import — jax locks the device count on first
init); smoke tests and benchmarks see 1 device.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import Axes, axes_for_mesh, opt_sharding_like
from repro.configs.registry import ARCH_NAMES, get_arch
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import adamw_init

COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9_\[\],\{\} ()]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)"
                      r"\[([0-9,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device bytes moved by collective kind, parsed from post-SPMD
    HLO (result shapes are per-device)."""
    stats: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(2).lower()
        # result shape(s) appear on the lhs of the '=' in HLO
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(")[0]
        b = shape_bytes(lhs)
        s = stats.setdefault(kind, {"count": 0, "bytes": 0})
        s["count"] += 1
        s["bytes"] += b
    return stats


def _named(mesh, spec_tree, abstract_tree):
    """Prefix spec tree (or None -> fully replicated) to NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if spec_tree is None:
        return NamedSharding(mesh, P())
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = axes_for_mesh(mesh)
    arch = get_arch(arch_name, axes=ax)
    cell = arch.cell(shape_name)

    if hasattr(arch, "abstract_params_for"):
        params_abs = arch.abstract_params_for(shape_name)
    else:
        params_abs = arch.abstract_params()
    param_spec = arch.param_sharding(ax)
    p_shard = _named(mesh, param_spec, params_abs)

    inputs_abs = cell.input_specs()
    in_shard = _named(mesh, cell.input_sharding(ax), inputs_abs)

    args = [params_abs]
    shards = [p_shard]
    if cell.needs_opt:
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        opt_shard = _named(
            mesh,
            opt_sharding_like(param_spec) if param_spec is not None else None,
            opt_abs)
        args.append(opt_abs)
        shards.append(opt_shard)
    args.append(inputs_abs)
    shards.append(in_shard)

    t0 = time.time()
    result = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(mesh.devices.shape)),
        "kind": cell.kind,
    }
    # set_mesh (not `with mesh:`): also installs the ABSTRACT mesh context
    # so in-model shard_map regions (MoE dispatch) see the mesh axes.
    # Pre-0.5 jax has no set_mesh; `with mesh:` covers the same regions
    # there because shard_map resolves axes from the physical mesh env.
    with (jax.sharding.set_mesh(mesh)
          if hasattr(jax.sharding, "set_mesh") else mesh):
        jitted = jax.jit(cell.step, in_shardings=tuple(shards),
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*args)
        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

        try:
            mem = compiled.memory_analysis()
            result["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "peak_bytes": int(mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - getattr(mem, "alias_size_in_bytes", 0)),
            }
        except Exception as exc:  # CPU backend may not implement it
            result["memory"] = {"error": str(exc)}
        try:
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            result["cost"] = {
                "flops": float(cost.get("flops", -1)),
                "bytes_accessed": float(cost.get("bytes accessed", -1)),
                "transcendentals": float(cost.get("transcendentals", 0)),
            }
        except Exception as exc:
            result["cost"] = {"error": str(exc)}
        try:
            hlo = compiled.as_text()
            result["collectives"] = collective_stats(hlo)
            result["hlo_bytes"] = len(hlo)
        except Exception as exc:
            result["collectives"] = {"error": str(exc)}
    result["total_s"] = round(time.time() - t0, 1)
    result["ok"] = True
    if verbose:
        print(json.dumps(result, indent=None), flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_NAMES} or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch_name in archs:
        arch = get_arch(arch_name)
        shapes = sorted(arch.cells) if args.shape == "all" else [args.shape]
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch_name}__{shape_name}__{'multi' if multi else 'single'}"
                path = out / f"{tag}.json"
                if args.skip_existing and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("ok"):
                        n_ok += 1
                        continue
                print(f"=== {tag}", flush=True)
                try:
                    res = run_cell(arch_name, shape_name, multi)
                    n_ok += 1
                except Exception as exc:
                    res = {"arch": arch_name, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "ok": False, "error": str(exc),
                           "traceback": traceback.format_exc()[-4000:]}
                    n_fail += 1
                    print(f"FAIL {tag}: {exc}", flush=True)
                path.write_text(json.dumps(res, indent=2))
    print(f"dryrun complete: {n_ok} ok, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
