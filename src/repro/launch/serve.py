"""Streaming graph-serving gateway with continuous batching.

The paper's result — no single (coherence, consistency, push/pull)
configuration wins across workloads — implies a serving front-end that
admits a live stream of heterogeneous ``(program, graph, config)``
queries and dispatches each on its best-fit packed batch.  This module
is that front-end, built vllm-style on iteration-level scheduling:

- **Admission.**  :meth:`GraphGateway.submit` validates the graph
  (:func:`repro.graph.structure.validate_graph` — malformed queries are
  rejected with a structured :class:`AdmissionError` before they can
  poison an in-flight batch), applies bounded-queue backpressure
  (:class:`GatewayBackpressure` once ``max_queue`` requests wait), and
  enqueues a :class:`Ticket` on the request's **lane** — the
  (program, config, knobs, :func:`~repro.core.batch.bucket_key`) class
  whose members are structurally compatible to pack together.

- **Continuous batching.**  Each lane keeps a *roster* of up to
  ``max_batch`` packed slots.  Every scheduling round admits waiting
  tickets into free slots and advances the whole roster by one fused
  ``slice_len``-iteration dispatch (:func:`~repro.core.batch.
  run_batch_slice`); converged requests retire at the slice boundary
  and newly arrived graphs join the next dispatch — the device stays
  saturated without waiting for stragglers.  Because each request
  carries its **own** iteration counter and freeze mask inside the
  packed batch, results are bit-identical to a sequential
  :func:`~repro.core.executor.run` no matter which cohort a request
  shared its dispatches with (inexact float-SUM programs like PR match
  ``run_batch`` bitwise and sequential ``run`` to float tolerance).

- **Fault containment.**  Every slice commit is guarded by the
  resilience layer (:mod:`repro.core.resilience`): host-side NaN /
  monotonicity sentinels check each active slot against its pre-slice
  state, a converged slot must additionally pass its program's
  fixpoint certificate before retiring, and a runner exception rolls
  the whole slice back (lane states are host-side between slices, so
  rollback is free), retries it under :class:`~repro.core.resilience.
  RetryPolicy`, then re-runs each surviving slot in an isolated B=1
  batch.  Only the offending slot is quarantined — ticket outcome
  ``"faulted"``, a structured :class:`~repro.core.resilience.
  ExecutionFault` on :meth:`Ticket.result` — while cohabitants resume
  from their parked state bit-identical to a solo run.

- **Plan-cache warmth.**  Rosters re-enter :data:`~repro.core.
  plan_cache.PLAN_CACHE` wholesale: an unchanged roster reuses its
  packed batch (``batch_pack``), bound context (``batch_context``) and
  compiled slice runner (``exec_fn``) outright, so the steady-state
  per-slice cost is one cached jitted call plus numpy repacking.

Quickstart (the README's 3-line session)::

    with GraphGateway() as gw:
        t = gw.submit(bfs(), graph, SystemConfig.from_name("DG1"))
        result = t.result()          # RunResult, bit-identical to run()

``python -m repro.launch.serve`` runs a self-contained demo; the LM
prefill/decode demo that used to live here moved to
``repro.launch.lm_demo`` (``--arch`` still forwards there).
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import sys
import threading
import time
import warnings
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import (BatchedEdgeContext, bucket_key,
                              get_graph_batch, run_batch_slice)
from repro.core.config_space import SystemConfig
from repro.core.executor import EdgeContext, RunResult, _normalize_autotune
from repro.core.plan_cache import PLAN_CACHE
from repro.core.resilience import (ExecutionFault, RetryPolicy,
                                   check_certificate, check_state_host)
from repro.core.vertex_program import VertexProgram
from repro.graph.structure import Graph, validate_graph

__all__ = ["GraphGateway", "ContinuousScheduler", "Ticket", "GatewayStats",
           "AdmissionError", "GatewayBackpressure", "OverloadError",
           "CancelledError", "main"]


class AdmissionError(ValueError):
    """A request rejected at admission, before touching any batch.

    ``code`` is a stable machine-readable class (``"invalid_graph"``),
    ``errors`` the list of human-readable structural defects
    :func:`~repro.graph.structure.validate_graph` found.
    """

    def __init__(self, code: str, errors: List[str]):
        super().__init__(f"{code}: " + "; ".join(errors))
        self.code = code
        self.errors = list(errors)


class GatewayBackpressure(RuntimeError):
    """Raised by ``submit`` when ``max_queue`` requests already wait —
    the bounded-queue signal that arrival rate exceeds service rate.
    Callers are expected to retry with backoff (or shed load)."""


class OverloadError(RuntimeError):
    """A deadline-carrying request shed at admission: the projected
    queue delay (waves of queued work ahead × the gateway's observed
    per-request service time, both from :class:`GatewayStats`) already
    exceeds the request's ``deadline_s``, so admitting it would only
    burn device time on a result the caller has declared worthless.

    ``code`` is ``"overload_shed"``; ``detail`` carries the projection
    the decision was made from.  Requests without a deadline are never
    shed — they fall under plain bounded-queue backpressure.
    """

    def __init__(self, code: str, detail: Optional[Dict[str, Any]] = None):
        self.code = code
        self.detail = dict(detail or {})
        super().__init__(f"{code}: {self.detail}" if self.detail else code)


class CancelledError(RuntimeError):
    """Raised by :meth:`Ticket.result` for a cancelled request."""


# ---------------------------------------------------------------------------
class Ticket:
    """One in-flight request: a future plus its lifecycle timestamps.

    Timestamps (``enqueued_at`` → ``admitted_at`` → ``first_dispatch_at``
    → ``completed_at``, on the gateway's clock) expose where a request
    spent its latency: queued behind backpressure, waiting for a roster
    slot, or actually iterating.
    """

    _ids = itertools.count()

    def __init__(self, program: VertexProgram, graph: Graph,
                 config: SystemConfig, key, max_iters: Optional[int],
                 deadline_s: Optional[float]):
        self.id = next(self._ids)
        #: journal-scoped id (stable across process restarts); assigned
        #: at submit when the scheduler runs with a write-ahead journal
        self.jid: Optional[str] = None
        #: recovery payload: ``(state, it, meta)`` from the ticket's
        #: newest persisted checkpoint — honoured (instead of
        #: ``program.init``) when the ticket claims a roster slot
        self._restore = None
        self.program = program
        self.graph = graph
        self.config = config
        #: how ``config`` was chosen at admission: "caller" unless the
        #: submit-side ``specialize=`` knob resolved it (then "static" /
        #: "static_partial" / "learned") — stamped onto the result
        self.config_source = "caller"
        self.key = key
        self.max_iters = max_iters
        self.deadline_s = deadline_s
        self.enqueued_at: Optional[float] = None
        self.admitted_at: Optional[float] = None
        self.first_dispatch_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self.cancelled = False
        self._event = threading.Event()
        self._result: Optional[RunResult] = None
        self._error: Optional[BaseException] = None
        self._on_cancel = None
        self._dispatches = 0
        self._trace: List[str] = []
        self._occs: List[float] = []
        self._traced = False
        self._occ_traced = False

    def cancel(self) -> None:
        """Request cancellation: honoured at the next slice boundary
        (mid-flight) or the next admission round (still queued)."""
        self.cancelled = True
        if self._on_cancel is not None:
            self._on_cancel()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RunResult:
        """The request's :class:`RunResult` (blocks up to ``timeout``).

        Raises :class:`CancelledError` for cancelled requests and
        ``TimeoutError`` when the result is not ready in time (with a
        pure :class:`ContinuousScheduler`, drive ``poll()`` first —
        nothing advances between polls).
        """
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.id} not finished")
        if self._error is not None:
            raise self._error
        return self._result

    def _finish(self, result: Optional[RunResult],
                error: Optional[BaseException], now: float) -> None:
        self.completed_at = now
        self._result, self._error = result, error
        self._event.set()


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class GatewayStats:
    """Aggregated request-lifecycle instrumentation.

    Counters cover every terminal outcome (completed = converged +
    iteration-limited + timed-out); the latency/occupancy samples feed
    :meth:`snapshot`'s p50/p99 and throughput summary — the metrics
    schema documented in docs/ARCHITECTURE.md and exported by
    ``benchmarks/serve.py``.
    """
    #: service-time samples kept for the shedding projection — bounded
    #: so one congestion episode ages out instead of biasing admission
    #: forever
    SERVICE_WINDOW = 32

    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    converged: int = 0
    timed_out: int = 0
    cancelled: int = 0
    faulted: int = 0
    rejected: int = 0
    backpressure_rejections: int = 0
    shed: int = 0
    #: admissions whose config was resolved by a specialization tier
    #: (``specialize=`` knob) rather than taken from the caller
    specialized: int = 0
    recovered_tickets: int = 0
    breaker_opens: int = 0
    breaker_closes: int = 0
    breaker_probes: int = 0
    solo_degraded_slices: int = 0
    slices: int = 0
    roster_rebuilds: int = 0
    slice_retries: int = 0
    sentinel_trips: int = 0
    quarantined: int = 0
    dispatch_seconds: float = 0.0
    recovery_seconds: float = 0.0
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    service_times_s: List[float] = dataclasses.field(default_factory=list)
    queue_delays_s: List[float] = dataclasses.field(default_factory=list)
    occupancy: List[float] = dataclasses.field(default_factory=list)
    requests: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    first_enqueue_at: Optional[float] = None
    last_complete_at: Optional[float] = None

    def record_submit(self, t: Ticket) -> None:
        self.submitted += 1
        if self.first_enqueue_at is None:
            self.first_enqueue_at = t.enqueued_at

    def record_slice(self, active: int, roster: int, seconds: float) -> None:
        self.slices += 1
        self.dispatch_seconds += seconds
        self.occupancy.append(active / max(1, roster))

    def record_done(self, t: Ticket, outcome: str) -> None:
        self.completed += 1 if outcome != "cancelled" else 0
        if outcome == "converged":
            self.converged += 1
        elif outcome == "timed_out":
            self.timed_out += 1
        elif outcome == "cancelled":
            self.cancelled += 1
        elif outcome == "faulted":
            self.faulted += 1
        self.last_complete_at = t.completed_at
        if outcome != "cancelled":
            self.latencies_s.append(t.completed_at - t.enqueued_at)
            if t.admitted_at is not None:
                self.service_times_s.append(t.completed_at - t.admitted_at)
                del self.service_times_s[:-self.SERVICE_WINDOW]
        if t.admitted_at is not None:
            self.queue_delays_s.append(t.admitted_at - t.enqueued_at)
        self.requests.append({
            "id": t.id, "outcome": outcome,
            "enqueued_at": t.enqueued_at, "admitted_at": t.admitted_at,
            "first_dispatch_at": t.first_dispatch_at,
            "completed_at": t.completed_at,
            "dispatches": t._dispatches,
        })

    @staticmethod
    def _pct(xs: List[float], q: float) -> Optional[float]:
        return float(np.percentile(np.asarray(xs), q)) if xs else None

    def projected_delay_s(self, queued_ahead: int,
                          max_batch: int) -> Optional[float]:
        """Projected delay until a request arriving behind
        ``queued_ahead`` waiting requests would finish: full admission
        waves (its own included) × the observed mean *service* time —
        ``completed_at - admitted_at``, over the newest
        ``SERVICE_WINDOW`` completions.  Queue wait is deliberately
        excluded and the window bounded, so a past congestion episode
        cannot inflate the projection and keep shedding requests after
        the queue has drained.  ``None`` until at least one admitted
        request has completed — a cold gateway never sheds on a
        projection it has no data for."""
        if not self.service_times_s:
            return None
        waves = (queued_ahead + max_batch) // max_batch
        return waves * float(np.mean(self.service_times_s))

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-able summary dict (the serving metrics schema)."""
        lat = self.latencies_s
        window = ((self.last_complete_at - self.first_enqueue_at)
                  if lat and self.last_complete_at is not None
                  and self.first_enqueue_at is not None else None)
        ms = lambda s: None if s is None else s * 1e3
        return {
            "submitted": self.submitted, "admitted": self.admitted,
            "completed": self.completed, "converged": self.converged,
            "timed_out": self.timed_out, "cancelled": self.cancelled,
            "faulted": self.faulted, "rejected": self.rejected,
            "backpressure_rejections": self.backpressure_rejections,
            "shed": self.shed,
            "specialized": self.specialized,
            "recovered_tickets": self.recovered_tickets,
            "breaker_opens": self.breaker_opens,
            "breaker_closes": self.breaker_closes,
            "breaker_probes": self.breaker_probes,
            "solo_degraded_slices": self.solo_degraded_slices,
            "slices": self.slices,
            "roster_rebuilds": self.roster_rebuilds,
            "slice_retries": self.slice_retries,
            "sentinel_trips": self.sentinel_trips,
            "quarantined": self.quarantined,
            "dispatch_seconds": self.dispatch_seconds,
            "recovery_seconds": self.recovery_seconds,
            "latency_p50_ms": ms(self._pct(lat, 50)),
            "latency_p99_ms": ms(self._pct(lat, 99)),
            "queue_delay_p50_ms": ms(self._pct(self.queue_delays_s, 50)),
            "mean_occupancy": (float(np.mean(self.occupancy))
                               if self.occupancy else None),
            "throughput_rps": (self.completed / window
                               if window else None),
        }


# ---------------------------------------------------------------------------
class _Breaker:
    """Per-lane circuit breaker over slice health.

    State machine (surfaced in ``GatewayStats``):

    - **closed** (healthy): packed-roster slices; ``threshold``
      *consecutive* faulty slices (runner exception or sentinel trip
      anywhere in the roster) trip it open.
    - **open**: the lane routes every active slot **solo-degraded**
      (isolated B=1 slices — per-slot iteration counters keep results
      bit-identical, only batching efficiency is sacrificed) so one
      poisoned cohabitant cannot keep failing the whole roster; after
      ``cooldown`` solo rounds the breaker half-opens.
    - **half-open**: the next dispatch is a single packed-roster
      *probe*; a clean probe closes the breaker, a faulty one reopens
      it for another cooldown.
    """

    def __init__(self, threshold: int = 3, cooldown: int = 4):
        if threshold < 1 or cooldown < 1:
            raise ValueError("breaker threshold and cooldown must be >= 1")
        self.threshold = int(threshold)
        self.cooldown = int(cooldown)
        self.state = "closed"
        self.failures = 0
        self._cool = 0

    def route(self) -> str:
        """How the next dispatch should run: ``"packed"`` / ``"solo"``
        / ``"probe"``."""
        if self.state == "open":
            return "solo"
        if self.state == "half_open":
            return "probe"
        return "packed"

    def tick(self, stats: GatewayStats) -> None:
        """One solo-degraded round elapsed while open."""
        self._cool -= 1
        if self._cool <= 0:
            self.state = "half_open"

    def record_fault(self, stats: GatewayStats) -> None:
        self.failures += 1
        if (self.state == "half_open"
                or (self.state == "closed"
                    and self.failures >= self.threshold)):
            self.state = "open"
            self._cool = self.cooldown
            self.failures = 0
            stats.breaker_opens += 1

    def record_clean(self, stats: GatewayStats) -> None:
        if self.state == "half_open":
            self.state = "closed"
            stats.breaker_closes += 1
        self.failures = 0


# ---------------------------------------------------------------------------
class _Lane:
    """One (program, config, knobs, bucket) service class.

    ``roster`` is the ordered tuple of graphs the packed batch is built
    from; a slot whose ticket retired stays in the roster as a parked
    placeholder (its rows frozen by the slice runner's done mask) so
    the compiled runner's shape — and the whole
    batch/context/executable plan-cache chain — survives request
    churn.  Only *membership* changes (a new graph claiming a slot, or
    roster growth toward ``max_batch``) rebuild the batch; re-admitting
    a graph already parked in the roster is entirely cache-warm.
    """

    def __init__(self, program: VertexProgram, config: SystemConfig,
                 use_pallas: bool, cap: Optional[int], autotune,
                 journal=None, breaker: Optional[_Breaker] = None):
        self.program = program
        self.config = config
        self.use_pallas = use_pallas
        self.cap = cap
        self.autotune = autotune
        self.journal = journal
        self.breaker = breaker if breaker is not None else _Breaker()
        self.queue: deque = deque()
        self.roster: List[Graph] = []
        self.tickets: List[Optional[Ticket]] = []
        self.states: List[Any] = []
        self.it_b: List[int] = []
        self.limit_b: List[int] = []
        self.batch = None
        self.bctx = None

    # -- admission ------------------------------------------------------
    def _claim_slot(self, graph: Graph, max_batch: int) -> Optional[int]:
        free = [i for i, t in enumerate(self.tickets) if t is None]
        for i in free:  # cache-warm: same graph already in the roster
            if self.roster[i] is graph:
                return i
        if free:
            self.roster[free[0]] = graph
            return free[0]
        if len(self.roster) < max_batch:
            self.roster.append(graph)
            self.tickets.append(None)
            self.states.append(None)
            self.it_b.append(0)
            self.limit_b.append(0)
            return len(self.roster) - 1
        return None

    def admit(self, max_batch: int, clock, stats: GatewayStats) -> bool:
        """Drain waiting tickets into free roster slots; returns True
        when at least one ticket was admitted this round."""
        before = tuple(id(g) for g in self.roster)
        admitted = False
        while self.queue:
            t = self.queue[0]
            if t.cancelled:
                self.queue.popleft()
                t._finish(None, CancelledError(f"request {t.id} cancelled "
                                               "while queued"), clock())
                stats.record_done(t, "cancelled")
                if self.journal is not None and t.jid is not None:
                    self.journal.record_retire(t.jid, "cancelled")
                continue
            slot = self._claim_slot(t.graph, max_batch)
            if slot is None:
                break
            self.queue.popleft()
            self.tickets[slot] = t
            if t._restore is not None:
                # journal recovery: resume from the ticket's newest
                # persisted slice boundary instead of iteration 0 —
                # state, iteration counter and cumulative traces all
                # come from the checkpoint, so the remaining slices are
                # the ones the killed gateway had left to run
                st, it0, meta = t._restore
                self.states[slot] = st
                self.it_b[slot] = int(it0)
                self.limit_b[slot] = int(t.max_iters
                                         if t.max_iters is not None
                                         else self.program.max_iters)
                t._dispatches = int(meta.get("dispatches", 0))
                if meta.get("trace") is not None:
                    t._traced = True
                    t._trace = list(meta["trace"])
                if meta.get("occs") is not None:
                    t._occ_traced = True
                    t._occs = list(meta["occs"])
                t._restore = None
                t.admitted_at = clock()
                stats.admitted += 1
                admitted = True
                if self.journal is not None and t.jid is not None:
                    self.journal.record_admit(t.jid)
                continue
            if t.key is None:
                # default-key init is deterministic per graph (randomized
                # apps derive their key from graph_key), so repeat traffic
                # over a graph reuses its host init state — kind
                # "init_state", evicted with the graph like every other
                # per-graph plan.  Safe to share: packing only reads it
                # and the first slice replaces the slot with fresh copies.
                st = PLAN_CACHE.get(
                    t.graph, "init_state", (id(self.program),),
                    lambda: jax.tree.map(np.asarray,
                                         self.program.init(t.graph)))
            else:
                st = jax.tree.map(np.asarray,
                                  self.program.init(t.graph, t.key))
            self.states[slot] = st
            self.it_b[slot] = 0
            self.limit_b[slot] = int(t.max_iters
                                     if t.max_iters is not None
                                     else self.program.max_iters)
            t.admitted_at = clock()
            stats.admitted += 1
            admitted = True
            if self.journal is not None and t.jid is not None:
                self.journal.record_admit(t.jid)
        if tuple(id(g) for g in self.roster) != before:
            self.batch = get_graph_batch(tuple(self.roster))
            self.bctx = BatchedEdgeContext.create(
                self.batch, self.config, use_pallas=self.use_pallas,
                sparse_edge_capacity=self.cap, autotune=self.autotune)
            stats.roster_rebuilds += 1
        return admitted

    # -- execution ------------------------------------------------------
    def dispatch(self, slice_len: int, clock, stats: GatewayStats,
                 retry: Optional[RetryPolicy] = None,
                 sentinels: bool = True, injector=None) -> bool:
        """One fused slice over the roster; retires finished requests
        at the slice boundary.  Returns True when work was done.

        Lane states are host-side numpy between slices and only
        committed after the slice's sentinel checks, so a runner
        exception (or injected fault) rolls back for free: the failed
        slice is retried whole under ``retry``, then slot-by-slot in
        isolated B=1 batches, and only slots that still fail are
        quarantined (``_quarantine``) — cohabitants never lose work.

        The lane's circuit breaker sits above all of this: repeated
        faulty slices open it, routing every slot solo-degraded (B=1,
        bit-identical, just unbatched) until a half-open packed probe
        comes back clean.
        """
        active = [i for i, t in enumerate(self.tickets) if t is not None]
        if not active:
            return False
        now = clock()
        for i in active:
            if self.tickets[i].first_dispatch_at is None:
                self.tickets[i].first_dispatch_at = now
        # pre-slice host snapshots: the rollback point AND the sentinel
        # baseline (unpack replaces the list wholesale, so these
        # references stay untouched by the dispatch)
        prev = {i: self.states[i] for i in active}
        route = self.breaker.route()
        if route == "solo":
            stats.solo_degraded_slices += 1
            for i in active:
                self._solo_advance(i, prev[i], slice_len, clock, stats,
                                   sentinels, injector)
            self.breaker.tick(stats)
            return True
        if route == "probe":
            stats.breaker_probes += 1
        trips_before = stats.sentinel_trips
        try:
            if injector is not None:
                injector.before_slice([self.tickets[i].id for i in active])
            sl = self._run_slice(slice_len)
        except Exception:  # noqa: BLE001 — containment is the point
            self.breaker.record_fault(stats)
            self._recover(active, prev, slice_len, clock, stats, retry,
                          sentinels, injector)
            return True
        self.states = self.batch.unpack_state_host(sl.state)
        stats.record_slice(len(active), len(self.roster), sl.seconds)
        now = clock()
        for i in active:
            self._commit_slot(i, i, sl, self.states[i], prev[i], now,
                              stats, sentinels, injector)
        if stats.sentinel_trips > trips_before:
            self.breaker.record_fault(stats)
        else:
            self.breaker.record_clean(stats)
        return True

    def _run_slice(self, slice_len: int):
        parked = np.asarray([t is None for t in self.tickets])
        packed = self.batch.pack_state_host(self.states,
                                            pad=self.program.state_pad)
        packed = jax.tree.map(jnp.asarray, packed)
        return run_batch_slice(
            self.program, self.batch, self.bctx, packed,
            np.asarray(self.it_b, np.int32), parked,
            np.asarray(self.limit_b, np.int32), slice_len)

    def _commit_slot(self, i: int, b: int, sl, st, prev, now: float,
                     stats: GatewayStats, sentinels: bool,
                     injector) -> None:
        """Commit roster slot ``i`` from row ``b`` of slice result
        ``sl`` — or quarantine it if a sentinel (or, at convergence,
        the program's fixpoint certificate) rejects the new state."""
        t = self.tickets[i]
        if injector is not None:
            p = injector.perturb_slot(t.id, st)
            if p is not None:
                st = p
        if sentinels:
            tripped = check_state_host(self.program, prev, st)
            if tripped:
                stats.sentinel_trips += 1
                self.states[i] = prev  # keep the clean pre-slice state
                self._quarantine(i, now, ExecutionFault("sentinel", {
                    "ticket": t.id, "sentinels": tripped,
                    "iteration": int(sl.it_b[b])}), stats)
                return
        self.states[i] = st
        self.it_b[i] = int(sl.it_b[b])
        adv = int(sl.advanced[b])
        t._dispatches += 1
        if sl.dir_cols is not None:
            t._traced = True
            t._trace.extend("T" if x else "S"
                            for x in sl.dir_cols[b, :adv])
        if sl.occ_cols is not None:
            t._occ_traced = True
            t._occs.extend(float(o) for o in sl.occ_cols[b, :adv])
        if self.journal is not None and t.jid is not None:
            # durable slice boundary: sentinel-checked state only (the
            # quarantine path above never persists), so recovery always
            # resumes from a clean boundary
            self.journal.record_commit(
                t.jid, self.it_b[i], st, t._dispatches,
                "".join(t._trace) if t._traced else None,
                list(t._occs) if t._occ_traced else None)
        if t.cancelled:
            self._retire(i, now, "cancelled", stats)
        elif bool(sl.converged_b[b]):
            if sentinels and not self._certified(i):
                stats.sentinel_trips += 1
                self._quarantine(i, now, ExecutionFault("certificate", {
                    "ticket": t.id, "iteration": self.it_b[i]}), stats)
            else:
                self._retire(i, now, "converged", stats)
        elif self.it_b[i] >= self.limit_b[i]:
            self._retire(i, now, "iteration_limit", stats)
        elif (t.deadline_s is not None
              and now >= t.enqueued_at + t.deadline_s):
            # deadlines fire only at slice boundaries: the request
            # keeps the partial state of its last completed slice
            self._retire(i, now, "timed_out", stats)

    def _certified(self, i: int) -> bool:
        """Fixpoint-certificate check for a converged slot, on a solo
        (cached) context for the slot's own graph — the O(E) proof that
        catches dropped-update staleness no boundary sentinel can see.
        Programs without a certificate pass vacuously."""
        if self.program.certificate is None:
            return True
        ctx = EdgeContext.create(
            self.roster[i], self.config, use_pallas=self.use_pallas,
            sparse_edge_capacity=self.cap, autotune=self.autotune)
        return check_certificate(self.program, ctx,
                                 self.states[i]) is not False

    def _recover(self, active: List[int], prev: Dict[int, Any],
                 slice_len: int, clock, stats: GatewayStats,
                 retry: Optional[RetryPolicy], sentinels: bool,
                 injector) -> None:
        """A slice dispatch raised: states were never committed, so
        every active slot still holds its pre-slice host state.  Retry
        the roster whole (``retry.max_attempts`` total tries), then
        advance each slot alone in a B=1 batch — a slot that fails even
        solo is quarantined with the structured error; the rest resume
        bit-identical to a solo run."""
        t0 = time.perf_counter()
        stats.slice_retries += 1
        tries = (retry.max_attempts if retry is not None else 1) - 1
        for _ in range(tries):
            try:
                if injector is not None:
                    injector.before_slice(
                        [self.tickets[i].id for i in active])
                sl = self._run_slice(slice_len)
            except Exception:  # noqa: BLE001
                stats.slice_retries += 1
                continue
            self.states = self.batch.unpack_state_host(sl.state)
            stats.record_slice(len(active), len(self.roster), sl.seconds)
            now = clock()
            for i in active:
                self._commit_slot(i, i, sl, self.states[i], prev[i], now,
                                  stats, sentinels, injector)
            stats.recovery_seconds += time.perf_counter() - t0
            return
        for i in active:
            self._solo_advance(i, prev[i], slice_len, clock, stats,
                               sentinels, injector)
        stats.recovery_seconds += time.perf_counter() - t0

    def _solo_advance(self, i: int, prev, slice_len: int, clock,
                      stats: GatewayStats, sentinels: bool,
                      injector) -> None:
        """Advance roster slot ``i`` alone in an isolated B=1 batch —
        the shared tail of slice recovery and open-breaker degraded
        routing.  Per-slot iteration counters make the solo slice
        bit-identical to the packed one; a slot that fails even solo is
        quarantined with the structured error."""
        t = self.tickets[i]
        try:
            if injector is not None:
                injector.before_slice([t.id])
            batch = get_graph_batch((self.roster[i],))
            bctx = BatchedEdgeContext.create(
                batch, self.config, use_pallas=self.use_pallas,
                sparse_edge_capacity=self.cap, autotune=self.autotune)
            packed = batch.pack_state_host(
                [self.states[i]], pad=self.program.state_pad)
            packed = jax.tree.map(jnp.asarray, packed)
            sl = run_batch_slice(
                self.program, batch, bctx, packed,
                np.asarray([self.it_b[i]], np.int32),
                np.asarray([False]),
                np.asarray([self.limit_b[i]], np.int32), slice_len)
        except Exception as err:  # noqa: BLE001
            self._quarantine(i, clock(), ExecutionFault(
                "slice_exception",
                {"ticket": t.id, "error": repr(err)}), stats)
            return
        st = batch.unpack_state_host(sl.state)[0]
        stats.record_slice(1, 1, sl.seconds)
        self._commit_slot(i, 0, sl, st, prev, clock(), stats,
                          sentinels, injector)

    def _retire(self, i: int, now: float, outcome: str,
                stats: GatewayStats) -> None:
        t = self.tickets[i]
        self.tickets[i] = None
        if outcome == "cancelled":
            t._finish(None, CancelledError(
                f"request {t.id} cancelled mid-flight"), now)
        else:
            t._finish(RunResult(
                state=self.states[i],
                iterations=self.it_b[i],
                seconds=now - t.enqueued_at,
                converged=(outcome == "converged"),
                direction_trace="".join(t._trace) if t._traced else None,
                occupancy_trace=t._occs if t._occ_traced else None,
                engine="gateway", dispatches=t._dispatches,
                timed_out=(outcome == "timed_out"),
                config_name=t.config.name,
                config_source=t.config_source), None, now)
        stats.record_done(t, outcome)
        if self.journal is not None and t.jid is not None:
            self.journal.record_retire(t.jid, outcome)

    def _quarantine(self, i: int, now: float, err: ExecutionFault,
                    stats: GatewayStats) -> None:
        """Terminal containment for one slot: free it (the roster keeps
        the parked placeholder, so cohabitants' compiled plans survive)
        and surface the structured fault on the ticket."""
        t = self.tickets[i]
        self.tickets[i] = None
        t._finish(None, err, now)
        stats.quarantined += 1
        stats.record_done(t, "faulted")
        if self.journal is not None and t.jid is not None:
            self.journal.record_retire(t.jid, "faulted")

    def pending(self) -> bool:
        return bool(self.queue) or any(t is not None for t in self.tickets)


# ---------------------------------------------------------------------------
class ContinuousScheduler:
    """The gateway's deterministic core: no threads, no wall-clock
    dependence beyond the injectable ``clock``.

    ``submit`` validates + enqueues; each ``poll()`` is one scheduling
    round — admit waiting requests into every lane, then advance every
    lane with active work by one fused slice.  The fault-injection and
    property tests drive this class directly so arbitrary
    arrival/retirement interleavings are replayable; production traffic
    goes through :class:`GraphGateway`, which runs the same scheduler
    under a worker thread.
    """

    def __init__(self, max_batch: int = 8, slice_len: int = 4,
                 max_queue: int = 256, clock=time.monotonic,
                 retry: Optional[RetryPolicy] = RetryPolicy(max_attempts=2),
                 sentinels: bool = True, fault_injector=None,
                 journal_dir=None, breaker_threshold: int = 3,
                 breaker_cooldown: int = 4):
        if max_batch < 1 or slice_len < 1 or max_queue < 1:
            raise ValueError("max_batch, slice_len and max_queue must "
                             "be >= 1")
        self.max_batch = int(max_batch)
        self.slice_len = int(slice_len)
        self.max_queue = int(max_queue)
        self.clock = clock
        self.retry = retry
        self.sentinels = bool(sentinels)
        self.fault_injector = fault_injector
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown = int(breaker_cooldown)
        self.journal = None
        if journal_dir is not None:
            from repro.launch.journal import WriteAheadJournal
            self.journal = WriteAheadJournal(journal_dir)
        self.stats = GatewayStats()
        self._lanes: Dict[tuple, _Lane] = {}

    def queued(self) -> int:
        return sum(len(l.queue) for l in self._lanes.values())

    def submit(self, program: VertexProgram, graph: Graph,
               config: SystemConfig, *, key=None,
               max_iters: Optional[int] = None,
               deadline_s: Optional[float] = None,
               use_pallas: bool = False,
               sparse_edge_capacity: Optional[int] = None,
               autotune=None, specialize=None) -> Ticket:
        """Admit one query; returns its :class:`Ticket`.

        Raises :class:`AdmissionError` for structurally invalid graphs,
        :class:`GatewayBackpressure` when the waiting queue is full,
        and :class:`OverloadError` for a deadline-carrying request
        whose projected queue delay already exceeds its ``deadline_s``
        (deadline-aware load shedding) — all *before* the request
        touches any lane state.

        ``specialize`` (``"off"``/``"static"``/``"learned"``, default
        off) resolves the config this request actually runs under at
        admission time via
        :func:`repro.core.specialize_learned.resolve_config` — after
        the admission checks, so shed/rejected traffic never pays the
        profiling cost.  The resolved config picks the lane (requests
        predicted into different configs never share a packed roster),
        is journaled for crash recovery, and is stamped with its source
        on the result's ``config_name``/``config_source``.
        """
        errors = validate_graph(graph)
        if errors:
            self.stats.rejected += 1
            raise AdmissionError("invalid_graph", errors)
        if self.queued() >= self.max_queue:
            self.stats.backpressure_rejections += 1
            raise GatewayBackpressure(
                f"{self.queued()} requests already queued "
                f"(max_queue={self.max_queue})")
        if deadline_s is not None:
            delay = self.stats.projected_delay_s(self.queued(),
                                                 self.max_batch)
            if delay is not None and delay > deadline_s:
                self.stats.shed += 1
                raise OverloadError("overload_shed", {
                    "projected_delay_s": delay,
                    "deadline_s": float(deadline_s),
                    "queued": self.queued(),
                    "max_batch": self.max_batch})
        cap = (None if sparse_edge_capacity is None
               else int(sparse_edge_capacity))
        mode = _normalize_autotune(autotune)
        config_source = "caller"
        if specialize not in (None, False, "off"):
            from repro.core.specialize_learned import resolve_config
            config, config_source = resolve_config(program, graph, config,
                                                   specialize)
            if config_source != "caller":
                self.stats.specialized += 1
        lane_key = (id(program), config, bool(use_pallas), cap, mode,
                    bucket_key(graph))
        lane = self._lanes.get(lane_key)
        if lane is None:
            lane = self._lanes[lane_key] = _Lane(
                program, config, bool(use_pallas), cap, mode,
                journal=self.journal,
                breaker=_Breaker(self.breaker_threshold,
                                 self.breaker_cooldown))
        t = Ticket(program, graph, config, key, max_iters, deadline_s)
        t.config_source = config_source
        t.enqueued_at = self.clock()
        if self.journal is not None:
            # the *resolved* config is journaled, so recovery replays the
            # decision without needing the model file to still exist
            t.jid = self.journal.record_submit(
                program, graph, config, key=key, max_iters=max_iters,
                deadline_s=deadline_s,
                knobs={"use_pallas": bool(use_pallas),
                       "sparse_edge_capacity": cap, "autotune": mode,
                       "config_source": config_source})
        lane.queue.append(t)
        self.stats.record_submit(t)
        return t

    def recover(self, journal_dir) -> List[Ticket]:
        """Replay a write-ahead journal and re-admit every unfinished
        ticket; returns the recovered tickets (in submit order).

        Each recovered ticket resumes from its newest intact persisted
        slice boundary (cold-restarts at iteration 0 when none
        survives), with its graph rebuilt bit-identically from the
        journal's graph store — so driving the recovered scheduler to
        idle produces results bit-identical to the uninterrupted
        gateway.  Replay appends nothing to the journal: recovering
        twice from the same journal yields the same ticket set, states
        and counters (idempotence).  ``deadline_s`` clocks restart at
        recovery time — the dead gateway's wall-clock is meaningless
        here.  Subsequent activity (admissions, commits, retirements,
        new submissions) journals to ``journal_dir``.

        Tickets already live in this process are never re-admitted: a
        scheduler constructed with ``journal_dir=X`` that then calls
        ``recover(X)`` (or calls ``recover`` twice) sees its own
        unfinished submissions in the journal, and replaying them would
        put two :class:`Ticket` objects on one jid — both executing,
        commits interleaving under the same checkpoint store.  Such
        jids are skipped; only tickets with no live counterpart are
        rebuilt.
        """
        from repro.launch.journal import WriteAheadJournal, _deserialize_key
        from repro.algorithms import REGISTRY
        self.journal = WriteAheadJournal(journal_dir)
        for lane in self._lanes.values():
            lane.journal = self.journal
        live_jids = {t.jid for lane in self._lanes.values()
                     for t in [*lane.queue, *lane.tickets]
                     if t is not None and t.jid is not None}
        programs: Dict[str, VertexProgram] = {}
        recovered: List[Ticket] = []
        for jid, rec in self.journal.unfinished().items():
            if jid in live_jids:
                continue
            sub = rec["submit"]
            program = programs.setdefault(sub["program"],
                                          REGISTRY[sub["program"]]())
            graph = self.journal.load_graph(sub["graph"])
            config = SystemConfig.from_name(sub["config"])
            knobs = sub["knobs"]
            t = Ticket(program, graph, config,
                       _deserialize_key(sub["key"]), sub["max_iters"],
                       sub["deadline_s"])
            t.config_source = knobs.get("config_source", "caller")
            t.jid = jid
            t.enqueued_at = self.clock()
            cp, _ckpt_faults = self.journal.store_for(jid).load_latest()
            if cp is not None:
                meta = next((c for c in reversed(rec["commits"])
                             if c["it"] == cp.it), {})
                t._restore = (cp.state, cp.it, meta)
            lane_key = (id(program), config, knobs["use_pallas"],
                        knobs["sparse_edge_capacity"], knobs["autotune"],
                        bucket_key(graph))
            lane = self._lanes.get(lane_key)
            if lane is None:
                lane = self._lanes[lane_key] = _Lane(
                    program, config, knobs["use_pallas"],
                    knobs["sparse_edge_capacity"], knobs["autotune"],
                    journal=self.journal,
                    breaker=_Breaker(self.breaker_threshold,
                                     self.breaker_cooldown))
            lane.queue.append(t)
            self.stats.record_submit(t)
            self.stats.recovered_tickets += 1
            recovered.append(t)
        return recovered

    def poll(self) -> int:
        """One scheduling round; returns how many slices dispatched."""
        for lane in self._lanes.values():
            lane.admit(self.max_batch, self.clock, self.stats)
        return sum(lane.dispatch(self.slice_len, self.clock, self.stats,
                                 retry=self.retry, sentinels=self.sentinels,
                                 injector=self.fault_injector)
                   for lane in self._lanes.values())

    def pending(self) -> bool:
        return any(lane.pending() for lane in self._lanes.values())

    def reset_stats(self) -> GatewayStats:
        """Swap in a fresh :class:`GatewayStats` (returns the old one).
        Lanes, rosters and compiled runners stay warm — benchmarks call
        this after their warmup wave so measured windows exclude
        roster-growth compiles."""
        old, self.stats = self.stats, GatewayStats()
        return old

    def run_until_idle(self, max_rounds: int = 1_000_000) -> None:
        for _ in range(max_rounds):
            if not self.pending():
                return
            self.poll()
        raise RuntimeError(f"gateway not idle after {max_rounds} rounds")


# ---------------------------------------------------------------------------
class GraphGateway:
    """Threaded front-end over :class:`ContinuousScheduler`.

    ``submit`` is safe from any thread and returns immediately with a
    :class:`Ticket`; a single worker thread runs scheduling rounds
    whenever work is pending and sleeps otherwise.  Use as a context
    manager (``with GraphGateway() as gw: ...``) or call
    ``start()``/``close()`` explicitly; ``drain()`` blocks until every
    accepted request reached a terminal state.
    """

    def __init__(self, max_batch: int = 8, slice_len: int = 4,
                 max_queue: int = 256, clock=time.monotonic,
                 retry: Optional[RetryPolicy] = RetryPolicy(max_attempts=2),
                 sentinels: bool = True, fault_injector=None,
                 journal_dir=None, breaker_threshold: int = 3,
                 breaker_cooldown: int = 4):
        self._sched = ContinuousScheduler(
            max_batch=max_batch, slice_len=slice_len, max_queue=max_queue,
            clock=clock, retry=retry, sentinels=sentinels,
            fault_injector=fault_injector, journal_dir=journal_dir,
            breaker_threshold=breaker_threshold,
            breaker_cooldown=breaker_cooldown)
        self._wake = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "GraphGateway":
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(target=self._loop,
                                            name="graph-gateway",
                                            daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        """Finish in-flight work, then stop the worker thread."""
        with self._wake:
            self._stop = True
            self._wake.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "GraphGateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- API ------------------------------------------------------------
    def submit(self, program: VertexProgram, graph: Graph,
               config: SystemConfig, **kw) -> Ticket:
        with self._wake:
            if self._thread is None or self._stop:
                raise RuntimeError("gateway is not running "
                                   "(use `with GraphGateway() as gw`)")
            t = self._sched.submit(program, graph, config, **kw)
            t._on_cancel = self._kick
            self._wake.notify_all()
            return t

    def recover(self, journal_dir) -> List[Ticket]:
        """Replay ``journal_dir``'s write-ahead journal and re-admit
        every unfinished ticket (see
        :meth:`ContinuousScheduler.recover`); wakes the worker so the
        recovered work starts immediately."""
        with self._wake:
            tickets = self._sched.recover(journal_dir)
            for t in tickets:
                t._on_cancel = self._kick
            self._wake.notify_all()
            return tickets

    def stats(self) -> Dict[str, Any]:
        with self._wake:
            return self._sched.stats.snapshot()

    def reset_stats(self) -> None:
        with self._wake:
            self._sched.reset_stats()

    def drain(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._wake:
                if not self._sched.pending():
                    return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("gateway still busy after drain timeout")
            time.sleep(1e-4)

    def _kick(self) -> None:
        with self._wake:
            self._wake.notify_all()

    def _loop(self) -> None:
        while True:
            with self._wake:
                while not self._stop and not self._sched.pending():
                    self._wake.wait(timeout=0.05)
                if self._stop and not self._sched.pending():
                    return
                self._sched.poll()


# ---------------------------------------------------------------------------
def main(argv: Optional[List[str]] = None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    if any(a == "--arch" or a.startswith("--arch=") for a in argv):
        warnings.warn(
            "the LM serving demo moved to repro.launch.lm_demo; "
            "`python -m repro.launch.serve --arch ...` forwards there "
            "and will be removed", DeprecationWarning, stacklevel=2)
        from repro.launch import lm_demo
        return lm_demo.main(argv)

    ap = argparse.ArgumentParser(
        description="streaming graph-serving gateway demo")
    ap.add_argument("--app", default="BFS")
    ap.add_argument("--config", default="DG1")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--pool", type=int, default=6,
                    help="distinct graphs cycled through the stream")
    ap.add_argument("--scale", type=int, default=5,
                    help="R-MAT scale of the pool graphs")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--slice-len", type=int, default=4)
    args = ap.parse_args(argv)

    from repro.algorithms import REGISTRY
    from repro.graph import rmat_batch

    prog = REGISTRY[args.app]()
    config = SystemConfig.from_name(args.config)
    pool = rmat_batch(args.pool, args.scale, seed=7)
    with GraphGateway(max_batch=args.max_batch,
                      slice_len=args.slice_len) as gw:
        tickets = [gw.submit(prog, pool[i % len(pool)], config)
                   for i in range(args.requests)]
        results = [t.result(timeout=600) for t in tickets]
        snap = gw.stats()
    print(f"{args.app}/{args.config}: {len(results)} requests, "
          f"{snap['slices']} slices, "
          f"{snap['roster_rebuilds']} roster rebuilds")
    print(f"p50 {snap['latency_p50_ms']:.1f} ms  "
          f"p99 {snap['latency_p99_ms']:.1f} ms  "
          f"throughput {snap['throughput_rps']:.1f} req/s  "
          f"occupancy {snap['mean_occupancy']:.2f}")


if __name__ == "__main__":
    main()
