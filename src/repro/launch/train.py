"""Training launcher: `--arch <id>` selects a registry architecture and
trains its REDUCED config on synthetic data with the full substrate
(checkpointing, preemption, retry, straggler tracking).  On a TPU slice
the same entry point runs the full config against the production mesh
(the dry-run proves that configuration compiles).

    PYTHONPATH=src python -m repro.launch.train --arch pna --steps 100
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_arch
from repro.data.synthetic import dlrm_batch, gnn_batch, lm_batch
from repro.graph import powerlaw_graph
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train.trainer import TrainLoopConfig, train_loop


def _loss_fn(arch, cfg):
    if arch.family in ("lm", "moe"):
        if arch.family == "moe":
            from repro.models.moe import moe_train_forward as fwd
        else:
            from repro.models.transformer import train_forward as fwd
        return lambda p, b: fwd(cfg, p, b)
    if arch.family == "recsys":
        from repro.models.dlrm import dlrm_loss
        return lambda p, b: dlrm_loss(cfg, p, b)
    from repro.models.gnn import (equiformer_loss, mgn_loss, pna_loss,
                                  schnet_loss)
    return {
        "meshgraphnet": lambda p, b: mgn_loss(cfg, p, b),
        "schnet": lambda p, b: schnet_loss(cfg, p, b),
        "pna": lambda p, b: pna_loss(cfg, p, b),
        "equiformer-v2": lambda p, b: equiformer_loss(cfg, p, b),
    }[arch.name]


def _make_batch_fn(arch, cfg, batch, seq):
    if arch.family in ("lm", "moe"):
        return lambda s: jax.tree.map(
            jnp.asarray, lm_batch(s, batch, seq, cfg.vocab))
    if arch.family == "recsys":
        return lambda s: jax.tree.map(
            jnp.asarray, dlrm_batch(s, batch, cfg.vocab_sizes,
                                    cfg.multi_hot))
    g = powerlaw_graph(512, 4000, alpha=1.0, seed=0, block_size=64)
    rng = np.random.default_rng(0)
    n, e = 512, g.n_edges

    def gnn_fixed(s):
        if arch.name == "pna":
            return jax.tree.map(jnp.asarray,
                                gnn_batch(0, g, cfg.d_in, cfg.n_classes))
        base = {
            "src": jnp.asarray(np.asarray(g.src, np.int32)),
            "dst": jnp.asarray(np.asarray(g.dst, np.int32)),
        }
        if arch.name == "meshgraphnet":
            base.update({
                "node_feat": jnp.asarray(rng.standard_normal(
                    (n, cfg.d_node_in)).astype(np.float32)),
                "edge_feat": jnp.asarray(rng.standard_normal(
                    (e, cfg.d_edge_in)).astype(np.float32)),
                "target": jnp.zeros((n, cfg.d_out), jnp.float32),
            })
        else:
            gg = cfg.n_graphs
            base.update({
                "species": jnp.asarray(rng.integers(0, 10, n)
                                       .astype(np.int32)),
                "positions": jnp.asarray(rng.standard_normal((n, 3))
                                         .astype(np.float32)),
                "graph_ids": jnp.asarray((np.arange(n) % gg)
                                         .astype(np.int32)),
                "energy": jnp.zeros((gg,), jnp.float32),
            })
        return base

    return gnn_fixed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.reduced_cfg
    loss_fn = _loss_fn(arch, cfg)
    params = arch.init_params(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=args.lr)

    def step(p, o, b):
        loss, grads = jax.value_and_grad(lambda pp: loss_fn(pp, b))(p)
        p2, o2, gnorm = adamw_update(grads, o, p, opt_cfg)
        return p2, o2, {"loss": loss, "grad_norm": gnorm}

    make_batch = _make_batch_fn(arch, cfg, args.batch, args.seq)
    loop = TrainLoopConfig(total_steps=args.steps, log_every=10,
                           checkpoint_every=max(args.steps // 2, 1),
                           checkpoint_dir=args.ckpt)
    _, _, hist = train_loop(
        step, params, make_batch, loop,
        log_fn=lambda r: print(f"step {r['step']:>5}  loss {r['loss']:.4f}"
                               f"  ({r['seconds']*1e3:.0f} ms)", flush=True))
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
