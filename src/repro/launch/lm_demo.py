"""LM serving demo: prefill + batched KV-cache decode for an LM arch
(reduced config on CPU; the production shapes are proven by the dry-run).

    PYTHONPATH=src python -m repro.launch.lm_demo --arch starcoder2-7b \
        --batch 4 --prompt-len 32 --gen 16

Relocated from ``repro.launch.serve``, which now hosts the streaming
graph-serving gateway; ``python -m repro.launch.serve --arch ...`` still
forwards here with a deprecation warning.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_arch
from repro.data.synthetic import lm_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b",
                    choices=[a for a in ARCH_NAMES
                             if "moe" in a or "command" in a
                             or "starcoder" in a or "grok" in a])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.reduced_cfg
    if arch.family == "moe":
        from repro.models.moe import init_moe_lm as init
        from repro.models.moe import moe_decode_step as decode_step
        from repro.models.moe import moe_prefill as prefill
    else:
        from repro.models.transformer import (decode_step, init_lm as init,
                                              prefill)
    params = init(jax.random.key(0), cfg)

    b, s = args.batch, args.prompt_len
    prompt = jnp.asarray(lm_batch(0, b, s, cfg.vocab)["tokens"])
    t0 = time.perf_counter()
    logits, cache = jax.jit(lambda p, t: prefill(cfg, p, t))(params, prompt)
    jax.block_until_ready(logits)
    print(f"prefill[{b}x{s}]: {(time.perf_counter()-t0)*1e3:.0f} ms "
          f"(incl. compile)")

    smax = s + args.gen
    kc = jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, smax, cfg.d_head),
                   jnp.bfloat16).at[:, :, :, :s].set(
        cache[0].astype(jnp.bfloat16))
    vc = jnp.zeros_like(kc).at[:, :, :, :s].set(
        cache[1].astype(jnp.bfloat16))
    decode = jax.jit(lambda p, t, c, n: decode_step(cfg, p, t, c, n))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [np.asarray(tok[:, 0])]
    t0 = time.perf_counter()
    for i in range(args.gen):
        lg, (kc, vc) = decode(params, tok, (kc, vc), jnp.int32(s + i))
        tok = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
        outs.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(tok)
    dt = (time.perf_counter() - t0) / args.gen
    print(f"decode: {dt*1e3:.1f} ms/token/batch "
          f"({args.gen} steps, batch {b})")
    print("sample token ids:", np.stack(outs, 1)[0][:12].tolist())


if __name__ == "__main__":
    main()
