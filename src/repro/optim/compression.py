"""Gradient compression for cross-pod reduction (DESIGN.md §6).

``CompressedReducer`` casts gradients to a narrow dtype before the
(cross-pod) all-reduce and keeps the quantisation residual locally,
adding it back into the next step's gradient (error feedback — the
standard convergence-preserving trick).  At 2×16×16 scale the pod-axis
gradient reduction halves its bytes with bf16 (or 4× with f8 where
supported); the within-pod reduction stays full precision.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["CompressedReducer"]


class CompressedReducer:
    """compress -> reduce_fn -> decompress, with error feedback.

    ``reduce_fn`` is whatever performs the cross-replica mean (a psum
    inside shard_map, or identity under GSPMD where jit inserts it); this
    class owns only the numerics.
    """

    def __init__(self, dtype=jnp.bfloat16):
        self.dtype = jnp.dtype(dtype)

    def init_state(self, grads: Any) -> Any:
        """Per-leaf fp32 residual accumulators."""
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress(self, grads: Any, state: Any) -> tuple[Any, Any]:
        """Returns (wire_grads in self.dtype, new residual state)."""
        def one(g, r):
            full = g.astype(jnp.float32) + r
            wire = full.astype(self.dtype)
            return wire, full - wire.astype(jnp.float32)

        pairs = jax.tree.map(one, grads, state)
        wires = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        resid = jax.tree.map(lambda p: p[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return wires, resid

    def reduce(self, grads: Any, state: Any, reduce_fn=None
               ) -> tuple[Any, Any]:
        """One full round: compress -> reduce -> fp32 decompress."""
        wires, resid = self.compress(grads, state)
        if reduce_fn is not None:
            wires = reduce_fn(wires)
        out = jax.tree.map(lambda w: w.astype(jnp.float32), wires)
        return out, resid
