"""AdamW with decoupled weight decay — states are plain pytrees that
inherit the parameter sharding (moments fp32, realistic HBM accounting)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Any):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)) + 1e-16)
    scale = jnp.minimum(1.0, cfg.grad_clip / gnorm)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        newp = p.astype(jnp.float32) - cfg.lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps)
            + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, gnorm
