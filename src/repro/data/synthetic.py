"""Synthetic data sources for every family (offline container: no real
corpora).  Deterministic per (seed, step) — restart-safe by construction:
the pipeline can replay any step after an elastic restart."""
from __future__ import annotations

import numpy as np

__all__ = ["lm_batch", "gnn_batch", "dlrm_batch"]


def lm_batch(step: int, batch: int, seq: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng((seed, step))
    # zipf-ish marginals so the loss curve is non-trivial
    tok = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64) % vocab
    return {"tokens": tok[:, :-1].astype(np.int32),
            "labels": tok[:, 1:].astype(np.int32)}


def gnn_batch(step: int, graph, d_feat: int, n_classes: int, seed: int = 0):
    rng = np.random.default_rng((seed, step))
    n = graph.n_nodes
    return {
        "node_feat": rng.standard_normal((n, d_feat)).astype(np.float32),
        "src": np.asarray(graph.src, np.int32),
        "dst": np.asarray(graph.dst, np.int32),
        "in_degree": np.asarray(graph.in_degree, np.int32),
        "labels": rng.integers(0, n_classes, n).astype(np.int32),
    }


def dlrm_batch(step: int, batch: int, vocab_sizes, multi_hot: int = 1,
               seed: int = 0):
    rng = np.random.default_rng((seed, step))
    sparse = np.stack(
        [rng.integers(0, v, (batch, multi_hot)) for v in vocab_sizes],
        axis=1).astype(np.int32)
    return {
        "dense": rng.standard_normal((batch, 13)).astype(np.float32),
        "sparse": sparse,
        "label": rng.integers(0, 2, batch).astype(np.int32),
    }
