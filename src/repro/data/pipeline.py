"""Host data pipeline: deterministic sharded iteration + prefetch.

Every host draws only its shard of the global batch (``host_id`` /
``n_hosts``), generation is a pure function of (seed, step) so restarts and
elastic resizes replay exactly, and a background thread keeps ``depth``
batches ready (overlapping host data work with device compute)."""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

__all__ = ["ShardedPipeline"]


class ShardedPipeline:
    def __init__(self, make_batch: Callable[[int], Any], start_step: int = 0,
                 depth: int = 2):
        self.make_batch = make_batch
        self.depth = depth
        self._step = start_step
        self._q: "queue.Queue[tuple[int, Any]]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.make_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, Any]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
