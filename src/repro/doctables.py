"""Single source of truth for the README's knob tables.

README.md documents three knob surfaces — ``run()``, the gateway
constructor, and per-``submit`` request knobs — that historically
drifted from the actual signatures as PRs grew them.  This module pins
each documented knob row next to the callable it describes, renders
the markdown tables, and rewrites the README blocks between
``<!-- knobs:<section>:begin/end -->`` markers:

    PYTHONPATH=src python -m repro.doctables --check   # CI / tests
    PYTHONPATH=src python -m repro.doctables --write   # regenerate

``tests/test_docs.py`` enforces both directions of freshness: every
documented knob must exist in the target's ``inspect.signature`` and
every signature parameter must have a documented row (so adding a knob
without documenting it fails the suite), and the README block must
equal the rendered table byte for byte.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import re
import sys
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

__all__ = ["SECTIONS", "render", "doc_knobs", "signature_knobs",
           "inject", "check_text", "marker"]

#: one documented row: (knob names it covers, values column, meaning)
Row = Tuple[Tuple[str, ...], str, str]

_RUN_ROWS: List[Row] = [
    (("engine",), '`"fused"` \\| `"host"`',
     "whole-loop `lax.while_loop` dispatch vs kernel-per-iteration "
     "oracle"),
    (("use_pallas",), "`False` \\| `True`",
     "XLA scatter/segment reductions vs the blocked Pallas reducers"),
    (("sparse_edge_capacity",), "`ceil(E/alpha)` \\| `0` \\| any int",
     "static gather capacity of the sparse frontier path (0 disables "
     "it)"),
    (("autotune",), '`"off"` \\| `"heuristic"` \\| `"measure"`',
     "blocked-reducer tiling plans: static default / degree-heuristic "
     "`suggest_plan` (zero measurement) / empirical candidate sweep, "
     "cached per graph and persisted to `results/autotune_cache.json` "
     "keyed by degree signature"),
    (("specialize",), '`"off"` \\| `"static"` \\| `"learned"`',
     "resolve the config this workload actually runs under: as passed "
     "/ the paper's full decision tree on (Table III properties, "
     "taxonomy profile) / the trained model "
     "(`results/specialize_model.json`), falling back learned → "
     "static partial → caller with a structured warning; resolved "
     "choice cached in `PLAN_CACHE` (`specialized_config`) and "
     "stamped on `RunResult.config_name`/`config_source` — see "
     "docs/SPECIALIZATION.md"),
    (("max_iters", "warmup"), "program default; `True`",
     "iteration cap; compile outside the timed region"),
    (("checkpoint_every",), "`None` \\| int",
     "segment the fused loop every K iterations, snapshotting each "
     "boundary into a host-side `CheckpointRing` — bit-identical to "
     "the unsegmented run (one compiled executable serves every "
     "segment)"),
    (("retry",), "`None` \\| `RetryPolicy(max_attempts, backoff_s)`",
     "on a sentinel trip / runner exception: roll back one checkpoint "
     "deeper per attempt and walk the degradation chain (as-is → "
     "default plans → dense → fused → host); exhausted attempts "
     'return `outcome="faulted"` with the fault history'),
    (("sentinels",), "`True` \\| `False`",
     "per-segment invariant battery (NaN guard, declared "
     "monotonicity, custom program sentinels, occupancy sanity) plus "
     "the O(E) convergence certificate at retire"),
    (("ring_capacity",), "`4` \\| int",
     "checkpoints kept (pinned initial + newest `C-1`); `1` = "
     "cold-restart semantics"),
    (("checkpoint_dir",), "`None` \\| path",
     "spill every ring boundary to a durable `CheckpointStore` "
     "(atomic write-then-rename, versioned header, sha256 content "
     "digest); a rerun resumes from the newest intact generation "
     "**bit-identical** to the uninterrupted run — corrupt "
     "generations are rejected with a structured `corrupt_checkpoint` "
     "fault and fall back to the previous one, then cold restart"),
    (("fault_injector",), "`None` \\| `FaultInjector`",
     "test/benchmark hook — seeded injectors in "
     "`repro.testing.faults`"),
]

_GATEWAY_ROWS: List[Row] = [
    (("max_batch", "slice_len"), "`8`, `4`",
     "roster slots packed per lane and iterations per fused slice "
     "(the continuous-batching grain)"),
    (("max_queue",), "`256`",
     "waiting-queue bound; admissions beyond it raise "
     "`GatewayBackpressure`"),
    (("clock",), "`time.monotonic`",
     "injectable time source (tests drive deterministic clocks)"),
    (("retry", "sentinels"), "`RetryPolicy(max_attempts=2)`, `True`",
     "slice-level fault containment: host-side sentinel battery on "
     "every commit, whole-roster retry then solo isolation, "
     "quarantine with a structured `ExecutionFault`"),
    (("fault_injector",), "`None` \\| `FaultInjector`",
     "seeded fault harness hook (`repro.testing.faults`)"),
    (("journal_dir",), "`None` \\| path",
     "write-ahead admission journal: every submit/admit/slice-commit/"
     "retire is appended (CRC-framed, fsynced) before the in-memory "
     "step completes, graphs persisted once content-addressed, "
     "per-ticket slice-boundary states in durable checkpoint stores. "
     "After a crash, `recover(journal_dir)` replays the journal and "
     "finishes every unfinished ticket **bit-identical** to the "
     "uninterrupted gateway; replay appends nothing, so recovering "
     "twice is idempotent"),
    (("breaker_threshold", "breaker_cooldown"), "`3`, `4`",
     "per-lane circuit breaker: that many *consecutive* faulty slices "
     "open it (lane routes solo-degraded B=1 — bit-identical, just "
     "unbatched), after `cooldown` solo rounds a packed probe "
     "half-opens it, clean probe closes. Counters in `stats()`: "
     "`shed`, `breaker_opens/closes/probes`, `solo_degraded_slices`, "
     "`recovered_tickets`"),
]

_SUBMIT_ROWS: List[Row] = [
    (("key", "max_iters"), "`None`; program default",
     "per-request PRNG key (randomized programs) and iteration cap"),
    (("deadline_s",), "`None` \\| seconds",
     "two protections: a request still iterating past its deadline "
     "retires at the next slice boundary with partial state flagged "
     "`timed_out`; and when the *projected* completion delay "
     "(admission waves ahead × mean service time over the newest "
     "`GatewayStats.SERVICE_WINDOW` completions — queue wait "
     "excluded, so past congestion never biases admission) already "
     "exceeds the deadline, the submit is shed with a structured "
     '`OverloadError(code="overload_shed")` before touching lane '
     "state; deadline-free submits and cold gateways never shed"),
    (("use_pallas", "sparse_edge_capacity", "autotune"),
     "as on `run()`",
     "execution knobs, part of the lane key — requests differing in "
     "them never share a packed roster"),
    (("specialize",), '`"off"` \\| `"static"` \\| `"learned"`',
     "resolve this request's config at admission time (after the "
     "admission checks, so shed/rejected traffic never pays the "
     "profiling cost); the resolved config picks the lane, is "
     "journaled for crash recovery, and its source lands on the "
     "result's `config_source` and in `stats()[\"specialized\"]` — "
     "see docs/SPECIALIZATION.md"),
]

#: section -> (target "module:qualname", params excluded from the
#: cross-check, header row, documented rows)
SECTIONS: Dict[str, dict] = {
    "run": {
        "target": "repro.core.executor:run",
        "exclude": ("program", "graph", "config", "key"),
        "header": ("Knob", "Values (default first)", "What it picks"),
        "rows": _RUN_ROWS,
    },
    "gateway": {
        "target": "repro.launch.serve:GraphGateway.__init__",
        "exclude": ("self",),
        "header": ("Knob", "Default", "What it does"),
        "rows": _GATEWAY_ROWS,
    },
    "submit": {
        "target": "repro.launch.serve:ContinuousScheduler.submit",
        "exclude": ("self", "program", "graph", "config"),
        "header": ("Knob (per `submit`)", "Values (default first)",
                   "What it does"),
        "rows": _SUBMIT_ROWS,
    },
}

# `run()` documents `key=` in prose, not the table; submit documents it
# as a row — so "key" sits in run's exclude list and submit's rows.


def doc_knobs(section: str) -> set:
    """Knob names the section's table documents."""
    return {n for names, _, _ in SECTIONS[section]["rows"] for n in names}


def signature_knobs(section: str) -> set:
    """Parameter names of the section's target callable (minus the
    structural ones in ``exclude``)."""
    spec = SECTIONS[section]
    mod_name, qualname = spec["target"].split(":")
    obj = importlib.import_module(mod_name)
    for attr in qualname.split("."):
        obj = getattr(obj, attr)
    params = inspect.signature(obj).parameters
    return {p for p in params if p not in spec["exclude"]}


def render(section: str) -> str:
    """The section's markdown table (no markers)."""
    spec = SECTIONS[section]
    h = spec["header"]
    lines = [f"| {h[0]} | {h[1]} | {h[2]} |", "|---|---|---|"]
    for names, values, desc in spec["rows"]:
        knob = ", ".join(f"`{n}=`" for n in names)
        lines.append(f"| {knob} | {values} | {desc} |")
    return "\n".join(lines)


def marker(section: str, which: str) -> str:
    if which == "begin":
        return (f"<!-- knobs:{section}:begin — generated by `python -m "
                "repro.doctables --write`; edit src/repro/doctables.py, "
                "not this table -->")
    return f"<!-- knobs:{section}:end -->"


def _block_re(section: str) -> re.Pattern:
    return re.compile(
        re.escape(marker(section, "begin")) + r"\n(?:.*?\n)?"
        + re.escape(marker(section, "end")), re.DOTALL)


def inject(text: str) -> str:
    """Rewrite every marked block in ``text`` with the fresh render;
    raises ValueError for a section whose markers are missing or
    malformed (a silent skip would let the table drift again)."""
    for section in SECTIONS:
        block = (marker(section, "begin") + "\n" + render(section)
                 + "\n" + marker(section, "end"))
        pat = _block_re(section)
        if not pat.search(text):
            raise ValueError(
                f"README markers for knob table {section!r} missing or "
                f"malformed (expected {marker(section, 'begin')!r} ... "
                f"{marker(section, 'end')!r})")
        text = pat.sub(lambda _m: block, text)
    return text


def check_text(text: str) -> List[str]:
    """Drift report for a README body: one message per stale/missing
    block, empty when everything is fresh."""
    problems = []
    for section in SECTIONS:
        m = _block_re(section).search(text)
        if not m:
            problems.append(f"{section}: markers missing")
            continue
        want = (marker(section, "begin") + "\n" + render(section)
                + "\n" + marker(section, "end"))
        if m.group(0) != want:
            problems.append(f"{section}: table out of date (run "
                            "`python -m repro.doctables --write`)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--readme", default="README.md")
    ap.add_argument("--write", action="store_true",
                    help="rewrite the marked README blocks in place")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any marked block is stale")
    args = ap.parse_args(argv)
    path = Path(args.readme)
    text = path.read_text()
    if args.write:
        path.write_text(inject(text))
        print(f"doctables: rewrote {len(SECTIONS)} knob tables in {path}")
        return 0
    problems = check_text(text)
    for p in problems:
        print(f"doctables: {p}")
    if not problems:
        print(f"doctables: {len(SECTIONS)} knob tables fresh in {path}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
