"""meshgraphnet [arXiv:2010.03409]: 15 message-passing layers, hidden 128,
sum aggregation, 2-layer MLPs."""
import dataclasses

from repro.configs.base import make_gnn_arch
from repro.models.gnn.meshgraphnet import MGNConfig, init_mgn, mgn_loss


def _builder(dims):
    return MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2,
                     d_node_in=max(dims["d_feat"], 12), d_edge_in=4, d_out=3)


REDUCED = MGNConfig(n_layers=2, d_hidden=32, mlp_layers=2, d_node_in=12,
                    d_edge_in=4, d_out=3)


def arch(axes=None):  # axes unused: params replicated / no axis names in cfg
    return make_gnn_arch("meshgraphnet", "mgn", _builder, init_mgn,
                         mgn_loss, REDUCED)
