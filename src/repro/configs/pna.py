"""pna [arXiv:2004.05718]: 4 layers, hidden 75, aggregators
mean/max/min/std, scalers id/amplification/attenuation."""
from repro.configs.base import make_gnn_arch
from repro.models.gnn.pna import PNAConfig, init_pna, pna_loss

_CLASSES = {"full_graph_sm": 7, "ogb_products": 47}


def _builder(dims):
    n_cls = 47 if dims["n_nodes"] > 1_000_000 else \
        (7 if dims["d_feat"] == 1433 else 16)
    return PNAConfig(n_layers=4, d_hidden=75, d_in=max(dims["d_feat"], 16),
                     n_classes=n_cls)


REDUCED = PNAConfig(n_layers=2, d_hidden=25, d_in=16, n_classes=5)


def arch(axes=None):  # axes unused: params replicated / no axis names in cfg
    return make_gnn_arch("pna", "pna", _builder, init_pna, pna_loss, REDUCED)
