from repro.configs.registry import ARCH_NAMES, get_arch

__all__ = ["ARCH_NAMES", "get_arch"]
