"""--arch lookup: one module per assigned architecture."""
from __future__ import annotations

import importlib
from functools import lru_cache

_MODULES = {
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "command-r-35b": "repro.configs.command_r_35b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "schnet": "repro.configs.schnet",
    "pna": "repro.configs.pna",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
}

ARCH_NAMES = tuple(_MODULES)


@lru_cache(maxsize=None)
def get_arch(name: str, axes=None):
    """axes: optional configs.base.Axes — binds mesh axis names into the
    model config (sharding constraints) for distributed lowering."""
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).arch(axes=axes)
