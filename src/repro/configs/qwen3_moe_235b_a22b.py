"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B]: 94L d=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, 128 experts top-8 — EP over the model axis."""
import dataclasses

from repro.configs.base import make_lm_arch
from repro.models.moe import MoEConfig

CFG = MoEConfig(
    name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
    n_kv_heads=4, d_head=128, d_ff=1536, vocab=151936, act="swiglu",
    norm="rmsnorm", parallel_block=False, use_bias=False,
    rope_theta=1_000_000.0, n_experts=128, top_k=8,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab=512, n_experts=8, top_k=2)


def arch(axes=None):
    return make_lm_arch("qwen3-moe-235b-a22b", CFG, REDUCED, moe_mode="ep", axes=axes)
