"""equiformer-v2 [arXiv:2306.12059]: 12 layers, hidden 128, l_max=6,
m_max=2, 8 heads, SO(2)/eSCN convolutions."""
from repro.configs.base import make_gnn_arch
from repro.models.gnn.equiformer_v2 import (EquiformerV2Config,
                                            equiformer_loss,
                                            init_equiformer)


def _builder(dims):
    return EquiformerV2Config(n_layers=12, d_hidden=128, l_max=6, m_max=2,
                              n_heads=8, n_graphs=dims["n_graphs"])


REDUCED = EquiformerV2Config(n_layers=2, d_hidden=16, l_max=3, m_max=2,
                             n_heads=4, n_rbf=16, n_graphs=4)


def arch(axes=None):  # axes unused: params replicated / no axis names in cfg
    return make_gnn_arch("equiformer-v2", "equiformer", _builder,
                         init_equiformer, equiformer_loss, REDUCED)
