"""grok-1-314b [hf:xai-org/grok-1]: 64L d=6144 48H (GQA kv=8) expert
d_ff=32768 vocab=131072, 8 experts top-2 — 8 experts < 16-way model axis,
so experts replicate and d_ff tensor-shards (TP-in-expert)."""
import dataclasses

from repro.configs.base import make_lm_arch
from repro.models.moe import MoEConfig

CFG = MoEConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_head=128, d_ff=32768, vocab=131072, act="geglu",
    norm="rmsnorm", parallel_block=False, use_bias=False,
    rope_theta=10_000.0, n_experts=8, top_k=2,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, n_experts=4, top_k=2)


def arch(axes=None):
    return make_lm_arch("grok-1-314b", CFG, REDUCED, moe_mode="tp", axes=axes)
