"""starcoder2-7b [arXiv:2402.19173]: 32L d=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GELU, learned bias, RoPE, 4k sliding-window attention."""
import dataclasses

from repro.configs.base import make_lm_arch
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36,
    n_kv_heads=4, d_head=128, d_ff=18432, vocab=49152, act="gelu",
    norm="layernorm", parallel_block=False, use_bias=True,
    rope_theta=1_000_000.0, window=4096,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
    d_ff=192, vocab=512, window=32)


def arch(axes=None):
    return make_lm_arch("starcoder2-7b", CFG, REDUCED, axes=axes)
