"""schnet [arXiv:1706.08566]: 3 interactions, hidden 64, 300 RBFs,
cutoff 10 A."""
from repro.configs.base import make_gnn_arch
from repro.models.gnn.schnet import SchNetConfig, init_schnet, schnet_loss


def _builder(dims):
    return SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300,
                        cutoff=10.0, n_graphs=dims["n_graphs"])


REDUCED = SchNetConfig(n_interactions=2, d_hidden=32, n_rbf=50, n_graphs=4)


def arch(axes=None):  # axes unused: params replicated / no axis names in cfg
    return make_gnn_arch("schnet", "schnet", _builder, init_schnet,
                         schnet_loss, REDUCED)
