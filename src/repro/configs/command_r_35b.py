"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01]: 40L d=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000 — parallel block, no bias."""
import dataclasses

from repro.configs.base import make_lm_arch
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="command-r-35b", n_layers=40, d_model=8192, n_heads=64,
    n_kv_heads=8, d_head=128, d_ff=22528, vocab=256000, act="swiglu",
    norm="layernorm", parallel_block=True, use_bias=False,
    rope_theta=8_000_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512)


def arch(axes=None):
    return make_lm_arch("command-r-35b", CFG, REDUCED, axes=axes)
