"""Arch/shape registry: every assigned architecture exposes, per input
shape, (abstract params, abstract inputs, step_fn, shardings) — the exact
contract the multi-pod dry-run lowers and compiles.

Families: lm (dense GQA), moe, gnn (mgn/schnet/pna/equiformer), recsys.
Axis conventions (launch/mesh.py): single-pod ("data", "model") = (16, 16);
multi-pod ("pod", "data", "model") = (2, 16, 16).  FSDP shards over
data(+pod), TP over model, EP over model (qwen3) or TP-in-expert (grok),
SP shards long KV caches / carries over model(+data for batch=1).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["Axes", "Cell", "Arch", "axes_for_mesh"]

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32


@dataclasses.dataclass(frozen=True)
class Axes:
    dp: tuple          # batch/FSDP axes
    tp: str            # tensor/expert axis
    all_axes: tuple    # every mesh axis (flat sharding for graph data)
    dp_size: int = 1   # product of dp axis sizes (MoE dispatch groups)


def axes_for_mesh(mesh) -> Axes:
    names = mesh.axis_names
    if "pod" in names:
        return Axes(dp=("pod", "data"), tp="model",
                    all_axes=("pod", "data", "model"),
                    dp_size=mesh.shape["pod"] * mesh.shape["data"])
    return Axes(dp=("data",), tp="model", all_axes=("data", "model"),
                dp_size=mesh.shape["data"])


@dataclasses.dataclass
class Cell:
    """One (arch x input-shape) dry-run unit."""
    shape_name: str
    kind: str                         # train | prefill | decode | serve
    #: () -> pytree of ShapeDtypeStruct for the step's data inputs
    input_specs: Callable[[], Any]
    #: (axes) -> pytree of PartitionSpec matching input_specs
    input_sharding: Callable[[Axes], Any]
    #: (params, [opt_state,] *inputs) -> outputs; closed over model config
    step: Callable[..., Any]
    needs_opt: bool = False
    donate: tuple = ()                # donated argnums for jit


@dataclasses.dataclass
class Arch:
    name: str
    family: str
    cfg: Any
    reduced_cfg: Any
    #: () -> abstract params (ShapeDtypeStruct pytree)
    abstract_params: Callable[[], Any]
    #: (key, cfg) -> concrete params (used with reduced_cfg in smoke tests)
    init_params: Callable[..., Any]
    #: (axes) -> PartitionSpec pytree matching params
    param_sharding: Callable[[Axes], Any]
    cells: "dict[str, Cell]"
    #: cfg-bound with mesh axes injected (lm/moe need axis names in-config)
    bind_axes: Optional[Callable[[Any, Axes], Any]] = None

    def cell(self, shape_name: str) -> Cell:
        return self.cells[shape_name]


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
def replicate_like(tree):
    return jax.tree.map(lambda _: P(), tree)


def opt_sharding_like(param_spec):
    """AdamW state sharding mirrors the parameters."""
    return {"mu": jax.tree.map(lambda s: s, param_spec),
            "nu": jax.tree.map(lambda s: s, param_spec),
            "step": P()}


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------
def lm_param_sharding(cfg, ax: Axes, moe_mode: Optional[str] = None):
    dp, tp = ax.dp, ax.tp
    dense = lambda spec: {"w": spec} if not cfg.use_bias else None
    attn = {
        "wq": {"w": P(None, dp, tp)},
        "wk": {"w": P(None, dp, tp)},
        "wv": {"w": P(None, dp, tp)},
        "wo": {"w": P(None, tp, dp)},
    }
    if cfg.use_bias:
        for k in ("wq", "wk", "wv"):
            attn[k]["b"] = P(None, tp)
        attn["wo"]["b"] = P(None, None)
    block = {"ln1": {"scale": P(None, None)}, "attn": attn}
    if moe_mode is None:
        mlp = {"up": {"w": P(None, dp, tp)}, "down": {"w": P(None, tp, dp)}}
        if cfg.act in ("swiglu", "geglu"):
            mlp["gate"] = {"w": P(None, dp, tp)}
        if cfg.use_bias:
            mlp["up"]["b"] = P(None, tp)
            mlp["down"]["b"] = P(None, None)
            if "gate" in mlp:
                mlp["gate"]["b"] = P(None, tp)
        block["mlp"] = mlp
        if not cfg.parallel_block:
            block["ln2"] = {"scale": P(None, None)}
    else:
        # MoE experts: 'ep' shards the expert axis over tp; 'tp' keeps
        # experts replicated and shards d_ff over tp (few-expert models).
        if moe_mode == "ep":
            espec = P(None, tp, dp, None)
            dspec = P(None, tp, None, dp)
        else:
            espec = P(None, None, dp, tp)
            dspec = P(None, None, tp, dp)
        moe = {"router": P(None, dp, None), "up": espec, "down": dspec}
        if cfg.act in ("swiglu", "geglu"):
            moe["gate"] = espec
        block["moe"] = moe
        block["ln2"] = {"scale": P(None, None)}
    return {
        "embed": P(tp, dp),
        "blocks": block,
        "final_norm": {"scale": P(None)},   # unstacked: rank 1
    }


def lm_train_cell(cfg, shape_name, batch, seq, train_fwd,
                  microbatches: int = 1) -> Cell:
    def specs():
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), I32),
                "labels": jax.ShapeDtypeStruct((batch, seq), I32)}

    def sharding(ax: Axes):
        return {"tokens": P(ax.dp, None), "labels": P(ax.dp, None)}

    opt_cfg = AdamWConfig()

    def step(params, opt_state, batch_in):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: train_fwd(cfg, p, batch_in))(params)
        else:
            # gradient accumulation (§Perf A3): activation/residual memory
            # scales with batch/microbatches while FLOPs and per-token
            # collective volume are unchanged.
            mb = {k: v.reshape(microbatches, batch // microbatches, seq)
                  for k, v in batch_in.items()}

            def micro(carry, b):
                l, g = jax.value_and_grad(
                    lambda p: train_fwd(cfg, p, b))(params)
                return (carry[0] + l,
                        jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                                     carry[1], g)), None

            # fp32 accumulators (bf16 grads summed across microbatches
            # would lose low bits)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss_sum, grads), _ = jax.lax.scan(micro,
                                                (jnp.float32(0), zeros), mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_p, new_o, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

    return Cell(shape_name=shape_name, kind="train", input_specs=specs,
                input_sharding=sharding, step=step, needs_opt=True,
                donate=(0, 1))


def lm_prefill_cell(cfg, shape_name, batch, seq, prefill_fn) -> Cell:
    def specs():
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), I32)}

    def sharding(ax: Axes):
        return {"tokens": P(ax.dp, None)}

    def step(params, batch_in):
        return prefill_fn(cfg, params, batch_in["tokens"])

    return Cell(shape_name=shape_name, kind="prefill", input_specs=specs,
                input_sharding=sharding, step=step)


def lm_decode_cell(cfg, shape_name, batch, kv_seq, decode_fn) -> Cell:
    cache_shape = (cfg.n_layers, batch, cfg.n_kv_heads, kv_seq, cfg.d_head)

    def specs():
        return {
            "token": jax.ShapeDtypeStruct((batch, 1), I32),
            "k_cache": jax.ShapeDtypeStruct(cache_shape, BF16),
            "v_cache": jax.ShapeDtypeStruct(cache_shape, BF16),
            "kv_len": jax.ShapeDtypeStruct((), I32),
        }

    def sharding(ax: Axes):
        if batch >= np.prod([1]) and batch > 1:
            cspec = P(None, ax.dp, None, ax.tp, None)   # B over dp, S over tp
            tspec = P(ax.dp, None)
        else:  # batch=1 long-context: shard the sequence over everything
            cspec = P(None, None, None, ax.all_axes, None)
            tspec = P(None, None)
        return {"token": tspec, "k_cache": cspec, "v_cache": cspec,
                "kv_len": P()}

    def step(params, batch_in):
        return decode_fn(cfg, params, batch_in["token"],
                         (batch_in["k_cache"], batch_in["v_cache"]),
                         batch_in["kv_len"])

    return Cell(shape_name=shape_name, kind="decode", input_specs=specs,
                input_sharding=sharding, step=step, donate=())


def make_lm_arch(name, cfg, reduced_cfg, *, moe_mode=None,
                 axes: Optional[Axes] = None) -> Arch:
    if axes is not None:
        cfg = dataclasses.replace(cfg, dp_axes=tuple(axes.dp),
                                  tp_axis=axes.tp, sp_axis=axes.tp)
        if moe_mode is not None:
            cfg = dataclasses.replace(cfg, moe_mode=moe_mode,
                                      dispatch_groups=axes.dp_size)
    if moe_mode is None:
        from repro.models.transformer import (abstract_lm_params, decode_step,
                                              init_lm, prefill, train_forward)
        init, abstract = init_lm, abstract_lm_params
        train_fwd, decode_fn = train_forward, decode_step
        prefill_fn = prefill
    else:
        from repro.models.moe import (abstract_moe_params, init_moe_lm,
                                      moe_decode_step, moe_prefill,
                                      moe_train_forward)
        init, abstract = init_moe_lm, abstract_moe_params
        train_fwd, decode_fn = moe_train_forward, moe_decode_step
        prefill_fn = moe_prefill

    cells = {
        "train_4k": lm_train_cell(cfg, "train_4k", 256, 4096, train_fwd,
                                  microbatches=8),
        "decode_32k": lm_decode_cell(cfg, "decode_32k", 128, 32768,
                                     decode_fn),
        "long_500k": lm_decode_cell(cfg, "long_500k", 1, 524288, decode_fn),
    }
    cells["prefill_32k"] = lm_prefill_cell(cfg, "prefill_32k", 32, 32768,
                                           prefill_fn)

    return Arch(
        name=name, family="moe" if moe_mode else "lm",
        cfg=cfg, reduced_cfg=reduced_cfg,
        abstract_params=lambda: abstract(cfg),
        init_params=init,
        param_sharding=lambda ax: lm_param_sharding(cfg, ax, moe_mode),
        cells=cells,
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------
def _pad512(n: int) -> int:
    """Graph tensors are padded up to the 512-device multiple (the input
    pipeline emits sentinel-masked pad nodes/edges — standard practice;
    worst case +13% on full_graph_sm)."""
    return -(-n // 512) * 512


#: the 4 assigned shapes: (n_nodes, n_edges, d_feat, n_graphs)
GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=_pad512(2708), n_edges=_pad512(10556),
                          d_feat=1433, n_graphs=1, kind="train"),
    "minibatch_lg": dict(n_nodes=_pad512(1024 * (1 + 10 + 150)),
                         n_edges=_pad512(1024 * 10 + 1024 * 150),
                         d_feat=602, n_graphs=1, kind="train"),
    "ogb_products": dict(n_nodes=_pad512(2449029), n_edges=_pad512(61859140),
                         d_feat=100, n_graphs=1, kind="train"),
    "molecule": dict(n_nodes=_pad512(30 * 128), n_edges=_pad512(64 * 128 * 2),
                     d_feat=0, n_graphs=128, kind="train"),
}


def gnn_input_specs(model_kind: str, dims) -> Callable[[], Any]:
    n, e, f, g = (dims["n_nodes"], dims["n_edges"], dims["d_feat"],
                  dims["n_graphs"])

    def specs():
        base = {"src": jax.ShapeDtypeStruct((e,), I32),
                "dst": jax.ShapeDtypeStruct((e,), I32)}
        if model_kind in ("schnet", "equiformer"):
            base.update({
                "species": jax.ShapeDtypeStruct((n,), I32),
                "positions": jax.ShapeDtypeStruct((n, 3), F32),
                "graph_ids": jax.ShapeDtypeStruct((n,), I32),
                "energy": jax.ShapeDtypeStruct((g,), F32),
            })
        elif model_kind == "mgn":
            base.update({
                "node_feat": jax.ShapeDtypeStruct((n, max(f, 12)), F32),
                "edge_feat": jax.ShapeDtypeStruct((e, 4), F32),
                "target": jax.ShapeDtypeStruct((n, 3), F32),
            })
        else:  # pna
            base.update({
                "node_feat": jax.ShapeDtypeStruct((n, max(f, 16)), F32),
                "in_degree": jax.ShapeDtypeStruct((n,), I32),
                "labels": jax.ShapeDtypeStruct((n,), I32),
            })
        return base

    return specs


def gnn_input_sharding(model_kind: str):
    def sharding(ax: Axes):
        flat = ax.all_axes
        base = {"src": P(flat), "dst": P(flat)}
        if model_kind in ("schnet", "equiformer"):
            base.update({"species": P(flat), "positions": P(flat, None),
                         "graph_ids": P(flat), "energy": P(None)})
        elif model_kind == "mgn":
            base.update({"node_feat": P(flat, None),
                         "edge_feat": P(flat, None),
                         "target": P(flat, None)})
        else:
            base.update({"node_feat": P(flat, None), "in_degree": P(flat),
                         "labels": P(flat)})
        return base

    return sharding


def make_gnn_arch(name, model_kind, cfg_builder, init_fn, loss_fn,
                  reduced_cfg) -> Arch:
    """cfg_builder(dims) -> shape-specialised model config."""
    opt_cfg = AdamWConfig(lr=1e-3)
    cells = {}
    cfg0 = cfg_builder(GNN_SHAPES["molecule"]
                       if model_kind in ("schnet", "equiformer")
                       else GNN_SHAPES["full_graph_sm"])

    for shape_name, dims in GNN_SHAPES.items():
        cfg = cfg_builder(dims)

        def step(params, opt_state, batch_in, cfg=cfg):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch_in))(params)
            new_p, new_o, gnorm = adamw_update(grads, opt_state, params,
                                               opt_cfg)
            return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

        cells[shape_name] = Cell(
            shape_name=shape_name, kind="train",
            input_specs=gnn_input_specs(model_kind, dims),
            input_sharding=gnn_input_sharding(model_kind),
            step=step, needs_opt=True, donate=(0, 1),
        )

    # NB: GNN params are small -> replicated; per-shape configs share the
    # same param structure except input-dim dependent encoders, so
    # abstract_params must be built per shape at dry-run time.
    def abstract_for(shape_name):
        cfg = cfg_builder(GNN_SHAPES[shape_name])
        return jax.eval_shape(lambda: init_fn(jax.random.key(0), cfg))

    arch = Arch(
        name=name, family="gnn", cfg=cfg0, reduced_cfg=reduced_cfg,
        abstract_params=lambda: abstract_for("full_graph_sm"),
        init_params=init_fn,
        param_sharding=lambda ax: None,  # computed from abstract (replicated)
        cells=cells,
    )
    arch.abstract_params_for = abstract_for  # per-shape variant
    return arch


# ---------------------------------------------------------------------------
# RecSys family (DLRM)
# ---------------------------------------------------------------------------
def make_dlrm_arch(name, cfg, reduced_cfg) -> Arch:
    from repro.models.dlrm import (dlrm_forward, dlrm_loss, init_dlrm,
                                   retrieval_score)
    opt_cfg = AdamWConfig(lr=1e-3)

    def specs_for(batch, retrieval=False):
        def specs():
            base = {
                "dense": jax.ShapeDtypeStruct((batch, cfg.n_dense), F32),
                "sparse": jax.ShapeDtypeStruct(
                    (batch, cfg.n_sparse, cfg.multi_hot), I32),
            }
            if retrieval:
                base["cand"] = jax.ShapeDtypeStruct(
                    (_pad512(1_000_000), cfg.embed_dim), F32)
            else:
                base["label"] = jax.ShapeDtypeStruct((batch,), I32)
            return base
        return specs

    def sharding_for(batch, retrieval=False):
        def sharding(ax: Axes):
            dp = ax.dp if batch > 1 else None
            base = {"dense": P(dp, None), "sparse": P(dp, None, None)}
            if retrieval:
                base["cand"] = P(ax.all_axes, None)
            else:
                base["label"] = P(dp)
            return base
        return sharding

    def train_step(params, opt_state, batch_in):
        loss, grads = jax.value_and_grad(
            lambda p: dlrm_loss(cfg, p, batch_in))(params)
        new_p, new_o, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

    def serve_step(params, batch_in):
        return dlrm_forward(cfg, params, batch_in)

    def retrieval_step(params, batch_in):
        return retrieval_score(cfg, params, batch_in)

    cells = {
        "train_batch": Cell("train_batch", "train", specs_for(65536),
                            sharding_for(65536), train_step, needs_opt=True,
                            donate=(0, 1)),
        "serve_p99": Cell("serve_p99", "serve", specs_for(512),
                          sharding_for(512), serve_step),
        "serve_bulk": Cell("serve_bulk", "serve", specs_for(262144),
                           sharding_for(262144), serve_step),
        "retrieval_cand": Cell("retrieval_cand", "serve",
                               specs_for(1, retrieval=True),
                               sharding_for(1, retrieval=True),
                               retrieval_step),
    }

    def param_sharding(ax: Axes):
        # row-shard big tables over tp; tiny tables replicated
        tables = [P(ax.tp, None) if v >= 4096 else P(None, None)
                  for v in cfg.vocab_sizes]
        rep = lambda tree: jax.tree.map(lambda _: P(), tree)
        import jax as _jax
        abstract = _jax.eval_shape(
            lambda: init_dlrm(_jax.random.key(0), cfg))
        return {"tables": tables, "bot": rep(abstract["bot"]),
                "top": rep(abstract["top"])}

    return Arch(
        name=name, family="recsys", cfg=cfg, reduced_cfg=reduced_cfg,
        abstract_params=lambda: jax.eval_shape(
            lambda: init_dlrm(jax.random.key(0), cfg)),
        init_params=init_dlrm,
        param_sharding=param_sharding,
        cells=cells,
    )
