"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-plus]: 64L d=12288
96H (GQA kv=8) d_ff=33792 vocab=256000 — parallel attn+FFN block, no bias."""
import dataclasses

from repro.configs.base import make_lm_arch
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
    n_kv_heads=8, d_head=128, d_ff=33792, vocab=256000, act="swiglu",
    norm="layernorm", parallel_block=True, use_bias=False,
    rope_theta=75_000_000.0,
)

REDUCED = dataclasses.replace(
    CFG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512)


def arch(axes=None):
    return make_lm_arch("command-r-plus-104b", CFG, REDUCED, axes=axes)
