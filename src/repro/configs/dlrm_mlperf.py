"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM (Criteo 1TB): 13 dense,
26 sparse, dim 128, bot 512-256-128, top 1024-1024-512-256-1, dot
interaction."""
import dataclasses

from repro.configs.base import make_dlrm_arch
from repro.models.dlrm import DLRMConfig

CFG = DLRMConfig()

REDUCED = DLRMConfig(vocab_sizes=(1000, 200, 50, 300, 77, 10),
                     embed_dim=16, bot_mlp=(64, 32, 16),
                     top_mlp=(64, 32, 1))


def arch(axes=None):  # axes unused: params replicated / no axis names in cfg
    return make_dlrm_arch("dlrm-mlperf", CFG, REDUCED)
